"""Benchmark regression gate: diff fresh ``BENCH_*.json`` artifacts against
the committed snapshots in ``benchmarks/baselines/``.

Per-metric tolerance bands, not one global threshold:

  * **exact class** — correctness flags and orderings (token parity,
    exactly-once, ``*_complete`` / ``*_equal`` / ``*_ok`` / ``*_conserved``
    observability gates, aware-beats-blind orderings). These must match the
    baseline bit-for-bit and are compared even when the smoke flags differ
    (a parity flag that holds on the full run must hold on the smoke run
    too). Any mismatch fails the job.
  * **wall-clock class** — ``wall_s``, ``*_tok_s``, latency percentiles,
    decision times: machine-dependent, reported only, never gated.
  * **banded class** — everything else numeric. Gated within a relative
    tolerance band, but only when the fresh artifact and the baseline were
    produced at the same scale (identical ``meta.smoke``): a smoke run's
    counts legitimately differ from the committed full-run snapshot, so a
    scale mismatch demotes the band to report-only.

Keys present on one side only are reported (new metrics appear with every
PR; that is the point of the trajectory) — except an exact-class key that
*disappears* at matching scale, which fails: a deleted parity gate is a
silenced alarm, not a neutral diff.

Usage::

    python -m benchmarks.regression --fresh bench-out [--baselines DIR]
                                    [--tolerance 0.3]

Exit code 0 when every gate holds, 1 otherwise. Stdlib only.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Tuple

_BASELINES = os.path.join(os.path.dirname(__file__), "baselines")

# substrings that put a metric name in the exact class
_EXACT_TOKENS = (
    "parity", "identical", "exactly_once", "all_passed", "ordering",
    "beats", "conserved",
)
_EXACT_SUFFIXES = ("_complete", "_equal", "_ok", "_passed")

# substrings that put a metric name in the wall-clock (report-only) class
_WALL_TOKENS = (
    "wall_s", "tok_s", "decision_ms", "_ms", "latency", "time_to_recover",
    "post_event", "recover_s",
)


def classify(key: str) -> str:
    """'exact' | 'wall' | 'banded' for a flattened metric key."""
    leaf = key.rsplit(".", 1)[-1]
    if any(t in leaf for t in _EXACT_TOKENS) or leaf.endswith(_EXACT_SUFFIXES):
        return "exact"
    if any(t in leaf for t in _WALL_TOKENS):
        return "wall"
    return "banded"


def flatten(obj, prefix: str = "") -> Dict[str, object]:
    """Nested metrics dict -> {'a.b.c': scalar-or-list} with dotted keys."""
    out: Dict[str, object] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(flatten(v, f"{prefix}{k}."))
    else:
        out[prefix[:-1]] = obj
    return out


def _eq(a, b) -> bool:
    if isinstance(a, bool) or isinstance(b, bool):
        return bool(a) == bool(b)
    if isinstance(a, (int, float)) and isinstance(b, (int, float)):
        return float(a) == float(b)
    return a == b


def _within_band(a, b, tol: float) -> bool:
    if not (isinstance(a, (int, float)) and isinstance(b, (int, float))):
        return a == b
    a, b = float(a), float(b)
    return abs(a - b) <= tol * max(abs(a), abs(b), 1.0)


def diff_artifact(
    fresh: dict, base: dict, tol: float
) -> Tuple[List[str], List[str]]:
    """(failures, notes) for one fresh/baseline artifact pair."""
    failures: List[str] = []
    notes: List[str] = []
    same_scale = fresh.get("meta", {}).get("smoke") == base.get(
        "meta", {}
    ).get("smoke")
    f = flatten(fresh.get("metrics", {}))
    b = flatten(base.get("metrics", {}))
    if not same_scale:
        notes.append(
            "scale mismatch (smoke flags differ): banded metrics report-only"
        )
    for key in sorted(set(f) | set(b)):
        if key.rsplit(".", 1)[-1].endswith("_path"):
            continue                    # machine-local paths, never compared
        cls = classify(key)
        if key not in b:
            notes.append(f"new metric: {key} = {f[key]}")
            continue
        if key not in f:
            if cls == "exact" and same_scale:
                failures.append(f"exact-class metric removed: {key}")
            else:
                notes.append(f"metric gone from fresh run: {key}")
            continue
        fv, bv = f[key], b[key]
        if cls == "exact":
            if not _eq(fv, bv):
                failures.append(f"exact mismatch: {key}: {bv} -> {fv}")
        elif cls == "wall":
            if isinstance(fv, (int, float)) and isinstance(bv, (int, float)):
                if float(bv) != 0.0 and float(fv) != float(bv):
                    notes.append(
                        f"wall-clock: {key}: {bv} -> {fv} "
                        f"({(float(fv) / float(bv) - 1) * 100:+.1f}%)"
                    )
        else:
            if not _within_band(fv, bv, tol):
                msg = f"banded drift (>{tol:.0%}): {key}: {bv} -> {fv}"
                if same_scale:
                    failures.append(msg)
                else:
                    notes.append(msg)
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", default=".",
                    help="directory holding the fresh BENCH_*.json artifacts")
    ap.add_argument("--baselines", default=_BASELINES,
                    help="committed snapshot directory")
    ap.add_argument("--tolerance", type=float, default=0.3,
                    help="relative band for same-scale numeric metrics")
    ap.add_argument("--verbose", action="store_true",
                    help="print report-only notes, not just gates")
    args = ap.parse_args(argv)

    fresh_paths = sorted(glob.glob(os.path.join(args.fresh, "BENCH_*.json")))
    if not fresh_paths:
        print(f"regression: no BENCH_*.json under {args.fresh!r}", file=sys.stderr)
        return 1

    any_failures = False
    compared = 0
    for path in fresh_paths:
        name = os.path.basename(path)
        base_path = os.path.join(args.baselines, name)
        if not os.path.exists(base_path):
            print(f"{name}: no committed baseline — skipped (new benchmark?)")
            continue
        with open(path) as fh:
            fresh = json.load(fh)
        with open(base_path) as fh:
            base = json.load(fh)
        failures, notes = diff_artifact(fresh, base, args.tolerance)
        compared += 1
        status = "FAIL" if failures else "ok"
        print(f"{name}: {status} "
              f"({len(failures)} gate failures, {len(notes)} notes)")
        for line in failures:
            print(f"  FAIL {line}")
        if args.verbose:
            for line in notes:
                print(f"  note {line}")
        any_failures = any_failures or bool(failures)

    if compared == 0:
        print("regression: no artifact had a committed baseline", file=sys.stderr)
        return 1
    print("# regression gates " + ("FAILED" if any_failures else "passed"))
    return 1 if any_failures else 0


if __name__ == "__main__":
    sys.exit(main())
