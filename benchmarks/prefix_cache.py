"""Prefix caching end-to-end: KV reuse on the serving path and cache-aware
pricing in the offline packer.

Three arms, all hard-gated (a regression exits non-zero):

  * reuse — the same Zipf-skewed shared-prefix workload served with the
    prefix cache off vs on. Gates: bit-identical token streams (caching is
    an optimization, not a model change), strictly fewer *computed* prefill
    tokens (computed + cached must equal the baseline's computed — pages
    are reused, work is not dropped), and strictly better mean TTFT and
    makespan (skipped chunk rounds are real time, not bookkeeping).
  * pricing — a warm cache plus a prompt mix where nominal prompt length
    misleads: hot-group requests carry long prompts that are almost fully
    cached, a cold request carries a slightly shorter but fully uncached
    prompt. Cache-blind LPT pairs the cold prompt with a hot one (it prices
    nominal tokens); cache-aware pricing isolates it. Gate: at exact
    nominal-token parity, the aware assignment's true makespan (priced by
    uncached work) is strictly better.
  * hygiene — after the cached serve every page still allocated is a cache
    hold (refcounts consistent), and clearing the index returns the pool
    to exactly zero pages in use. Leaked or double-freed pages fail here.

Run: PYTHONPATH=src python -m benchmarks.prefix_cache [--smoke] [--out DIR]
Prints ``name,value,unit`` CSV and writes BENCH_prefix_cache.json.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import (
    CostModel,
    GlobalQueueScheduler,
    PrefillFirstPolicy,
    Request,
    build_clients,
)
from repro.core.offline import request_weights, solve_offline
from repro.data import WorkloadSpec, shared_prefix_workload

from .bench_io import emit_json, run_serving_benchmark

FULL = dict(
    arch=ArchConfig(
        name="bench", family="dense", n_layers=2, d_model=128, n_heads=8,
        n_kv_heads=4, d_ff=256, vocab_size=512,
    ),
    # short replies: prefill dominates, which is the regime prefix reuse
    # is supposed to win in (long shared templates, short completions)
    spec=WorkloadSpec(
        n_requests=32, input_mean=72, input_std=20, output_mean=8,
        output_std=4, output_max=12, input_max=120,
    ),
    n_groups=3, prefix_mean=64.0, prefix_std=8.0,
    n_slots=8, max_len=160, seq_buckets=(64, 128),
    level_caps=(64, 128, 256), prefill_chunk=16, page_size=16, num_pages=192,
    # pricing arm: hot prompts are nominally the longest but ~fully cached
    price_hot=110, price_cold=100, price_prefix=96, price_decode=4,
)
SMOKE = dict(
    arch=ArchConfig(
        name="bench-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256,
    ),
    spec=WorkloadSpec(
        n_requests=10, input_mean=48, input_std=12, output_mean=6,
        output_std=3, output_max=10, input_max=80,
    ),
    n_groups=2, prefix_mean=32.0, prefix_std=4.0,
    n_slots=4, max_len=112, seq_buckets=(32, 64),
    level_caps=(32, 64, 128), prefill_chunk=16, page_size=16, num_pages=96,
    price_hot=62, price_cold=56, price_prefix=48, price_decode=4,
)


def _serve_arm(cfg, prefix_cache: bool):
    """One measured serve of the shared-prefix workload (cache on or off).

    The harness's warm pass (seed 12) draws from the same prefix groups, so
    the cache-on arm measures the steady state: a warm index, the regime a
    long-running server actually sits in."""
    wf = lambda seed: shared_prefix_workload(  # noqa: E731
        cfg["spec"], seed=seed, n_groups=cfg["n_groups"],
        prefix_mean=cfg["prefix_mean"], prefix_std=cfg["prefix_std"],
        known_lengths=True,
    )
    eng, metrics, trace = run_serving_benchmark(
        cfg, workload_factory=wf, kv_layout="paged",
        page_size=cfg["page_size"], prefill_chunk=cfg["prefill_chunk"],
        num_pages=cfg["num_pages"], prefix_cache=prefix_cache,
    )
    ttfts = [r.ttft for r in trace.requests if r.ttft is not None]
    metrics["ttft_mean_s"] = float(np.mean(ttfts)) if ttfts else 0.0
    metrics["makespan_s"] = trace.makespan
    metrics["computed_prefill_tokens"] = float(trace.computed_prefill_tokens)
    metrics["cached_prefill_tokens"] = float(trace.cached_prefill_tokens)
    return eng, metrics, trace


def run_reuse_arm(cfg):
    eng_off, off, _ = _serve_arm(cfg, prefix_cache=False)
    eng_on, on, _ = _serve_arm(cfg, prefix_cache=True)
    parity = all(
        eng_off.generated[r] == eng_on.generated[r] for r in eng_off.generated
    ) and set(eng_off.generated) == set(eng_on.generated)
    failures = []
    if not parity:
        failures.append("reuse: token streams differ between cache off/on")
    if not on["computed_prefill_tokens"] < off["computed_prefill_tokens"]:
        failures.append(
            "reuse: cache did not reduce computed prefill tokens "
            f"({on['computed_prefill_tokens']:.0f} vs "
            f"{off['computed_prefill_tokens']:.0f})"
        )
    if (on["computed_prefill_tokens"] + on["cached_prefill_tokens"]
            != off["computed_prefill_tokens"]):
        failures.append(
            "reuse: computed+cached != baseline computed (work was dropped "
            "or double-counted, not reused)"
        )
    if not on["ttft_mean_s"] < off["ttft_mean_s"]:
        failures.append(
            f"reuse: mean TTFT not improved ({on['ttft_mean_s']:.4f}s vs "
            f"{off['ttft_mean_s']:.4f}s)"
        )
    if not on["makespan_s"] < off["makespan_s"]:
        failures.append(
            f"reuse: makespan not improved ({on['makespan_s']:.4f}s vs "
            f"{off['makespan_s']:.4f}s)"
        )
    if not on["cache_hit_tokens"] > 0:
        failures.append("reuse: cache-on serve recorded zero hit tokens")
    return eng_on, {"off": off, "on": on, "token_parity": parity}, failures


def run_pricing_arm(cfg, eng):
    """Cache-aware vs cache-blind offline pricing on a warm cache.

    Two hot requests share a ``price_prefix``-token template the serve just
    left resident; one cold request is slightly shorter but fully uncached.
    Blind LPT orders by nominal length, so the cold prompt lands next to a
    hot one; aware pricing sees the hot prompts are nearly free and gives
    the cold prompt a client of its own. Both assignments cover the same
    requests (exact nominal-token parity) — only the split differs."""
    cm = CostModel(level_caps=cfg["level_caps"])
    hot_group = 9000  # fresh group id: warmed here, not by the reuse arm
    warm = Request(
        rid=9000, n_prefill=cfg["price_hot"], n_decode=1, n_decode_est=1,
        prefix_group=hot_group, prefix_len=cfg["price_prefix"],
    )
    eng.serve([warm], build_clients(cfg["n_slots"], [warm]),
              GlobalQueueScheduler([warm]), PrefillFirstPolicy())
    reqs = [
        Request(rid=0, n_prefill=cfg["price_hot"], n_decode=cfg["price_decode"],
                n_decode_est=cfg["price_decode"], prefix_group=hot_group,
                prefix_len=cfg["price_prefix"]),
        Request(rid=1, n_prefill=cfg["price_hot"], n_decode=cfg["price_decode"],
                n_decode_est=cfg["price_decode"], prefix_group=hot_group,
                prefix_len=cfg["price_prefix"]),
        Request(rid=2, n_prefill=cfg["price_cold"], n_decode=cfg["price_decode"],
                n_decode_est=cfg["price_decode"]),
    ]
    # price against the warm fleet state: probe each prompt's resident pages
    for r in reqs:
        r.cached_prefill = eng.slots.probe_prefix(eng._prompt_tokens(r))
    aware = solve_offline(reqs, 2, cm, include_prefill=True, cache_aware=True)
    blind = solve_offline(reqs, 2, cm, include_prefill=True, cache_aware=False)
    # both splits are judged by the work that will actually run: the
    # cache-aware (uncached-token) cost is ground truth for a warm cache
    w_true = request_weights(reqs, cm, 2, include_prefill=True, cache_aware=True)
    w_of = {r.rid: float(w) for r, w in zip(reqs, w_true)}
    ms = lambda asn: max(  # noqa: E731
        (sum(w_of[rid] for rid in client) for client in asn), default=0.0
    )
    aware_ms, blind_ms = float(ms(aware.assignment)), float(ms(blind.assignment))
    failures = []
    if [r.cached_prefill for r in reqs[:2]] != [cfg["price_prefix"]] * 2:
        failures.append(
            "pricing: warm probe missed the hot prefix "
            f"(got {[r.cached_prefill for r in reqs]})"
        )
    if reqs[2].cached_prefill != 0:
        failures.append("pricing: cold request probed as cached")
    if not aware_ms < blind_ms:
        failures.append(
            f"pricing: cache-aware not strictly better ({aware_ms:.4f}s vs "
            f"blind {blind_ms:.4f}s)"
        )
    metrics = {
        "aware_makespan_s": aware_ms,
        "blind_makespan_s": blind_ms,
        "pricing_gain": (blind_ms - aware_ms) / blind_ms if blind_ms else 0.0,
        "nominal_tokens": float(sum(r.n_prefill for r in reqs)),
        "cached_tokens_probed": float(sum(r.cached_prefill for r in reqs)),
    }
    return metrics, failures


def run_hygiene_arm(eng):
    """The pool must end refcount-clean: every allocated page is an index
    hold, and dropping the index frees everything."""
    failures = []
    try:
        eng.slots.check_refcounts()
    except AssertionError as e:  # pragma: no cover - gate path
        failures.append(f"hygiene: refcount check failed ({e})")
    held = len(eng.slots.prefix_index.held_pages())
    used = eng.slots.allocator.num_used
    if used != held:
        failures.append(
            f"hygiene: {used} pages in use but only {held} cache holds "
            "(leaked pages)"
        )
    eng.slots.prefix_index.clear()
    if eng.slots.allocator.num_used != 0:
        failures.append(
            f"hygiene: {eng.slots.allocator.num_used} pages still in use "
            "after clearing the index"
        )
    return {
        "end_pages_held": float(held),
        "end_pages_used_after_clear": float(eng.slots.allocator.num_used),
    }, failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (seconds, not minutes)")
    ap.add_argument("--out", default=None, help="directory for BENCH_*.json")
    args = ap.parse_args()
    cfg = SMOKE if args.smoke else FULL

    eng_on, reuse, failures = run_reuse_arm(cfg)
    pricing, f2 = run_pricing_arm(cfg, eng_on)
    hygiene, f3 = run_hygiene_arm(eng_on)
    failures += f2 + f3

    print("name,value,unit")
    for name in ("off", "on"):
        m = reuse[name]
        print(f"{name}_throughput,{m['throughput_tok_s']:.1f},tok/s")
        print(f"{name}_computed_prefill,{m['computed_prefill_tokens']:.0f},tok")
        print(f"{name}_cached_prefill,{m['cached_prefill_tokens']:.0f},tok")
        print(f"{name}_ttft_mean,{m['ttft_mean_s'] * 1e3:.2f},ms")
        print(f"{name}_makespan,{m['makespan_s']:.4f},s")
    on = reuse["on"]
    print(f"token_parity,{int(reuse['token_parity'])},bool")
    print(f"cached_token_rate,{on['cached_token_rate']:.4f},frac")
    print(f"shared_pages_peak,{on['shared_pages_peak']:.0f},pages")
    print(f"aware_makespan,{pricing['aware_makespan_s']:.4f},s")
    print(f"blind_makespan,{pricing['blind_makespan_s']:.4f},s")
    print(f"pricing_gain,{pricing['pricing_gain']:.4f},frac")
    print(f"end_pages_used_after_clear,"
          f"{hygiene['end_pages_used_after_clear']:.0f},pages")

    payload = {"reuse": reuse, "pricing": pricing, "hygiene": hygiene}
    path = emit_json("prefix_cache", payload, smoke=args.smoke, out_dir=args.out)
    print(f"# wrote {path}")
    if failures:
        raise SystemExit("prefix_cache gates failed:\n  " + "\n  ".join(failures))


if __name__ == "__main__":
    main()
