"""Overload control: SLO goodput, preemption-by-eviction, fault recovery.

Three arms, each a robustness claim of the serving layer with a hard-fail
structural gate (stable on CPU; wall-clock magnitudes are reported, not
asserted):

  * **goodput** — one engine co-serves an offline backlog with
    SLO-carrying Poisson-style online arrivals, once SLO-blind (base
    ``OverloadPolicy``: admit FCFS) and once under
    ``SLOAwareOverloadPolicy`` (defer offline admission while online TTFT
    attainment is under pressure — HyGen-style graceful degradation). The
    TTFT SLO is calibrated from a measured aggressive-deferral run, so the
    gate is machine-independent: the aware serve must strictly beat the
    blind serve on goodput (SLO-attaining tokens / makespan) AND SLO
    attainment, at exact per-request token parity.
  * **eviction** — the same workload on the same deliberately small page
    pool, once with up-front whole-lifetime page reservation and once with
    on-demand growth + preemption-by-page-eviction. On-demand must admit
    strictly more concurrent requests (peak concurrency), actually exercise
    preemption, and still produce bit-identical streams.
  * **fault** — a fleet serve with a replica killed mid-flight
    (``ReplicaFault``): survivors must absorb its queued and in-flight
    work and finish EVERY request exactly once, with token streams
    bit-identical to the no-fault serve.

Run:  PYTHONPATH=src python -m benchmarks.overload [--smoke] [--out DIR]
Prints ``name,value,unit`` CSV and writes BENCH_overload.json.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

FULL = dict(
    model=dict(n_layers=2, d_model=128, n_heads=8, n_kv_heads=4, d_ff=256,
               vocab_size=512),
    # goodput arm: 2 slots keep admission contention high
    a_slots=2, a_max_len=96,
    n_off=14, off_prefill=16, off_decode=32,
    n_on=8, on_prefill=8, on_decode=14,
    arrival_gap_rounds=8.0, first_arrival_rounds=4.0,
    slo_margin=2.0,
    # eviction arm: pool sized so up-front reservation halves concurrency
    b_slots=4, b_max_len=64, b_page_size=8, b_num_pages=12,
    n_b=6, b_prefill=12, b_decode=28,
    # fault arm
    n_replicas=3, f_slots=2, f_max_len=64,
    n_f=10, f_prefill=12, f_decode=24, kill_frac=0.3,
    seq_buckets=(32,), level_caps=(32, 64, 128),
    page_size=16, prefill_chunk=16,
)
SMOKE = dict(
    model=dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
               vocab_size=256),
    a_slots=2, a_max_len=64,
    n_off=12, off_prefill=16, off_decode=24,
    n_on=6, on_prefill=8, on_decode=12,
    arrival_gap_rounds=8.0, first_arrival_rounds=4.0,
    slo_margin=2.0,
    b_slots=4, b_max_len=64, b_page_size=8, b_num_pages=12,
    n_b=6, b_prefill=12, b_decode=28,
    n_replicas=2, f_slots=2, f_max_len=64,
    n_f=8, f_prefill=12, f_decode=24, kill_frac=0.3,
    seq_buckets=(32,), level_caps=(32, 64, 128),
    page_size=16, prefill_chunk=16,
)


def _model_and_params(cfg):
    import jax

    from repro.configs.base import ArchConfig
    from repro.models.layers import init_params
    from repro.models.transformer import TransformerLM

    arch = ArchConfig(name="overload-bench", family="dense", **cfg["model"])
    model = TransformerLM(arch)
    params = init_params(jax.random.key(0), model.param_defs())
    return model, params


# --------------------------------------------------------------------------- #
# Arm A: SLO-aware vs SLO-blind goodput                                       #
# --------------------------------------------------------------------------- #
def _goodput_workload(cfg, round_s: float, slo_s: float):
    """Offline backlog + early online arrivals. Arrival spacing scales with
    the measured decode round time so traffic intensity (and therefore the
    contention the SLO protects against) is machine-independent."""
    from repro.core import Request

    reqs = [
        Request(rid=i, n_prefill=cfg["off_prefill"], n_decode=cfg["off_decode"])
        for i in range(cfg["n_off"])
    ]
    t = cfg["first_arrival_rounds"] * round_s
    for i in range(cfg["n_on"]):
        reqs.append(Request(
            rid=100 + i, n_prefill=cfg["on_prefill"],
            n_decode=cfg["on_decode"], arrival=t,
            ttft_slo_s=(slo_s if slo_s > 0 else None),
        ))
        t += cfg["arrival_gap_rounds"] * round_s
    return reqs


def _engine(cfg, model, params, n_slots, max_len, overload=None, **kw):
    from repro.core import CostModel
    from repro.serving.engine import Engine, EngineConfig

    kw.setdefault("page_size", cfg.get("page_size", 16))
    eng = Engine(
        model, params,
        EngineConfig(
            n_slots=n_slots, max_len=max_len,
            prefill_seq_buckets=cfg["seq_buckets"],
            kv_layout="paged",
            prefill_chunk=cfg["prefill_chunk"], **kw,
        ),
        overload_policy=overload,
    )
    eng.profiler.cost_model = CostModel(level_caps=cfg["level_caps"])
    return eng


def _serve(eng, reqs):
    from repro.core import ArrivalQueueScheduler, LagrangianPolicy, build_clients

    clients = build_clients(eng.cfg.n_slots, reqs, None)
    t0 = time.perf_counter()
    trace = eng.serve(
        reqs, clients, ArrivalQueueScheduler(reqs), LagrangianPolicy()
    )
    wall = time.perf_counter() - t0
    return trace, wall


def _round_time_s(trace) -> float:
    samples = [
        s.duration / max(s.rounds, 1)
        for s in trace.stages
        if s.kind.value in ("decode", "mixed") and s.tokens - s.chunk_tokens > 0
    ]
    return float(np.median(samples))


def run_goodput_arm(cfg, model, params):
    from repro.serving.overload import OverloadPolicy, SLOAwareOverloadPolicy

    from .bench_io import engine_metrics

    def warmed(pol):
        # jit caches live per-engine: every arm warms ITS OWN engine on a
        # same-shape SLO-free workload, so no compile lands inside a
        # measured serve (a single compile blip dwarfs every real TTFT and
        # would erase the policy separation this arm measures). The warm
        # serve runs without the arm's policy attached — no TTFT samples or
        # deferral state leak into the measured run.
        eng = _engine(cfg, model, params, cfg["a_slots"], cfg["a_max_len"])
        trace, _ = _serve(eng, _goodput_workload(cfg, round_s=1e-3, slo_s=0.0))
        # deferral reshapes admission (e.g. a lone online prefill in the
        # req-bucket-1 variant the warm workload never hits) — compile every
        # variant now, not inside the measured serve
        eng.warm_serving_shapes()
        eng.overload = pol
        return eng, trace

    blind_eng, warm_trace = warmed(OverloadPolicy())
    round_s = _round_time_s(warm_trace)

    # calibration: an effectively-zero SLO makes the aware policy defer as
    # aggressively as it ever can — the measured online TTFTs are the best
    # this workload can achieve, so margin × their max is an SLO the aware
    # serve can attain and (checked below) the blind serve structurally
    # cannot (the FCFS backlog drains ahead of every online admission)
    calib, _ = warmed(SLOAwareOverloadPolicy())
    calib_trace, _ = _serve(
        calib, _goodput_workload(cfg, round_s, slo_s=1e-9)
    )
    best_ttfts = [
        r.ttft for r in calib_trace.requests
        if r.ttft_slo_s is not None and r.ttft is not None
    ]
    slo_s = cfg["slo_margin"] * max(best_ttfts)

    arms = {}
    for name, pol in (
        ("slo_blind", None),
        ("slo_aware", SLOAwareOverloadPolicy()),
    ):
        eng = blind_eng if pol is None else warmed(pol)[0]
        reqs = _goodput_workload(cfg, round_s, slo_s)
        trace, wall = _serve(eng, reqs)
        m = engine_metrics(eng, trace, wall)
        m["ttft_p95_s"] = trace.ttft_p95()
        m["makespan_s"] = trace.makespan
        arms[name] = (eng, trace, m)

    blind_ttfts = [
        r.ttft for r in arms["slo_blind"][1].requests
        if r.ttft_slo_s is not None and r.ttft is not None
    ]
    gen_blind = arms["slo_blind"][0].generated
    gen_aware = arms["slo_aware"][0].generated
    parity = gen_blind.keys() == gen_aware.keys() and all(
        gen_blind[r] == gen_aware[r] for r in gen_blind
    )
    return {
        "round_time_s": round_s,
        "ttft_slo_s": slo_s,
        "calib_best_ttft_s": max(best_ttfts),
        "blind_min_ttft_s": min(blind_ttfts),
        "token_parity": bool(parity),
        "slo_blind": arms["slo_blind"][2],
        "slo_aware": arms["slo_aware"][2],
    }


# --------------------------------------------------------------------------- #
# Arm B: preemption-by-eviction vs up-front reservation                       #
# --------------------------------------------------------------------------- #
def run_eviction_arm(cfg, model, params):
    from repro.core import GlobalQueueScheduler, LagrangianPolicy, build_clients

    from .bench_io import engine_metrics

    def reqs():
        from repro.core import Request
        return [
            Request(rid=i, n_prefill=cfg["b_prefill"], n_decode=cfg["b_decode"])
            for i in range(cfg["n_b"])
        ]

    arms = {}
    for mode in ("upfront", "ondemand"):
        eng = _engine(
            cfg, model, params, cfg["b_slots"], cfg["b_max_len"],
            page_size=cfg["b_page_size"], num_pages=cfg["b_num_pages"],
            page_reserve=mode,
        )
        r = reqs()
        eng.serve(r, build_clients(cfg["b_slots"], r, None),
                  GlobalQueueScheduler(r), LagrangianPolicy())   # warm
        r = reqs()
        clients = build_clients(cfg["b_slots"], r, None)
        t0 = time.perf_counter()
        trace = eng.serve(r, clients, GlobalQueueScheduler(r),
                          LagrangianPolicy())
        wall = time.perf_counter() - t0
        arms[mode] = (eng, trace, engine_metrics(eng, trace, wall))

    gen_up = arms["upfront"][0].generated
    gen_od = arms["ondemand"][0].generated
    parity = gen_up.keys() == gen_od.keys() and all(
        gen_up[r] == gen_od[r] for r in gen_up
    )
    return {
        "num_pages": cfg["b_num_pages"],
        "token_parity": bool(parity),
        "upfront": arms["upfront"][2],
        "ondemand": arms["ondemand"][2],
    }


# --------------------------------------------------------------------------- #
# Arm C: mid-serve replica kill                                               #
# --------------------------------------------------------------------------- #
def run_fault_arm(cfg, model, params):
    from repro.core import CostModel, LagrangianPolicy, Request
    from repro.serving.engine import EngineConfig
    from repro.serving.fleet import FaultPlan, Fleet, FleetConfig, ReplicaFault

    def reqs():
        out = []
        for i in range(cfg["n_f"]):
            d = cfg["f_decode"] + (8 if i % 2 == 0 else 0)
            out.append(Request(rid=i, n_prefill=cfg["f_prefill"], n_decode=d))
        return out

    def fleet():
        return Fleet(
            model, params,
            EngineConfig(
                n_slots=cfg["f_slots"], max_len=cfg["f_max_len"],
                prefill_seq_buckets=cfg["seq_buckets"],
                kv_layout="paged", page_size=cfg["page_size"],
                prefill_chunk=cfg["prefill_chunk"],
            ),
            FleetConfig(n_replicas=cfg["n_replicas"]),
            cost_model=CostModel(level_caps=cfg["level_caps"]),
        )

    def warmed_fleet():
        fl = fleet()
        fl.serve(reqs(), LagrangianPolicy)                       # warm
        # post-kill a survivor serves adopted work in admission shapes the
        # warm serve never produced (lone resumes land in small req-bucket
        # variants) — compile everything up front on every replica so no
        # blip lands inside the measured virtual timeline
        for eng in fl.engines:
            eng.warm_serving_shapes()
        return fl

    base_fleet = warmed_fleet()
    t0 = time.perf_counter()
    base_report = base_fleet.serve(reqs(), LagrangianPolicy)
    base_wall = time.perf_counter() - t0
    base_gen = {rid: list(t) for rid, t in base_fleet.generated.items()}

    kill_at = cfg["kill_frac"] * base_report.makespan
    fault_fleet = warmed_fleet()
    t0 = time.perf_counter()
    fault_report = fault_fleet.serve(
        reqs(), LagrangianPolicy,
        fault_plan=FaultPlan([ReplicaFault(replica=0, at_s=kill_at)]),
    )
    fault_wall = time.perf_counter() - t0
    fault_gen = {rid: list(t) for rid, t in fault_fleet.generated.items()}

    done = [r for t in fault_report.traces for r in t.requests]
    parity = fault_gen.keys() == base_gen.keys() and all(
        fault_gen[r] == base_gen[r] for r in base_gen
    )
    return {
        "kill_at_s": kill_at,
        "n_requests": cfg["n_f"],
        "completed": len(done),
        "all_done": all(r.t_done is not None for r in done),
        "exactly_once": len({r.rid for r in done}) == len(done),
        "recovered_requests": fault_fleet.recovered_requests,
        "token_parity": bool(parity),
        "base_makespan_s": base_report.makespan,
        "fault_makespan_s": fault_report.makespan,
        "base_goodput_tok_s": base_report.goodput,
        "fault_goodput_tok_s": fault_report.goodput,
        "base_wall_s": base_wall,
        "fault_wall_s": fault_wall,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (seconds, not minutes)")
    ap.add_argument("--out", default=None, help="directory for BENCH_*.json")
    args = ap.parse_args()
    cfg = SMOKE if args.smoke else FULL

    from .bench_io import emit_json

    model, params = _model_and_params(cfg)
    goodput = run_goodput_arm(cfg, model, params)
    eviction = run_eviction_arm(cfg, model, params)
    fault = run_fault_arm(cfg, model, params)

    print("name,value,unit")
    for arm in ("slo_blind", "slo_aware"):
        m = goodput[arm]
        print(f"{arm}_goodput,{m['goodput_tok_s']:.1f},tok/s")
        print(f"{arm}_throughput,{m['throughput_tok_s']:.1f},tok/s")
        print(f"{arm}_slo_attainment,{m['slo_attainment']:.3f},frac")
        print(f"{arm}_ttft_p95,{m['ttft_p95_s'] * 1e3:.1f},ms")
        print(f"{arm}_offline_deferrals,{int(m['offline_deferrals'])},count")
    print(f"goodput_token_parity,{int(goodput['token_parity'])},bool")
    print(f"ttft_slo,{goodput['ttft_slo_s'] * 1e3:.1f},ms")
    for arm in ("upfront", "ondemand"):
        m = eviction[arm]
        print(f"{arm}_peak_concurrency,{int(m['peak_concurrency'])},requests")
        print(f"{arm}_preemptions,{int(m['preemption_events'])},events")
        print(f"{arm}_throughput,{m['throughput_tok_s']:.1f},tok/s")
    print(f"eviction_token_parity,{int(eviction['token_parity'])},bool")
    print(f"fault_completed,{fault['completed']},requests")
    print(f"fault_recovered,{fault['recovered_requests']},requests")
    print(f"fault_token_parity,{int(fault['token_parity'])},bool")
    print(f"fault_makespan_ratio,"
          f"{fault['fault_makespan_s'] / fault['base_makespan_s']:.3f},x")

    payload = {"goodput": goodput, "eviction": eviction, "fault": fault}
    path = emit_json("overload", payload, smoke=args.smoke, out_dir=args.out)
    print(f"# wrote {path}")

    # ---- hard-fail gates (stable structural signals) --------------------- #
    if goodput["blind_min_ttft_s"] <= goodput["ttft_slo_s"]:
        raise SystemExit(
            "calibration failed to separate: the SLO-blind serve met an "
            "online TTFT below the calibrated SLO — grow the offline "
            "backlog so blind FCFS admission structurally misses it"
        )
    if not goodput["token_parity"]:
        raise SystemExit("goodput arm: token parity violated between policies")
    blind, aware = goodput["slo_blind"], goodput["slo_aware"]
    if not aware["goodput_tok_s"] > blind["goodput_tok_s"]:
        raise SystemExit(
            f"SLO-aware goodput {aware['goodput_tok_s']:.1f} tok/s not above "
            f"SLO-blind {blind['goodput_tok_s']:.1f} tok/s"
        )
    if not aware["slo_attainment"] > blind["slo_attainment"]:
        raise SystemExit(
            f"SLO-aware attainment {aware['slo_attainment']:.3f} not above "
            f"SLO-blind {blind['slo_attainment']:.3f}"
        )
    if not eviction["token_parity"]:
        raise SystemExit("eviction arm: token parity violated between modes")
    up, od = eviction["upfront"], eviction["ondemand"]
    if not od["peak_concurrency"] > up["peak_concurrency"]:
        raise SystemExit(
            f"on-demand peak concurrency {int(od['peak_concurrency'])} not "
            f"above up-front {int(up['peak_concurrency'])} — pool not tight "
            f"enough to exercise the reservation gap"
        )
    if not od["preemption_events"] > 0:
        raise SystemExit("eviction arm never preempted — gate is vacuous")
    if up["preemption_events"] != 0:
        raise SystemExit("up-front reservation should never need preemption")
    if fault["completed"] != fault["n_requests"] or not fault["all_done"]:
        raise SystemExit(
            f"fault arm: {fault['completed']}/{fault['n_requests']} requests "
            f"completed after the kill"
        )
    if not fault["exactly_once"]:
        raise SystemExit("fault arm: a request completed on two replicas")
    if not fault["token_parity"]:
        raise SystemExit(
            "fault arm: recovered streams diverged from the no-fault serve"
        )


if __name__ == "__main__":
    main()
