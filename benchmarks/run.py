# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys


def main() -> None:
    from . import paper_tables

    print("name,us_per_call,derived")
    failures = 0
    for bench in paper_tables.ALL_BENCHES:
        try:
            for name, us, derived in bench():
                print(f'{name},{us:.1f},"{derived}"', flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f'{bench.__name__},-1,"FAILED: {type(e).__name__}: {e}"', flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
