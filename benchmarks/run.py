# One function per paper table. Prints ``name,us_per_call,derived`` CSV and
# writes the machine-readable BENCH_paper_tables.json artifact (same schema
# as every other benchmark: bench_io.emit_json), so the perf trajectory
# tracks the paper-reproduction numbers alongside the engine benchmarks.
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="run only the fast single-simulation tables (CI budget)",
    )
    ap.add_argument("--out", default=None, help="directory for BENCH_*.json")
    args = ap.parse_args()

    from . import paper_tables
    from .bench_io import emit_json

    benches = paper_tables.ALL_BENCHES
    if args.smoke:
        benches = [
            b for b in benches
            if b.__name__ not in paper_tables.SLOW_BENCHES
        ]

    print("name,us_per_call,derived")
    failures = 0
    metrics = {}
    for bench in benches:
        try:
            for name, us, derived in bench():
                print(f'{name},{us:.1f},"{derived}"', flush=True)
                metrics[name] = {"us_per_call": us, "derived": derived}
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f'{bench.__name__},-1,"FAILED: {type(e).__name__}: {e}"', flush=True)
            metrics[bench.__name__] = {
                "us_per_call": -1.0,
                "derived": f"FAILED: {type(e).__name__}: {e}",
            }
    metrics["failures"] = failures
    path = emit_json("paper_tables", metrics, smoke=args.smoke, out_dir=args.out)
    print(f"# wrote {path}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
