"""Machine-readable benchmark output.

Every benchmark writes a ``BENCH_<name>.json`` artifact next to its stdout
CSV so the perf trajectory can be tracked per PR (CI uploads these files).
The schema is deliberately flat: a ``meta`` block (benchmark name, smoke
flag, device) plus a ``metrics`` dict of scalars — easy to diff, easy to
plot, no parser needed beyond ``json.load``.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

import jax
import numpy as np


def _decode_latency_samples(trace, burst_only: bool = False):
    """Per-token decode latency samples (inter-token gaps) from a trace.

    The honest per-token latency a decoding request experiences is the time
    between its consecutive tokens — *including* any preempting prefill
    stage that froze it in between (the alternating engine's whole cost
    lives in those gaps, not inside its decode stages). Stages tile the
    timeline, so for each (slot, rid) pair the gap between two stages that
    decoded it is the sum of everything in between: its first token of a
    stage costs ``t_end - previous t_end - (R-1)·duration/R``, the other
    R-1 fused tokens ``duration/R`` each (tokens inside a fused horizon are
    not individually timed; the host only sees the horizon boundary, which
    is the point). Mixed stages are one round; slots whose *first* token
    emitted there (``prefilled``) start their clock rather than sample it.
    ``burst_only`` keeps only stages that ran while prefill work was in
    flight — the latency slice the mixed-step path is supposed to protect.
    """
    samples = []
    last_end: Dict[tuple, float] = {}      # (cid, rid) -> t_end of last decode
    for s in trace.stages:
        if s.kind.value == "prefill":
            # a completed prefill samples token #1 — the inter-token clock
            # starts here, exactly as MIXED stages do via ``prefilled``
            # (without this the first decode gap after an alternating-mode
            # prefill would be under-reported and the two modes would not
            # be measured the same way)
            for cid, rid in s.busy.items():
                last_end[(cid, rid)] = s.t_end
            continue
        if s.kind.value not in ("decode", "mixed"):
            continue
        rounds = max(s.rounds, 1)
        per = s.duration / rounds
        take = not burst_only or s.burst
        for cid, rid in s.busy.items():
            if s.kind.value == "mixed" and cid in s.prefilled:
                last_end[(cid, rid)] = s.t_end     # token #1: clock starts
                continue
            prev = last_end.get((cid, rid))
            if take:
                first = per if prev is None else s.t_end - prev - (rounds - 1) * per
                samples.append(max(first, 0.0))
                samples.extend([per] * (rounds - 1))
            last_end[(cid, rid)] = s.t_end
    return samples


def decode_latency_percentiles(trace) -> Dict[str, float]:
    """p50/p95 per-token decode latency (seconds) from a ScheduleTrace."""
    samples = _decode_latency_samples(trace)
    if not samples:
        return {"p50_token_latency_s": 0.0, "p95_token_latency_s": 0.0}
    return {
        "p50_token_latency_s": float(np.percentile(samples, 50)),
        "p95_token_latency_s": float(np.percentile(samples, 95)),
    }


def burst_decode_latency_p95(trace) -> float:
    """p95 per-token decode latency (seconds) during prefill bursts only."""
    samples = _decode_latency_samples(trace, burst_only=True)
    return float(np.percentile(samples, 95)) if samples else 0.0


def engine_metrics(eng, trace, wall_s: float) -> Dict[str, float]:
    """The shared serving-benchmark metric set for one engine run."""
    out_tokens = sum(r.n_decode for r in trace.requests)
    m = {
        "throughput_tok_s": out_tokens / wall_s,
        "wall_s": wall_s,
        "output_tokens": out_tokens,
        # utilization over the full makespan (arrival gaps included — the
        # paper's closed-loop metric) next to the gap-excluded view; an
        # open-loop run is judged on the busy window, a closed-loop run
        # reports the two identical
        "utilization": trace.utilization,
        "busy_window_utilization": trace.busy_window_utilization,
        "idle_gap_s": trace.idle_gap_time,
        "decode_dispatches": eng.decode_dispatches,
        "dispatches_per_token": (
            eng.decode_dispatches / max(eng.decoded_tokens, 1)
        ),
        "mixed_rounds": eng.mixed_rounds,
        "prefill_stall_time_s": eng.prefill_stall_time,
        "p95_burst_token_latency_s": burst_decode_latency_p95(trace),
        # SLO view: goodput counts only output tokens of requests that met
        # their SLOs (requests with no SLO always count) — the quantity an
        # overloaded serve should protect, next to raw throughput
        "goodput_tok_s": trace.goodput,
        "slo_attainment": trace.slo_attainment,
        "slo_tracked": float(len(trace.slo_tracked_requests)),
        "preemption_events": float(eng.preemption_events),
        "peak_concurrency": float(eng.peak_concurrency),
        "offline_deferrals": float(eng.offline_deferrals),
        # recovery/migration accounting: tokens the engine re-prefilled for
        # resumed (preempted/recovered) requests, and live-migration traffic
        "recomputed_tokens": float(eng.recomputed_tokens),
        "migrated_pages_in": float(eng.migrated_pages_in),
        "migrated_pages_out": float(eng.migrated_pages_out),
        "migrations_in": float(eng.migrations_in),
        "migrations_out": float(eng.migrations_out),
        # prefix-cache accounting: prompt tokens served from cached KV pages
        # instead of being recomputed, as a count and as a fraction of the
        # workload's total prompt tokens (0.0 when the cache is off)
        "cache_hit_tokens": float(eng.cache_hit_tokens),
        "cached_token_rate": (
            eng.cache_hit_tokens
            / max(sum(r.n_prefill for r in trace.requests), 1)
        ),
    }
    m.update(decode_latency_percentiles(trace))
    if getattr(eng, "obs", None) is not None:
        # observability volume of the run (obs-enabled benches only): how
        # many span events / audit records / capacity samples the serve
        # emitted — tracked so instrumentation growth shows up in the
        # artifact diff, not just in memory profiles
        m["obs_span_events"] = float(len(eng.obs.spans.events))
        m["obs_audit_records"] = float(len(eng.obs.audit.records))
        m["obs_capacity_samples"] = float(len(eng.obs.capacity_samples))
    if eng.cfg.kv_layout == "paged":
        m["peak_kv_bytes"] = eng.slots.peak_kv_bytes()
        m["kv_capacity_bytes"] = eng.slots.kv_bytes_capacity()
        m["shared_pages_peak"] = float(eng.slots.shared_pages_peak)
    else:
        cap = eng.slots.cache["k"].nbytes + eng.slots.cache["v"].nbytes
        m["peak_kv_bytes"] = cap
        m["kv_capacity_bytes"] = cap
    return m


def fleet_recovery_metrics(report) -> Dict[str, float]:
    """Recovery/migration accounting for a fleet summary, read from the
    FleetReport meta: tokens re-prefilled by recompute-on-resume, live
    page-copy traffic, how displaced requests were recovered, and the
    worst span from a fault/drain event to full re-admission."""
    keys = (
        "recomputed_tokens", "migration_events", "migrated_pages",
        "recovered_requests", "recovered_page_copy", "recovered_recompute",
        "time_to_recover_s",
    )
    return {k: float(report.meta.get(k, 0.0)) for k in keys}


def fleet_detection_metrics(report) -> Dict[str, float]:
    """Failure-detection/fencing accounting for a fleet summary, read from
    the FleetReport meta: health-monitor transitions (suspicions, false
    positives, condemnations, gray-degrade flags), redispatches of work
    stranded on SUSPECT replicas, stale claims/exports refused by epoch
    fencing, and KV page imports rejected by checksum. All keys default to
    0.0 so fault-free serves (or fleets without a monitor) report clean
    zeros rather than missing columns."""
    keys = (
        "suspect_events", "false_suspicions", "condemned_replicas",
        "degraded_events", "redispatch_events",
        "fenced_stale_completions", "fenced_stale_exports",
        "integrity_rejections",
    )
    return {k: float(report.meta.get(k, 0.0)) for k in keys}


def run_serving_benchmark(
    cfg: Dict,
    workload_factory=None,
    scheduler_factory=None,
    policy_factory=None,
    warm_seed: int = 12,
    **engine_kwargs,
):
    """Shared serving-benchmark harness: build an engine from a config dict
    (keys: arch, spec, n_slots, max_len, seq_buckets, level_caps), warm the
    jit caches on a same-shape workload (seed 12), then time a full serve of
    the measured workload (seed 11). Returns (engine, metrics, trace).
    Keeping the protocol in one place means every benchmark measures the
    same thing.

    ``workload_factory(seed)`` / ``scheduler_factory(requests)`` /
    ``policy_factory()`` override the default GSM8K-shaped workload on a
    FCFS queue under prefill-first (e.g. Poisson arrivals through an
    ``ArrivalQueueScheduler`` in ``benchmarks/mixed_batch.py``).
    ``warm_seed=11`` warms on the measured workload itself — every jit
    shape the timed serve will hit compiles in the warm pass, which
    latency-percentile benchmarks need (one compile blip dwarfs every real
    stage).
    """
    import time

    from repro.core import (
        CostModel,
        GlobalQueueScheduler,
        PrefillFirstPolicy,
        build_clients,
    )
    from repro.data import gsm8k_like_workload
    from repro.models.layers import init_params
    from repro.models.transformer import TransformerLM
    from repro.serving.engine import Engine, EngineConfig

    if workload_factory is None:
        workload_factory = lambda seed: gsm8k_like_workload(  # noqa: E731
            cfg["spec"], seed=seed, known_lengths=True
        )
    if scheduler_factory is None:
        scheduler_factory = GlobalQueueScheduler
    if policy_factory is None:
        policy_factory = PrefillFirstPolicy

    model = TransformerLM(cfg["arch"])
    params = init_params(jax.random.key(0), model.param_defs())
    reqs = workload_factory(11)
    eng = Engine(
        model, params,
        EngineConfig(
            n_slots=cfg["n_slots"], max_len=cfg["max_len"],
            prefill_seq_buckets=cfg["seq_buckets"], **engine_kwargs,
        ),
    )
    eng.profiler.cost_model = CostModel(level_caps=cfg["level_caps"])
    clients = build_clients(cfg["n_slots"], reqs, None)
    warm = workload_factory(warm_seed)
    eng.serve(warm, build_clients(cfg["n_slots"], warm, None),
              scheduler_factory(warm), policy_factory())
    if engine_kwargs.get("kv_layout") == "paged":
        # the online refit can shift policy decisions between the warm and
        # measured serves onto a jit variant the warm pass never hit —
        # compile every variant now, not inside the timed region
        eng.warm_serving_shapes()
    t0 = time.perf_counter()
    trace = eng.serve(
        reqs, clients, scheduler_factory(reqs), policy_factory()
    )
    wall = time.perf_counter() - t0
    trace.validate()
    return eng, engine_metrics(eng, trace, wall), trace


def emit_json(
    name: str,
    metrics: Dict,
    smoke: bool = False,
    out_dir: Optional[str] = None,
) -> str:
    """Write ``BENCH_<name>.json`` (to ``out_dir``, $BENCH_OUT_DIR, or cwd)
    and return the path."""
    out_dir = out_dir or os.environ.get("BENCH_OUT_DIR") or "."
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    payload = {
        "meta": {
            "bench": name,
            "smoke": smoke,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
        },
        "metrics": metrics,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path
