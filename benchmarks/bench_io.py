"""Machine-readable benchmark output.

Every benchmark writes a ``BENCH_<name>.json`` artifact next to its stdout
CSV so the perf trajectory can be tracked per PR (CI uploads these files).
The schema is deliberately flat: a ``meta`` block (benchmark name, smoke
flag, device) plus a ``metrics`` dict of scalars — easy to diff, easy to
plot, no parser needed beyond ``json.load``.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

import jax
import numpy as np


def decode_latency_percentiles(trace) -> Dict[str, float]:
    """p50/p95 per-token decode latency (seconds) from a ScheduleTrace.

    A fused decode stage of R rounds contributes R samples of
    ``duration / R`` — the per-iteration latency every token in that stage
    experienced (tokens inside a fused horizon are not individually timed;
    the host only sees the horizon boundary, which is the point).
    """
    samples = []
    for s in trace.stages:
        if s.kind.value == "decode" and s.rounds > 0:
            samples.extend([s.duration / s.rounds] * s.rounds)
    if not samples:
        return {"p50_token_latency_s": 0.0, "p95_token_latency_s": 0.0}
    return {
        "p50_token_latency_s": float(np.percentile(samples, 50)),
        "p95_token_latency_s": float(np.percentile(samples, 95)),
    }


def engine_metrics(eng, trace, wall_s: float) -> Dict[str, float]:
    """The shared serving-benchmark metric set for one engine run."""
    out_tokens = sum(r.n_decode for r in trace.requests)
    m = {
        "throughput_tok_s": out_tokens / wall_s,
        "wall_s": wall_s,
        "output_tokens": out_tokens,
        "decode_dispatches": eng.decode_dispatches,
        "dispatches_per_token": (
            eng.decode_dispatches / max(eng.decoded_tokens, 1)
        ),
    }
    m.update(decode_latency_percentiles(trace))
    if eng.cfg.kv_layout == "paged":
        m["peak_kv_bytes"] = eng.slots.peak_kv_bytes()
        m["kv_capacity_bytes"] = eng.slots.kv_bytes_capacity()
    else:
        cap = eng.slots.cache["k"].nbytes + eng.slots.cache["v"].nbytes
        m["peak_kv_bytes"] = cap
        m["kv_capacity_bytes"] = cap
    return m


def run_serving_benchmark(cfg: Dict, **engine_kwargs):
    """Shared serving-benchmark harness: build an engine from a config dict
    (keys: arch, spec, n_slots, max_len, seq_buckets, level_caps), warm the
    jit caches on a same-shape workload (seed 12), then time a full serve of
    the measured workload (seed 11). Returns (engine, metrics). Keeping the
    protocol in one place means every benchmark measures the same thing."""
    import time

    from repro.core import (
        CostModel,
        GlobalQueueScheduler,
        PrefillFirstPolicy,
        build_clients,
    )
    from repro.data import gsm8k_like_workload
    from repro.models.layers import init_params
    from repro.models.transformer import TransformerLM
    from repro.serving.engine import Engine, EngineConfig

    model = TransformerLM(cfg["arch"])
    params = init_params(jax.random.key(0), model.param_defs())
    reqs = gsm8k_like_workload(cfg["spec"], seed=11, known_lengths=True)
    eng = Engine(
        model, params,
        EngineConfig(
            n_slots=cfg["n_slots"], max_len=cfg["max_len"],
            prefill_seq_buckets=cfg["seq_buckets"], **engine_kwargs,
        ),
    )
    eng.profiler.cost_model = CostModel(level_caps=cfg["level_caps"])
    clients = build_clients(cfg["n_slots"], reqs, None)
    warm = gsm8k_like_workload(cfg["spec"], seed=12, known_lengths=True)
    eng.serve(warm, build_clients(cfg["n_slots"], warm, None),
              GlobalQueueScheduler(warm), PrefillFirstPolicy())
    t0 = time.perf_counter()
    trace = eng.serve(
        reqs, clients, GlobalQueueScheduler(reqs), PrefillFirstPolicy()
    )
    wall = time.perf_counter() - t0
    trace.validate()
    return eng, engine_metrics(eng, trace, wall)


def emit_json(
    name: str,
    metrics: Dict,
    smoke: bool = False,
    out_dir: Optional[str] = None,
) -> str:
    """Write ``BENCH_<name>.json`` (to ``out_dir``, $BENCH_OUT_DIR, or cwd)
    and return the path."""
    out_dir = out_dir or os.environ.get("BENCH_OUT_DIR") or "."
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    payload = {
        "meta": {
            "bench": name,
            "smoke": smoke,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
        },
        "metrics": metrics,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path
