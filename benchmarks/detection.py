"""Failure-detection harness: oracle-free hang/gray-failure detection, gated.

PR 7's chaos harness told the fleet about every fault (the fault plan *was*
the detector). This harness injects faults the fleet is NOT told about —
hangs (a replica silently stops, later silently resumes) and gray degrades
(×4-slow but progressing) — and hard-gates that the heartbeat/suspicion
monitor plus epoch fencing recover exactly-once without an oracle:

  * **hang** — replica 0 stops mid-serve and stays silent ~10 makespans.
    The adaptive detector must condemn it long before it would resume, the
    fleet must finish every request exactly once with streams bit-identical
    to the fault-free serve, and the ghost's late claims must all be fenced.
  * **ablation** — the same hang against the fixed-timeout detector
    (timeout derived from the clean serve's own observed stage gaps, the
    honest way an operator would set it). The adaptive detector must beat
    it on detection latency — or, failing that, on clean-serve false
    positives — at token parity. Both detectors' clean-serve false-positive
    counts are measured directly; the adaptive one must be zero.
  * **zombie** — seeded schedules where the hang RESUMES before the serve
    ends: the condemned replica wakes and replays the work it held under
    its fenced epoch. Every seed must finish with zero double-served tokens
    (bit-identical streams, one completion per request) and a fenced
    stale-completion count > 0 — fencing, not luck of timing.
  * **gray** — a ×4 silent degrade mid-serve: the monitor must flag the
    replica *degraded* (SUSPECT, priced out of dispatch) while it keeps
    progressing, with zero condemnations and exact token parity.

Seeds for the zombie arm: ``--n-seeds N`` runs seeds 0..N-1, ``--seeds``
takes an explicit comma list, and ``REPRO_DETECTION_SEEDS`` (same syntax as
``--seeds``, or a bare count) sets the default for both. A failing seed
writes the full journal next to the JSON artifact and prints the
one-command repro.

Run:  PYTHONPATH=src python -m benchmarks.detection [--smoke] [--out DIR]
Prints ``name,value,unit`` CSV and writes BENCH_detection.json.
"""
from __future__ import annotations

import argparse
import json
import os
import random
import time

FULL = dict(
    model=dict(n_layers=2, d_model=128, n_heads=8, n_kv_heads=4, d_ff=256,
               vocab_size=512),
    n_slots=2, max_len=64, n_replicas=2,
    prefills=(10, 8, 12, 8), decodes=(16, 16, 12, 12),
    calib_prefill=4, calib_decode=8,
    hang_at_frac=0.3, hang_until_factor=10.0,
    degrade_at_frac=0.3, degrade_speed=0.25,
    n_seeds=5,
    seq_buckets=(32,), level_caps=(32, 64, 128),
    page_size=16, prefill_chunk=16,
)
SMOKE = dict(
    model=dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
               vocab_size=256),
    n_slots=2, max_len=64, n_replicas=2,
    prefills=(10, 8, 12, 8), decodes=(16, 16, 12, 12),
    calib_prefill=4, calib_decode=8,
    hang_at_frac=0.3, hang_until_factor=10.0,
    degrade_at_frac=0.3, degrade_speed=0.25,
    n_seeds=2,
    seq_buckets=(32,), level_caps=(32, 64, 128),
    page_size=16, prefill_chunk=16,
)


def _model_and_params(cfg):
    import jax

    from repro.configs.base import ArchConfig
    from repro.models.layers import init_params
    from repro.models.transformer import TransformerLM

    arch = ArchConfig(name="detection-bench", family="dense", **cfg["model"])
    model = TransformerLM(arch)
    params = init_params(jax.random.key(0), model.param_defs())
    return model, params


def _engine_cfg(cfg):
    from repro.serving.engine import EngineConfig

    return EngineConfig(
        n_slots=cfg["n_slots"], max_len=cfg["max_len"],
        prefill_seq_buckets=cfg["seq_buckets"], kv_layout="paged",
        page_size=cfg["page_size"], prefill_chunk=cfg["prefill_chunk"],
        decode_horizon=1, mixed_schedule=False,
    )


def _fleet(cfg, model, params, health):
    from repro.core import CostModel
    from repro.serving.fleet import Fleet, FleetConfig

    return Fleet(
        model, params, _engine_cfg(cfg),
        FleetConfig(
            n_replicas=cfg["n_replicas"], assign="round_robin",
            dispatch="round_robin", work_stealing=False, health=health,
        ),
        cost_model=CostModel(level_caps=cfg["level_caps"]),
    )


def _requests(cfg):
    from repro.core import Request

    return [
        Request(rid=i, n_prefill=p, n_decode=d)
        for i, (p, d) in enumerate(zip(cfg["prefills"], cfg["decodes"]))
    ]


def _calib_requests(cfg):
    from repro.core import Request

    # prefill totals differ from the measured set so each replica's
    # profiler sees >= 2 distinct prefill sizes and reaches its first full
    # refit (a replica batches all its offline prompts into one stage)
    return [
        Request(rid=90 + i, n_prefill=cfg["calib_prefill"],
                n_decode=cfg["calib_decode"])
        for i in range(len(cfg["prefills"]))
    ]


def _fit_and_reference(cfg, model, params, health):
    """Warm + calibrate a fleet until every replica has a full cost-model
    fit, then serve the measured workload once for the fitted reference.
    Returns (fleet, clean_report, ref_gen)."""
    from repro.core import LagrangianPolicy

    fleet = _fleet(cfg, model, params, health)
    fleet.serve(_calib_requests(cfg), LagrangianPolicy)    # warm/compile
    fleet.serve(_requests(cfg), LagrangianPolicy)
    if not all(e.profiler.full_fits > 0 for e in fleet.engines):
        raise SystemExit("calibration never reached a full cost-model fit")
    rep = fleet.serve(_requests(cfg), LagrangianPolicy)
    ref_gen = {rid: list(t) for rid, t in fleet.generated.items()}
    return fleet, rep, ref_gen


def _check_consistency(fleet):
    for i, eng in enumerate(fleet.engines):
        eng.slots.allocator.check_consistency()
        eng.slots.check_block_table_mirror()
        if eng.slots.allocator.num_used != 0:
            raise AssertionError(f"replica {i}: orphaned pages after serve")


def _condemned_at(fleet):
    from repro.serving.health import CONDEMNED

    return next(
        (tr["at_s"] for tr in fleet.monitor.transitions
         if tr["state"] == CONDEMNED),
        None,
    )


# --------------------------------------------------------------------------- #
# Arm 1 + 2: mid-serve hang, adaptive detector vs fixed-timeout ablation      #
# --------------------------------------------------------------------------- #
def run_hang_arm(cfg, model, params, health, label):
    from repro.core import LagrangianPolicy
    from repro.serving.fleet import FaultPlan, ReplicaFault

    from .bench_io import fleet_recovery_metrics

    fleet, clean, ref_gen = _fit_and_reference(cfg, model, params, health)
    mk = clean.makespan
    clean_false = clean.meta["suspect_events"]

    at_s = cfg["hang_at_frac"] * mk
    until_s = cfg["hang_until_factor"] * mk
    t0 = time.perf_counter()
    rep = fleet.serve(
        _requests(cfg), LagrangianPolicy,
        fault_plan=FaultPlan([ReplicaFault(
            replica=0, at_s=at_s, kind="hang", until_s=until_s,
        )]),
    )
    wall = time.perf_counter() - t0
    rep.validate()
    _check_consistency(fleet)
    applied = next(
        e["applied_at_s"] for e in fleet.injected_log if e["kind"] == "hang"
    )
    condemned_at = _condemned_at(fleet)
    done = [r for t in rep.traces for r in t.requests]
    return {
        "detector": label,
        "makespan_clean_s": mk,
        "makespan_s": rep.makespan,
        "hang_at_s": applied,
        "hang_until_s": until_s,
        "condemned": condemned_at is not None,
        "detection_latency_s": (
            condemned_at - applied if condemned_at is not None else None
        ),
        "clean_false_suspicions": clean_false,
        "completed": len(done),
        "exactly_once": len({r.rid for r in done}) == len(done),
        "token_parity": (
            {r: list(t) for r, t in fleet.generated.items()} == ref_gen
        ),
        "fenced_stale_completions": rep.meta.get(
            "fenced_stale_completions", 0.0
        ),
        "epoch_bumped": fleet.epochs[0] >= 1,
        "wall_s": wall,
        **fleet_recovery_metrics(rep),
    }


def _derive_fixed_timeout(cfg, model, params):
    """The honest fixed timeout an operator would configure: 3x the largest
    inter-beat gap the clean fitted serve actually exhibited."""
    from repro.serving.health import HealthConfig

    fleet, _, _ = _fit_and_reference(
        cfg, model, params, HealthConfig()
    )
    max_gap = max(
        (g for r in fleet.monitor.replicas for g in r.gaps), default=0.0
    )
    if max_gap <= 0.0:
        raise SystemExit("calibration serve produced no heartbeat gaps")
    return 3.0 * max_gap


# --------------------------------------------------------------------------- #
# Arm 3: seeded zombie schedules (condemn, then the hang resumes)             #
# --------------------------------------------------------------------------- #
def run_zombie_seed(cfg, model, params, seed):
    from repro.core import LagrangianPolicy
    from repro.serving.fleet import FaultPlan, ReplicaFault
    from repro.serving.health import HealthConfig

    rng = random.Random(seed)
    fleet, clean, ref_gen = _fit_and_reference(
        cfg, model, params, HealthConfig()
    )
    mk = clean.makespan
    at_s = rng.uniform(0.25, 0.45) * mk
    until_s = rng.uniform(0.8, 0.95) * mk
    journal = {
        "seed": seed, "replica": rng.randrange(cfg["n_replicas"]),
        "at_s": at_s, "until_s": until_s, "makespan_clean_s": mk,
        "violation": None,
    }
    try:
        rep = fleet.serve(
            _requests(cfg), LagrangianPolicy,
            fault_plan=FaultPlan([ReplicaFault(
                replica=journal["replica"], at_s=at_s, kind="hang",
                until_s=until_s,
            )]),
        )
        rep.validate()
        _check_consistency(fleet)
        condemned_at = _condemned_at(fleet)
        journal["condemned_at_s"] = condemned_at
        journal["fenced"] = rep.meta.get("fenced_stale_completions", 0.0)
        journal["fenced_reasons"] = sorted(
            {e["reason"] for e in fleet.fenced_log}
        )
        kinds = [e["kind"] for e in fleet.injected_log]
        journal["woke"] = "hang_end" in kinds
        done = [r for t in rep.traces for r in t.requests]
        if condemned_at is None:
            raise AssertionError("hang never condemned")
        if condemned_at >= until_s:
            raise AssertionError(
                f"condemned at {condemned_at:.4f}s, after the wake-up at "
                f"{until_s:.4f}s — the schedule exercised no zombie"
            )
        if journal["fenced"] <= 0:
            raise AssertionError("zombie claims were never fenced")
        if len(done) != len(ref_gen) or len({r.rid for r in done}) != len(done):
            raise AssertionError(
                f"{len(done)} completions for {len(ref_gen)} requests"
            )
        gen = {rid: list(t) for rid, t in fleet.generated.items()}
        if gen != ref_gen:
            bad = sorted(r for r in ref_gen if gen.get(r) != ref_gen[r])
            raise AssertionError(
                f"double-serve or divergence: streams differ for rids {bad}"
            )
    except (AssertionError, RuntimeError, SystemExit) as e:
        journal["violation"] = str(e)
        return False, journal
    return True, journal


# --------------------------------------------------------------------------- #
# Arm 4: x4 gray degrade, flagged while progressing                           #
# --------------------------------------------------------------------------- #
def run_gray_arm(cfg, model, params):
    from repro.core import LagrangianPolicy
    from repro.serving.fleet import FaultPlan, ReplicaFault
    from repro.serving.health import SUSPECT, HealthConfig

    fleet, clean, ref_gen = _fit_and_reference(
        cfg, model, params, HealthConfig()
    )
    mk = clean.makespan
    rep = fleet.serve(
        _requests(cfg), LagrangianPolicy,
        fault_plan=FaultPlan([ReplicaFault(
            replica=0, at_s=cfg["degrade_at_frac"] * mk, kind="degrade",
            speed_factor=cfg["degrade_speed"],
        )]),
    )
    rep.validate()
    _check_consistency(fleet)
    h = fleet.monitor.replicas[0]
    return {
        "degraded_events": rep.meta["degraded_events"],
        "flagged_suspect": fleet.monitor.state(0) == SUSPECT,
        "suspect_reason": h.suspect_reason,
        "slowdown_level": h.slowdown_level,
        "slowdown_baseline": h.slowdown_baseline,
        "condemned_replicas": rep.meta["condemned_replicas"],
        "survivor_false_suspicions": rep.meta["false_suspicions"],
        "token_parity": (
            {r: list(t) for r, t in fleet.generated.items()} == ref_gen
        ),
        "makespan_s": rep.makespan,
    }


def _parse_seeds(args, cfg):
    """Seed list: --seeds wins, then --n-seeds, then REPRO_DETECTION_SEEDS
    (a comma list or a bare count), then the config default."""
    if args.seeds:
        return [int(s) for s in args.seeds.split(",") if s.strip()]
    if args.n_seeds is not None:
        return list(range(args.n_seeds))
    env = os.environ.get("REPRO_DETECTION_SEEDS", "").strip()
    if env:
        if "," in env:
            return [int(s) for s in env.split(",") if s.strip()]
        return list(range(int(env)))
    return list(range(cfg["n_seeds"]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (seconds, not minutes)")
    ap.add_argument("--out", default=None, help="directory for BENCH_*.json")
    ap.add_argument("--n-seeds", type=int, default=None,
                    help="zombie arm: run seeds 0..N-1")
    ap.add_argument("--seeds", default=None,
                    help="zombie arm: explicit comma-separated seed list")
    args = ap.parse_args()
    cfg = SMOKE if args.smoke else FULL
    seeds = _parse_seeds(args, cfg)

    from .bench_io import emit_json

    from repro.serving.health import HealthConfig

    model, params = _model_and_params(cfg)

    fixed_timeout = _derive_fixed_timeout(cfg, model, params)
    adaptive = run_hang_arm(
        cfg, model, params, HealthConfig(), "adaptive"
    )
    fixed = run_hang_arm(
        cfg, model, params,
        HealthConfig(detector="fixed", fixed_timeout_s=fixed_timeout),
        "fixed",
    )

    journals, failed = [], []
    t0 = time.perf_counter()
    for seed in seeds:
        ok, journal = run_zombie_seed(cfg, model, params, seed)
        journals.append(journal)
        if not ok:
            failed.append(seed)
    zombie_wall = time.perf_counter() - t0
    if failed:
        out_dir = args.out or "."
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, "BENCH_detection_journal.json")
        with open(path, "w") as fh:
            json.dump(journals, fh, indent=2)
        raise SystemExit(
            f"zombie arm: seeds {failed} violated invariants — journal "
            f"written to {path}; repro with: PYTHONPATH=src python -m "
            f"benchmarks.detection{' --smoke' if args.smoke else ''} "
            f"--seeds {','.join(str(s) for s in failed)}"
        )
    zombie = {
        "n_schedules": len(seeds),
        "seeds": list(seeds),
        "all_passed": True,
        "fenced_total": sum(j["fenced"] for j in journals),
        "woke_mid_serve": sum(1 for j in journals if j["woke"]),
        "wall_s": zombie_wall,
    }
    gray = run_gray_arm(cfg, model, params)

    print("name,value,unit")
    print(f"fixed_timeout_derived,{fixed_timeout * 1e3:.3f},ms")
    for arm in (adaptive, fixed):
        p = arm["detector"]
        lat = arm["detection_latency_s"]
        print(f"{p}_condemned,{int(arm['condemned'])},bool")
        print(f"{p}_detection_latency,"
              f"{(lat * 1e3 if lat is not None else -1.0):.3f},ms")
        print(f"{p}_clean_false_suspicions,"
              f"{int(arm['clean_false_suspicions'])},events")
        print(f"{p}_token_parity,{int(arm['token_parity'])},bool")
        print(f"{p}_fenced,{int(arm['fenced_stale_completions'])},claims")
    print(f"zombie_schedules,{zombie['n_schedules']},runs")
    print(f"zombie_fenced_total,{int(zombie['fenced_total'])},claims")
    print(f"gray_degraded_events,{int(gray['degraded_events'])},events")
    print(f"gray_flagged_suspect,{int(gray['flagged_suspect'])},bool")
    print(f"gray_token_parity,{int(gray['token_parity'])},bool")

    payload = {
        "fixed_timeout_derived_s": fixed_timeout,
        "hang_adaptive": adaptive,
        "hang_fixed": fixed,
        "zombie": zombie,
        "gray": gray,
    }
    path = emit_json("detection", payload, smoke=args.smoke, out_dir=args.out)
    print(f"# wrote {path}")

    # ---- hard-fail gates ------------------------------------------------- #
    # (a) the hang is detected without an oracle and served exactly once
    if not adaptive["condemned"]:
        raise SystemExit("adaptive detector never condemned the hung replica")
    if adaptive["detection_latency_s"] >= (
        adaptive["hang_until_s"] - adaptive["hang_at_s"]
    ):
        raise SystemExit("hang detected only after it would have resumed")
    if not adaptive["epoch_bumped"]:
        raise SystemExit("condemnation did not bump the fencing epoch")
    if adaptive["fenced_stale_completions"] <= 0:
        raise SystemExit("the condemned replica's stale claims never fenced")
    if not (adaptive["exactly_once"] and adaptive["token_parity"]):
        raise SystemExit("hang arm: not exactly-once / streams diverged")
    if adaptive["clean_false_suspicions"] != 0:
        raise SystemExit(
            f"adaptive detector false-suspected "
            f"{int(adaptive['clean_false_suspicions'])} times on a clean serve"
        )
    # (b) adaptive beats the fixed-timeout ablation at token parity
    if not (fixed["exactly_once"] and fixed["token_parity"]):
        raise SystemExit("fixed arm: not exactly-once / streams diverged")
    adaptive_wins_latency = (
        fixed["detection_latency_s"] is None
        or (adaptive["detection_latency_s"]
            < fixed["detection_latency_s"])
    )
    adaptive_wins_fp = (
        adaptive["clean_false_suspicions"] < fixed["clean_false_suspicions"]
    )
    if not (adaptive_wins_latency or adaptive_wins_fp):
        raise SystemExit(
            f"adaptive detector beat fixed on neither detection latency "
            f"({adaptive['detection_latency_s']:.5f}s vs "
            f"{fixed['detection_latency_s']:.5f}s) nor clean-serve false "
            f"positives ({int(adaptive['clean_false_suspicions'])} vs "
            f"{int(fixed['clean_false_suspicions'])})"
        )
    # (c) zombie schedules: fenced > 0, zero double-serve — gated per seed
    if not zombie["all_passed"]:
        raise SystemExit("zombie schedules failed")
    if zombie["woke_mid_serve"] != zombie["n_schedules"]:
        raise SystemExit(
            f"only {zombie['woke_mid_serve']}/{zombie['n_schedules']} "
            f"zombies woke mid-serve — the schedule is not testing the fence"
        )
    # (d) the x4 gray failure is flagged while progressing
    if gray["degraded_events"] < 1:
        raise SystemExit("x4 degrade never flagged degraded")
    if not gray["flagged_suspect"] or gray["suspect_reason"] != "degraded":
        raise SystemExit("degraded replica not held SUSPECT")
    if gray["condemned_replicas"] != 0:
        raise SystemExit("gray degrade must not condemn a progressing replica")
    if gray["survivor_false_suspicions"] != 0:
        raise SystemExit("gray arm produced false suspicions")
    if not gray["token_parity"]:
        raise SystemExit("gray arm: streams diverged")
    print("# all detection gates passed")


if __name__ == "__main__":
    main()
