"""Fused multi-step decode vs the per-token baseline.

Sweeps the fixed decode horizon K ∈ {1, 2, 4, 8, 16} over a mixed
prompt/decode workload (short chatty prompts next to longer documents,
decode-heavy outputs — the regime where dispatch overhead, not FLOPs, bounds
decode throughput) and measures what fusing K iterations into one on-device
loop buys:

  * throughput — output tokens / s of engine wall-clock;
  * dispatches per decoded token — the quantity the subsystem minimizes
    (K=1 pays one host↔device round trip per token; K=8 pays ⌈1/8⌉);
  * p50/p95 per-token decode latency;
  * exact token parity against the K=1 baseline (fusion must never change
    results — it only changes how often the host gets to look).

Run:  PYTHONPATH=src python -m benchmarks.decode_fusion [--smoke] [--out DIR]
Prints ``name,value,unit`` CSV and writes BENCH_decode_fusion.json.
"""
from __future__ import annotations

import argparse

from repro.configs.base import ArchConfig
from repro.data import WorkloadSpec

from .bench_io import emit_json, run_serving_benchmark

HORIZONS = (1, 2, 4, 8, 16)

FULL = dict(
    arch=ArchConfig(
        name="bench", family="dense", n_layers=2, d_model=128, n_heads=8,
        n_kv_heads=4, d_ff=256, vocab_size=512,
    ),
    spec=WorkloadSpec(
        n_requests=24, input_mean=40, input_std=25, output_mean=48,
        output_std=20, output_max=80, input_max=96,
    ),
    n_slots=8, max_len=192, seq_buckets=(32, 64, 96),
    level_caps=(64, 128, 256),
)
SMOKE = dict(
    arch=ArchConfig(
        name="bench-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256,
    ),
    spec=WorkloadSpec(
        n_requests=8, input_mean=14, input_std=6, output_mean=20,
        output_std=8, output_max=28, input_max=24,
    ),
    n_slots=4, max_len=64, seq_buckets=(32,),
    level_caps=(32, 64, 128),
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (seconds, not minutes)")
    ap.add_argument("--out", default=None, help="directory for BENCH_*.json")
    args = ap.parse_args()
    cfg = SMOKE if args.smoke else FULL

    results = {}
    streams = {}
    for k in HORIZONS:
        eng, m, _ = run_serving_benchmark(cfg, decode_horizon=k)
        results[k] = m
        streams[k] = eng.generated

    base = streams[HORIZONS[0]]
    parity = all(
        streams[k].keys() == base.keys()
        and all(streams[k][r] == base[r] for r in base)
        for k in HORIZONS[1:]
    )

    print("name,value,unit")
    for k in HORIZONS:
        m = results[k]
        print(f"k{k}_throughput,{m['throughput_tok_s']:.1f},tok/s")
        print(f"k{k}_dispatches_per_token,{m['dispatches_per_token']:.4f},1/tok")
        print(f"k{k}_p50_token_latency,{m['p50_token_latency_s'] * 1e3:.3f},ms")
        print(f"k{k}_p95_token_latency,{m['p95_token_latency_s'] * 1e3:.3f},ms")
    print(f"token_parity,{int(parity)},bool")
    speedup = results[8]["throughput_tok_s"] / results[1]["throughput_tok_s"]
    print(f"k8_vs_k1_speedup,{speedup:.3f},x")

    payload = {f"k{k}": results[k] for k in HORIZONS}
    payload["token_parity"] = bool(parity)
    payload["k8_vs_k1_speedup"] = speedup
    path = emit_json("decode_fusion", payload, smoke=args.smoke, out_dir=args.out)
    print(f"# wrote {path}")
    if not parity:
        raise SystemExit("token parity violated between horizons")


if __name__ == "__main__":
    main()
