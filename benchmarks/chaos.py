"""Chaos harness: randomized fault/drain/migration schedules, hard-checked.

Live KV migration by page-copy turns a replica's mid-request state into a
portable checkpoint. This harness is the proof it is *safe to fire at any
moment*: three arms, each a hard-fail structural gate (stable on CPU —
wall-clock magnitudes are reported, never asserted):

  * **drain** — the page-copy value claim, at a deterministic instant
    (replica 0 mid-decode, survivor idle): a graceful drain must complete
    every request exactly once with ZERO recomputed tokens and streams
    bit-identical to the fault-free serve; a hard kill at the same instant
    must re-pay the full generated prefix (recomputed tokens > 0). The
    gate: page-copy strictly beats recompute on tokens re-paid.
  * **cache drain** — the same drain instant with the prefix cache on, so
    the drained replica's slots sit on refcount-shared pages: completion
    must stay exactly-once with zero recompute and streams bit-identical
    both to the fault-free *cached* serve and to the cache-off serve, and
    every replica must end refcount-clean (pages in use == index holds,
    clearing the index empties the pool).
  * **rebalance** — in-flight rebalancing: a long request decoding on a
    4x-slow replica with the fast replica drained. Queued-only stealing
    has nothing to take; extending the steal gate to RUNNING slots
    (``FleetConfig.steal_running``) must strictly improve the fleet
    makespan at exact token parity and zero recompute.
  * **chaos** — N seeded schedules against a 3-replica fleet with the
    health monitor enabled: random kills (hard and soft), drains, slow
    faults, *undeclared* hangs and gray degrades (the fleet is never told —
    detection is the heartbeat monitor's job), and random mid-serve
    ``migrate_slot`` probes. Every schedule must preserve exactly-once
    completion, bit-identical streams vs the fault-free serve, allocator
    consistency and host<->device block-table agreement on every replica,
    no orphaned pages, and monotone per-replica virtual clocks. A failing
    seed writes its full event journal next to the JSON artifact and
    hard-fails naming the seed with its one-command repro.

Seeds: ``--n-seeds N`` runs seeds 0..N-1, ``--seeds`` takes an explicit
comma list, and ``REPRO_CHAOS_SEEDS`` (same syntax, or a bare count) sets
the default for both.

Run:  PYTHONPATH=src python -m benchmarks.chaos [--smoke] [--out DIR]
Prints ``name,value,unit`` CSV and writes BENCH_chaos.json.
"""
from __future__ import annotations

import argparse
import json
import os
import random
import time

FULL = dict(
    model=dict(n_layers=2, d_model=128, n_heads=8, n_kv_heads=4, d_ff=256,
               vocab_size=512),
    # drain/rebalance arms: 2 replicas, 2 slots
    d_slots=2, d_max_len=64,
    # chaos arm: 3 replicas so two fault events can fire per schedule
    n_replicas=3, c_slots=2, c_max_len=96,
    n_c=12, c_prefill_short=10, c_prefill_long=40, c_decode=16,
    n_seeds=20, max_events=2, migration_probes=3,
    seq_buckets=(32,), level_caps=(32, 64, 128),
    page_size=16, prefill_chunk=16,
)
SMOKE = dict(
    model=dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
               vocab_size=256),
    d_slots=2, d_max_len=64,
    n_replicas=3, c_slots=2, c_max_len=96,
    n_c=9, c_prefill_short=10, c_prefill_long=40, c_decode=10,
    n_seeds=6, max_events=2, migration_probes=2,
    seq_buckets=(32,), level_caps=(32, 64, 128),
    page_size=16, prefill_chunk=16,
)


def _model_and_params(cfg):
    import jax

    from repro.configs.base import ArchConfig
    from repro.models.layers import init_params
    from repro.models.transformer import TransformerLM

    arch = ArchConfig(name="chaos-bench", family="dense", **cfg["model"])
    model = TransformerLM(arch)
    params = init_params(jax.random.key(0), model.param_defs())
    return model, params


def _engine_cfg(cfg, n_slots, max_len, **engine_kw):
    from repro.serving.engine import EngineConfig

    return EngineConfig(
        n_slots=n_slots, max_len=max_len,
        prefill_seq_buckets=cfg["seq_buckets"], kv_layout="paged",
        page_size=cfg["page_size"], prefill_chunk=cfg["prefill_chunk"],
        decode_horizon=1, mixed_schedule=False, **engine_kw,
    )


def _fleet(cfg, model, params, n_slots, max_len, specs=None, engine_kw=None,
           **fc_kw):
    from repro.core import CostModel
    from repro.serving.fleet import Fleet, FleetConfig

    fc_kw.setdefault("n_replicas", 2)
    fc_kw.setdefault("assign", "round_robin")
    fc_kw.setdefault("dispatch", "round_robin")
    fc_kw.setdefault("work_stealing", False)
    return Fleet(
        model, params, _engine_cfg(cfg, n_slots, max_len, **(engine_kw or {})),
        FleetConfig(**fc_kw),
        cost_model=CostModel(level_caps=cfg["level_caps"]),
        replica_specs=specs,
    )


def _check_consistency(fleet):
    """Allocator + block-table invariants on every replica; raises on any
    orphaned page or host/device divergence."""
    for i, eng in enumerate(fleet.engines):
        eng.slots.allocator.check_consistency()
        eng.slots.check_block_table_mirror()
        if eng.slots.allocator.num_used != 0:
            raise AssertionError(
                f"replica {i}: {eng.slots.allocator.num_used} orphaned "
                f"pages after serve"
            )


def _check_cache_consistency(fleet):
    """The cache-enabled variant: after a serve the prefix index legitimately
    holds pages, so 'no orphans' becomes 'every allocated page is an index
    hold, refcounts agree, and dropping the index empties the pool'. The
    index is cleared as the final step, so a fleet checked here starts the
    next serve cold."""
    for i, eng in enumerate(fleet.engines):
        eng.slots.allocator.check_consistency()
        eng.slots.check_block_table_mirror()
        eng.slots.check_refcounts()
        held = len(eng.slots.prefix_index.held_pages())
        used = eng.slots.allocator.num_used
        if used != held:
            raise AssertionError(
                f"replica {i}: {used} pages in use but {held} cache holds "
                f"after serve (leaked pages)"
            )
        eng.slots.prefix_index.clear()
        if eng.slots.allocator.num_used != 0:
            raise AssertionError(
                f"replica {i}: {eng.slots.allocator.num_used} pages still "
                f"in use after clearing the prefix index"
            )


# --------------------------------------------------------------------------- #
# Arm 1: graceful drain (page-copy) vs hard kill (recompute)                  #
# --------------------------------------------------------------------------- #
def _drain_requests():
    from repro.core import Request

    out = []
    for rid in range(6):
        if rid % 2 == 0:
            out.append(Request(rid=rid, n_prefill=10, n_decode=20))
        else:
            out.append(Request(rid=rid, n_prefill=8, n_decode=2))
    return out


def _step_until_survivor_idle(fleet, min_emitted):
    while True:
        e0, e1 = fleet.engines
        ready = [
            s for s in e0.slots.active_slots
            if e0.slots.emitted[s] >= min_emitted
        ]
        if (ready and not e1.slots.active_slots and not e1._chunking
                and not e1._sv.scheduler.queued):
            return True
        if not fleet.step():
            return False


def run_drain_arm(cfg, model, params):
    from repro.core import LagrangianPolicy

    from .bench_io import fleet_recovery_metrics

    base = _fleet(cfg, model, params, cfg["d_slots"], cfg["d_max_len"])
    base.warm_serving_shapes()
    base.serve(_drain_requests(), LagrangianPolicy)        # warm
    base.serve(_drain_requests(), LagrangianPolicy)
    ref_gen = {rid: list(t) for rid, t in base.generated.items()}

    out = {"n_requests": len(_drain_requests())}
    for mode, readable in (("drain", None), ("hard_kill", False)):
        fleet = _fleet(cfg, model, params, cfg["d_slots"], cfg["d_max_len"])
        fleet.serve(_drain_requests(), LagrangianPolicy)   # warm
        fleet.begin_serve(_drain_requests(), LagrangianPolicy)
        if not _step_until_survivor_idle(fleet, min_emitted=2):
            raise SystemExit(f"{mode}: never reached the injection state")
        t0 = time.perf_counter()
        if mode == "drain":
            fleet.drain_replica(0)
        else:
            fleet._kill_replica(0, fleet.engines[0].clock,
                                pool_readable=readable)
        while fleet.step():
            pass
        wall = time.perf_counter() - t0
        report = fleet.finish_serve()
        report.validate()
        _check_consistency(fleet)
        done = [r for t in report.traces for r in t.requests]
        out[mode] = {
            "completed": len(done),
            "exactly_once": len({r.rid for r in done}) == len(done),
            "token_parity": fleet.generated == ref_gen,
            "makespan_s": report.makespan,
            "post_event_wall_s": wall,
            **fleet_recovery_metrics(report),
        }
    return out


# --------------------------------------------------------------------------- #
# Arm 1b: drain with the prefix cache enabled (shared pages in flight)        #
# --------------------------------------------------------------------------- #
def _cache_requests(cfg):
    from repro.core import Request

    # round-robin assign puts evens on replica 0, odds on replica 1; each
    # parity class is one prefix group, so every replica serves prompts
    # sharing a 24-token template (1 full page + a COW'd partial at
    # page_size 16). Evens decode long so replica 0 is still mid-decode —
    # holding SHARED pages — when replica 1 goes idle and the drain fires.
    out = []
    for rid in range(8):
        long_side = rid % 2 == 0
        out.append(Request(
            rid=rid,
            n_prefill=40 if long_side else 26,
            n_decode=20 if long_side else 3,
            prefix_group=rid % 2, prefix_len=24,
        ))
    return out


def run_cache_arm(cfg, model, params):
    from repro.core import LagrangianPolicy

    from .bench_io import fleet_recovery_metrics

    kw = dict(engine_kw=dict(prefix_cache=True))
    # fault-free reference, cache ON (second serve runs against a warm index)
    ref = _fleet(cfg, model, params, cfg["d_slots"], cfg["d_max_len"], **kw)
    ref.warm_serving_shapes()
    ref.serve(_cache_requests(cfg), LagrangianPolicy)      # warm
    ref.serve(_cache_requests(cfg), LagrangianPolicy)
    ref_gen = {rid: list(t) for rid, t in ref.generated.items()}
    ref_hits = sum(e.cache_hit_tokens for e in ref.engines)
    _check_cache_consistency(ref)

    # cache OFF on the same workload: caching must not change a single token
    base = _fleet(cfg, model, params, cfg["d_slots"], cfg["d_max_len"])
    base.serve(_cache_requests(cfg), LagrangianPolicy)     # warm
    base.serve(_cache_requests(cfg), LagrangianPolicy)
    off_parity = base.generated == ref_gen
    _check_consistency(base)

    # the event: drain replica 0 while its slots decode on shared pages
    fleet = _fleet(cfg, model, params, cfg["d_slots"], cfg["d_max_len"], **kw)
    fleet.serve(_cache_requests(cfg), LagrangianPolicy)    # warm
    fleet.begin_serve(_cache_requests(cfg), LagrangianPolicy)
    if not _step_until_survivor_idle(fleet, min_emitted=2):
        raise SystemExit("cache drain: never reached the injection state")
    fleet.drain_replica(0)
    while fleet.step():
        pass
    report = fleet.finish_serve()
    report.validate()
    _check_cache_consistency(fleet)
    done = [r for t in report.traces for r in t.requests]
    return {
        "n_requests": len(_cache_requests(cfg)),
        "completed": len(done),
        "exactly_once": len({r.rid for r in done}) == len(done),
        "token_parity": fleet.generated == ref_gen,
        "off_on_parity": off_parity,
        "ref_cache_hit_tokens": float(ref_hits),
        "drain_cache_hit_tokens": float(
            sum(e.cache_hit_tokens for e in fleet.engines)
        ),
        "makespan_s": report.makespan,
        **fleet_recovery_metrics(report),
    }


# --------------------------------------------------------------------------- #
# Arm 2: in-flight rebalancing (running-slot steal)                           #
# --------------------------------------------------------------------------- #
def run_rebalance_arm(cfg, model, params):
    from repro.core import LagrangianPolicy, Request
    from repro.serving.fleet import ReplicaSpec

    def requests():
        # odd rid → slow replica under round-robin: the straggler decode
        return [
            Request(rid=0, n_prefill=8, n_decode=4),
            Request(rid=1, n_prefill=10, n_decode=32),
            Request(rid=2, n_prefill=8, n_decode=4),
        ]

    specs = [ReplicaSpec(speed_factor=1.0), ReplicaSpec(speed_factor=0.25)]
    out = {}
    for running in (True, False):
        fleet = _fleet(
            cfg, model, params, cfg["d_slots"], cfg["d_max_len"],
            specs=specs, work_stealing=True, steal_running=running,
        )
        fleet.serve(requests(), LagrangianPolicy)          # warm
        t0 = time.perf_counter()
        report = fleet.serve(requests(), LagrangianPolicy)
        wall = time.perf_counter() - t0
        report.validate()
        _check_consistency(fleet)
        key = "running_steal" if running else "queued_only"
        out[key] = {
            "makespan_s": report.makespan,
            "migration_events": fleet.migration_events,
            "recomputed_tokens": report.meta["recomputed_tokens"],
            "generated": {r: list(t) for r, t in fleet.generated.items()},
            "wall_s": wall,
        }
    on, off = out["running_steal"], out["queued_only"]
    out["token_parity"] = on.pop("generated") == off.pop("generated")
    return out


# --------------------------------------------------------------------------- #
# Arm 3: seeded chaos schedules                                               #
# --------------------------------------------------------------------------- #
def _chaos_requests(cfg):
    from repro.core import Request

    out = []
    for rid in range(cfg["n_c"]):
        # every third prompt is long enough to chunk, so schedules can
        # catch requests BETWEEN prefill chunks, not just mid-decode
        n_pre = (cfg["c_prefill_long"] if rid % 3 == 2
                 else cfg["c_prefill_short"])
        out.append(Request(
            rid=rid, n_prefill=n_pre,
            n_decode=cfg["c_decode"] + 3 * (rid % 4),
        ))
    return out


def _chaos_schedule(cfg, rng, base_makespan):
    """A random fault plan: up to max_events events at random fractions of
    the fault-free makespan, never retiring more than n_replicas - 1
    replicas. Declared kinds (kill/drain/slow) tell the fleet; undeclared
    kinds (hang/degrade) only feed the injection layer — the health
    monitor has to notice them from heartbeats alone. A hung replica that
    gets condemned retires at runtime, so hangs count against the retire
    budget too (conservatively — a short hang may wake first)."""
    from repro.serving.fleet import ReplicaFault

    events = []
    retired = set()
    for _ in range(rng.randint(1, cfg["max_events"])):
        kind = rng.choice(
            ["kill", "soft_kill", "drain", "slow", "hang", "degrade"]
        )
        at = rng.uniform(0.05, 0.8) * base_makespan
        replica = rng.randrange(cfg["n_replicas"])
        if kind in ("kill", "soft_kill", "drain", "hang"):
            if replica in retired or len(retired) + 1 >= cfg["n_replicas"]:
                continue
            retired.add(replica)
            if kind == "hang":
                events.append(ReplicaFault(
                    replica=replica, at_s=at, kind="hang",
                    until_s=at + rng.uniform(0.5, 3.0) * base_makespan,
                ))
            else:
                events.append(ReplicaFault(
                    replica=replica, at_s=at,
                    kind="drain" if kind == "drain" else "kill",
                    pool_readable=(kind == "soft_kill"),
                ))
        elif kind == "degrade":
            events.append(ReplicaFault(
                replica=replica, at_s=at, kind="degrade",
                speed_factor=rng.uniform(0.2, 0.6),
            ))
        else:
            events.append(ReplicaFault(
                replica=replica, at_s=at, kind="slow",
                speed_factor=rng.uniform(0.3, 0.8),
            ))
    return events


def _run_one_schedule(cfg, model, params, seed, ref_gen, base_makespan):
    """One seeded chaos serve. Returns (ok, journal); journal records the
    schedule, every migration probe, and the first violated invariant."""
    from repro.core import LagrangianPolicy
    from repro.serving.fleet import FaultPlan

    from repro.serving.health import HealthConfig

    rng = random.Random(seed)
    events = _chaos_schedule(cfg, rng, base_makespan)
    journal = {
        "seed": seed,
        "schedule": [
            dict(replica=f.replica, at_s=f.at_s, kind=f.kind,
                 until_s=f.until_s, pool_readable=f.pool_readable,
                 speed_factor=f.speed_factor)
            for f in events
        ],
        "probes": [], "violation": None,
    }
    # the health monitor is live during chaos: undeclared hangs must be
    # detected from heartbeat silence alone, and a condemned-then-woken
    # zombie must have its stale claims fenced for parity to survive
    fleet = _fleet(
        cfg, model, params, cfg["c_slots"], cfg["c_max_len"],
        n_replicas=cfg["n_replicas"], assign="lpt", dispatch="least_load",
        work_stealing=True, health=HealthConfig(),
    )
    # random mid-serve migration probes at pre-drawn step indices
    probe_steps = sorted(
        rng.randrange(10, 200) for _ in range(cfg["migration_probes"])
    )
    try:
        fleet.begin_serve(
            _chaos_requests(cfg), LagrangianPolicy,
            fault_plan=FaultPlan(list(events)),
        )
        prev_clocks = [eng.clock for eng in fleet.engines]
        steps = 0
        while fleet.step():
            steps += 1
            clocks = [eng.clock for eng in fleet.engines]
            for i, (a, b) in enumerate(zip(prev_clocks, clocks)):
                if b < a - 1e-12:
                    raise AssertionError(
                        f"replica {i} clock moved backwards: {a} -> {b}"
                    )
            prev_clocks = clocks
            if probe_steps and steps >= probe_steps[0]:
                probe_steps.pop(0)
                alive = fleet.alive_replicas
                if len(alive) >= 2:
                    src, dst = rng.sample(alive, 2)
                    slots = list(fleet.engines[src].slots.active_slots)
                    if slots:
                        slot = rng.choice(slots)
                        moved = fleet.migrate_slot(src, slot, dst)
                        journal["probes"].append(
                            dict(step=steps, src=src, dst=dst,
                                 slot=slot, moved=moved)
                        )
        report = fleet.finish_serve()
        report.validate()
        _check_consistency(fleet)
        done = [r for t in report.traces for r in t.requests]
        if len(done) != cfg["n_c"] or len({r.rid for r in done}) != cfg["n_c"]:
            raise AssertionError(
                f"{len(done)} completions for {cfg['n_c']} requests"
            )
        if any(r.t_done is None for r in done):
            raise AssertionError("request finished without a done time")
        gen = {rid: list(t) for rid, t in fleet.generated.items()}
        if gen != ref_gen:
            bad = sorted(r for r in ref_gen if gen.get(r) != ref_gen[r])
            raise AssertionError(f"streams diverged for rids {bad}")
    except (AssertionError, RuntimeError) as e:
        journal["violation"] = str(e)
        journal["fault_log"] = list(getattr(fleet, "fault_log", []))
        journal["injected_log"] = list(getattr(fleet, "injected_log", []))
        return False, journal
    from .bench_io import fleet_detection_metrics

    journal["fault_log"] = fleet.fault_log
    journal["injected_log"] = fleet.injected_log
    journal["detection"] = fleet_detection_metrics(report)
    journal["migration_events"] = fleet.migration_events
    journal["steps"] = steps
    return True, journal


def run_chaos_arm(cfg, model, params, out_dir, seeds, smoke):
    from repro.core import LagrangianPolicy

    base = _fleet(
        cfg, model, params, cfg["c_slots"], cfg["c_max_len"],
        n_replicas=cfg["n_replicas"], assign="lpt", dispatch="least_load",
        work_stealing=True,
    )
    base.warm_serving_shapes()
    base.serve(_chaos_requests(cfg), LagrangianPolicy)     # warm
    ref = base.serve(_chaos_requests(cfg), LagrangianPolicy)
    ref_gen = {rid: list(t) for rid, t in base.generated.items()}

    journals, failed = [], []
    t0 = time.perf_counter()
    for seed in seeds:
        ok, journal = _run_one_schedule(
            cfg, model, params, seed, ref_gen, ref.makespan
        )
        journals.append(journal)
        if not ok:
            failed.append(seed)
    wall = time.perf_counter() - t0
    if failed:
        os.makedirs(out_dir or ".", exist_ok=True)
        path = os.path.join(out_dir or ".", "BENCH_chaos_journal.json")
        with open(path, "w") as fh:
            json.dump(journals, fh, indent=2)
        repro = (
            f"PYTHONPATH=src python -m benchmarks.chaos"
            f"{' --smoke' if smoke else ''} "
            f"--seeds {','.join(str(s) for s in failed)}"
        )
        raise SystemExit(
            f"chaos arm: seeds {failed} violated invariants — "
            f"event journal written to {path}\n# repro: {repro}"
        )
    events = [e for j in journals for e in j.get("fault_log", [])]
    injected = [e for j in journals for e in j.get("injected_log", [])]
    det_keys = (
        "suspect_events", "false_suspicions", "condemned_replicas",
        "degraded_events", "fenced_stale_completions", "fenced_stale_exports",
    )
    detection = {
        k: sum(j.get("detection", {}).get(k, 0.0) for j in journals)
        for k in det_keys
    }
    return {
        "n_schedules": len(seeds),
        "seeds": list(seeds),
        "n_requests": cfg["n_c"],
        "all_passed": True,
        "fault_events": len(events),
        "injected_events": len(injected),
        "drains": sum(1 for e in events if e["kind"] == "drain"),
        "kills": sum(1 for e in events if e["kind"] == "kill"),
        "slows": sum(1 for e in events if e["kind"] == "slow"),
        "hangs": sum(1 for e in injected if e["kind"] == "hang"),
        "degrades": sum(1 for e in injected if e["kind"] == "degrade"),
        **detection,
        "recovered_page_copy": sum(e.get("page_copy", 0) for e in events),
        "recovered_recompute": sum(e.get("recompute", 0) for e in events),
        "migration_probes_moved": sum(
            1 for j in journals for p in j["probes"] if p["moved"]
        ),
        "migration_events": sum(j.get("migration_events", 0) for j in journals),
        "wall_s": wall,
    }


# --------------------------------------------------------------------------- #
# Arm 4: observability (Perfetto trace + capacity conservation + audit)       #
# --------------------------------------------------------------------------- #
def _obs_requests(cfg):
    from repro.core import Request

    # the chaos workload with half the requests staggered as online
    # arrivals, so replica-dispatch decisions actually fire (and must all
    # land in the audit log)
    out = []
    for rid in range(cfg["n_c"]):
        n_pre = (cfg["c_prefill_long"] if rid % 3 == 2
                 else cfg["c_prefill_short"])
        out.append(Request(
            rid=rid, n_prefill=n_pre,
            n_decode=cfg["c_decode"] + 3 * (rid % 4),
            arrival=0.0 if rid % 2 == 0 else 0.02 * rid,
        ))
    return out


def run_obs_arm(cfg, model, params, out_dir):
    """Observability gates on a seeded chaos serve:

      * ``summary()`` back-compat — a fault-free serve with observability
        on reports exactly the same summary keys (and the same token
        streams) as the identical serve with it off;
      * capacity conservation — every replica's attribution rows sum
        EXACTLY to makespan x slots (``capacity_attribution`` hard-checks
        the over-attribution side; the gate closes the under side too);
      * audit completeness — every dispatch, steal, migration, and
        condemnation the fleet executed has a matching audit/span record;
      * the exported Chrome-trace JSON is schema-valid and non-trivial.
    """
    from repro.core import LagrangianPolicy
    from repro.obs import Observation, capacity_attribution, write_trace
    from repro.serving.fleet import FaultPlan, ReplicaFault
    from repro.serving.health import HealthConfig

    fc = dict(
        n_replicas=cfg["n_replicas"], assign="lpt", dispatch="least_load",
        work_stealing=True, health=HealthConfig(),
    )
    # fault-free reference, observability OFF
    ref = _fleet(cfg, model, params, cfg["c_slots"], cfg["c_max_len"], **fc)
    ref.warm_serving_shapes()
    ref.serve(_obs_requests(cfg), LagrangianPolicy)        # warm
    ref_report = ref.serve(_obs_requests(cfg), LagrangianPolicy)
    ref_gen = {rid: list(t) for rid, t in ref.generated.items()}

    # the identical fault-free serve, observability ON
    obs0 = Observation()
    quiet = _fleet(
        cfg, model, params, cfg["c_slots"], cfg["c_max_len"],
        engine_kw=dict(observe=obs0), **fc,
    )
    quiet_report = quiet.serve(_obs_requests(cfg), LagrangianPolicy)
    from repro.obs import check_capacity_conservation

    check_capacity_conservation(obs0)
    summary_keys_equal = (
        set(quiet_report.summary()) == set(ref_report.summary())
    )
    quiet_parity = quiet.generated == ref_gen

    # the chaos serve: a drain mid-flight + a declared slowdown, recorded
    obs = Observation()
    fleet = _fleet(
        cfg, model, params, cfg["c_slots"], cfg["c_max_len"],
        engine_kw=dict(observe=obs), **fc,
    )
    plan = FaultPlan([
        ReplicaFault(replica=1, at_s=0.35 * ref_report.makespan,
                     kind="drain"),
        ReplicaFault(replica=2, at_s=0.2 * ref_report.makespan,
                     kind="slow", speed_factor=0.5),
    ])
    report = fleet.serve(_obs_requests(cfg), LagrangianPolicy,
                         fault_plan=plan)
    check_capacity_conservation(obs)
    rows = capacity_attribution(obs)

    n_online = sum(1 for r in _obs_requests(cfg) if r.arrival > 0.0)
    audit = obs.audit.counts()
    instants = [e for e in obs.spans.events if e.rid < 0]
    n_steal_instants = sum(1 for e in instants if e.kind == "steal")
    n_migr_instants = sum(1 for e in instants if e.kind == "migration")
    n_fault_instants = sum(1 for e in instants if e.kind == "fault")

    trace_path = os.path.join(out_dir or ".", "chaos_obs.trace.json")
    write_trace(obs, trace_path)
    with open(trace_path) as fh:
        doc = json.load(fh)
    events = doc.get("traceEvents", [])
    schema_ok = bool(events) and all(
        isinstance(e.get("name"), str) and e.get("ph") in ("X", "i", "M")
        and ("ts" in e or e.get("ph") == "M")
        for e in events
    )

    return {
        "summary_keys_equal": summary_keys_equal,
        "quiet_token_parity": quiet_parity,
        "chaos_token_parity": fleet.generated == ref_gen,
        "capacity_rows": len(rows),
        "capacity_conserved": True,            # check_* raised otherwise
        "capacity_total_s": sum(r["total"] for r in rows.values()),
        "capacity_busy_s": sum(r["busy"] for r in rows.values()),
        "n_online_arrivals": n_online,
        "dispatch_audits": audit.get("dispatch", 0),
        "dispatch_complete": audit.get("dispatch", 0) == n_online,
        "steal_instants": n_steal_instants,
        "steals_complete": n_steal_instants == len(fleet.steal_log),
        "migration_instants": n_migr_instants,
        "migrations_complete": n_migr_instants == fleet.migration_events,
        "condemn_audits": audit.get("condemn", 0),
        "condemns_complete": (
            audit.get("condemn", 0) == fleet.monitor.condemned_events
        ),
        "fault_instants": n_fault_instants,
        "placement_audits": audit.get("placement", 0),
        "span_events": len(obs.spans.events),
        "audit_records": len(obs.audit.records),
        "trace_events": len(events),
        "trace_schema_ok": schema_ok,
        "trace_path": trace_path,
        "makespan_s": report.makespan,
    }


def _parse_seeds(args, cfg):
    """Seed list: --seeds wins, then --n-seeds, then REPRO_CHAOS_SEEDS
    (a comma list or a bare count), then the config default."""
    if args.seeds:
        return [int(s) for s in args.seeds.split(",") if s.strip()]
    if args.n_seeds is not None:
        return list(range(args.n_seeds))
    env = os.environ.get("REPRO_CHAOS_SEEDS", "").strip()
    if env:
        if "," in env:
            return [int(s) for s in env.split(",") if s.strip()]
        return list(range(int(env)))
    return list(range(cfg["n_seeds"]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (seconds, not minutes)")
    ap.add_argument("--out", default=None, help="directory for BENCH_*.json")
    ap.add_argument("--n-seeds", type=int, default=None,
                    help="chaos arm: run seeds 0..N-1")
    ap.add_argument("--seeds", default=None,
                    help="chaos arm: explicit comma-separated seed list")
    args = ap.parse_args()
    cfg = SMOKE if args.smoke else FULL
    seeds = _parse_seeds(args, cfg)

    from .bench_io import emit_json

    model, params = _model_and_params(cfg)
    drain = run_drain_arm(cfg, model, params)
    cache = run_cache_arm(cfg, model, params)
    rebalance = run_rebalance_arm(cfg, model, params)
    chaos = run_chaos_arm(cfg, model, params, args.out, seeds, args.smoke)
    obs = run_obs_arm(cfg, model, params, args.out)

    print("name,value,unit")
    for mode in ("drain", "hard_kill"):
        m = drain[mode]
        print(f"{mode}_completed,{m['completed']},requests")
        print(f"{mode}_recomputed_tokens,{int(m['recomputed_tokens'])},tokens")
        print(f"{mode}_page_copy,{int(m['recovered_page_copy'])},requests")
        print(f"{mode}_time_to_recover,{m['time_to_recover_s'] * 1e3:.2f},ms")
        print(f"{mode}_token_parity,{int(m['token_parity'])},bool")
    print(f"cache_drain_completed,{cache['completed']},requests")
    print(f"cache_drain_recomputed_tokens,"
          f"{int(cache['recomputed_tokens'])},tokens")
    print(f"cache_drain_hit_tokens,"
          f"{int(cache['drain_cache_hit_tokens'])},tokens")
    print(f"cache_drain_token_parity,{int(cache['token_parity'])},bool")
    print(f"cache_off_on_parity,{int(cache['off_on_parity'])},bool")
    print(f"rebalance_queued_only_makespan,"
          f"{rebalance['queued_only']['makespan_s'] * 1e3:.2f},ms")
    print(f"rebalance_running_steal_makespan,"
          f"{rebalance['running_steal']['makespan_s'] * 1e3:.2f},ms")
    print(f"rebalance_migrations,"
          f"{rebalance['running_steal']['migration_events']},events")
    print(f"rebalance_token_parity,{int(rebalance['token_parity'])},bool")
    print(f"chaos_schedules,{chaos['n_schedules']},runs")
    print(f"chaos_fault_events,{chaos['fault_events']},events")
    print(f"chaos_injected_events,{chaos['injected_events']},events")
    print(f"chaos_hangs,{chaos['hangs']},events")
    print(f"chaos_degrades,{chaos['degrades']},events")
    print(f"chaos_condemned,{int(chaos['condemned_replicas'])},replicas")
    print(f"chaos_fenced_claims,"
          f"{int(chaos['fenced_stale_completions'])},claims")
    print(f"chaos_page_copy,{chaos['recovered_page_copy']},requests")
    print(f"chaos_recompute,{chaos['recovered_recompute']},requests")
    print(f"chaos_migrations,{chaos['migration_events']},events")
    print(f"obs_summary_keys_equal,{int(obs['summary_keys_equal'])},bool")
    print(f"obs_capacity_conserved,{int(obs['capacity_conserved'])},bool")
    print(f"obs_dispatch_complete,{int(obs['dispatch_complete'])},bool")
    print(f"obs_span_events,{obs['span_events']},events")
    print(f"obs_audit_records,{obs['audit_records']},records")
    print(f"obs_trace_events,{obs['trace_events']},events")
    print(f"obs_trace_schema_ok,{int(obs['trace_schema_ok'])},bool")

    payload = {"drain": drain, "cache": cache, "rebalance": rebalance,
               "chaos": chaos, "obs": obs}
    path = emit_json("chaos", payload, smoke=args.smoke, out_dir=args.out)
    print(f"# wrote {path}")

    # ---- hard-fail gates (stable structural signals) --------------------- #
    for mode in ("drain", "hard_kill"):
        m = drain[mode]
        if m["completed"] != drain["n_requests"] or not m["exactly_once"]:
            raise SystemExit(
                f"{mode}: {m['completed']}/{drain['n_requests']} completions"
            )
        if not m["token_parity"]:
            raise SystemExit(f"{mode}: streams diverged from fault-free serve")
    if drain["drain"]["recomputed_tokens"] != 0:
        raise SystemExit(
            f"drain recomputed {int(drain['drain']['recomputed_tokens'])} "
            f"tokens — page-copy must re-pay nothing"
        )
    if drain["drain"]["recovered_page_copy"] < 1:
        raise SystemExit("drain never exercised the page-copy path")
    if drain["hard_kill"]["recomputed_tokens"] <= 0:
        raise SystemExit(
            "hard kill re-paid no tokens — the injection state had no "
            "generated prefix, the comparison is vacuous"
        )
    if cache["completed"] != cache["n_requests"] or not cache["exactly_once"]:
        raise SystemExit(
            f"cache drain: {cache['completed']}/{cache['n_requests']} "
            f"completions"
        )
    if not cache["token_parity"]:
        raise SystemExit(
            "cache drain: streams diverged from the fault-free cached serve"
        )
    if not cache["off_on_parity"]:
        raise SystemExit(
            "cache arm: enabling the prefix cache changed token streams"
        )
    if cache["recomputed_tokens"] != 0:
        raise SystemExit(
            f"cache drain recomputed {int(cache['recomputed_tokens'])} "
            f"tokens — migrating shared pages must re-pay nothing"
        )
    if cache["recovered_page_copy"] < 1:
        raise SystemExit("cache drain never exercised the page-copy path")
    if cache["ref_cache_hit_tokens"] <= 0 or cache["drain_cache_hit_tokens"] <= 0:
        raise SystemExit(
            "cache arm served zero tokens from the cache — the drain hit "
            "no shared pages, the arm is vacuous"
        )
    if not rebalance["token_parity"]:
        raise SystemExit("rebalance: migration changed token streams")
    if rebalance["running_steal"]["migration_events"] < 1:
        raise SystemExit("rebalance: running-slot steal never fired")
    if rebalance["running_steal"]["recomputed_tokens"] != 0:
        raise SystemExit("rebalance: migration must not recompute")
    if not (rebalance["running_steal"]["makespan_s"]
            < rebalance["queued_only"]["makespan_s"]):
        raise SystemExit(
            f"running steal makespan "
            f"{rebalance['running_steal']['makespan_s']:.4f}s not below "
            f"queued-only {rebalance['queued_only']['makespan_s']:.4f}s"
        )
    if not chaos["all_passed"]:
        raise SystemExit("chaos schedules failed")
    # under-injection gate: declared faults land in fault_log, undeclared
    # hangs/degrades only in injected_log — count both, or a hang-heavy
    # draw would trip this even though every schedule injected something
    n_injections = chaos["fault_events"] + chaos["injected_events"]
    if n_injections < len(seeds):
        raise SystemExit(
            f"only {n_injections} fault/injection events across "
            f"{len(seeds)} schedules — the harness is under-injecting"
        )
    # ---- observability gates -------------------------------------------- #
    if not obs["summary_keys_equal"]:
        raise SystemExit(
            "obs arm: enabling observability changed the summary() key set"
        )
    if not (obs["quiet_token_parity"] and obs["chaos_token_parity"]):
        raise SystemExit("obs arm: observability changed token streams")
    for gate in ("dispatch_complete", "steals_complete",
                 "migrations_complete", "condemns_complete"):
        if not obs[gate]:
            raise SystemExit(f"obs arm: audit incomplete ({gate})")
    if obs["dispatch_audits"] < 1 or obs["fault_instants"] < 1:
        raise SystemExit(
            "obs arm vacuous: no dispatch decisions or fault instants "
            "were recorded"
        )
    if not obs["trace_schema_ok"] or obs["trace_events"] < 10:
        raise SystemExit(
            f"obs arm: Perfetto export invalid or trivial "
            f"({obs['trace_events']} events)"
        )
    print("# all chaos gates passed")


if __name__ == "__main__":
    main()
