"""Heterogeneous fleet study: R||Cmax-aware LPT vs speed-blind LPT vs
round-robin on a REAL 2-speed multi-replica fleet.

The paper's offline model assumes identical machines (P||Cmax); real fleets
mix accelerator generations. This benchmark emulates a 2-speed fleet on one
host — each replica's ``speed_factor`` scales its virtual-time stage clock,
so a 0.5× replica IS a machine whose stages take twice as long, as far as
every scheduler can observe — and serves the same skewed workload three
ways at exact token parity:

  * ``hetero_lpt``  — ``solve_hetero`` (speed-scaled LPT + local search,
                      each candidate priced through the destination
                      replica's own cost model) partitions the backlog;
  * ``blind_lpt``   — the P||Cmax solve on the shared base model, ignoring
                      replica speed (the pre-heterogeneous ``Fleet``);
  * ``round_robin`` — the unbalanced baseline.

The workload is adversarial for both baselines by construction: the
decode-heavy requests sit at *odd* queue positions, so round-robin piles
all of them onto the slow replica, and speed-blind LPT balances token
counts 50/50 when the speed-optimal split is ~2:1 toward the fast replica.

Work stealing is OFF in all three gated arms so the comparison isolates the
offline partitioner (a reported-only ``hetero_lpt+steal`` arm shows what
the R||Cmax-gated stealing adds back on top).

Hard-fail gates (stable on CPU — the slow replica's ×2 virtual time dwarfs
timer noise):

  * hetero-aware LPT strictly beats speed-blind LPT AND round-robin on
    fleet makespan and (speed-weighted) fleet utilization;
  * exact per-request token parity across all assignments;
  * the R||Cmax lower bound — ``hetero_theoretical_lower_bound`` evaluated
    with per-replica cost models measured from the traces' own stage-time
    medians — is ≤ every achieved makespan (its exact reduction to the
    P||Cmax bound at equal speeds is unit-tested in tests/test_hetero.py).

Run:  PYTHONPATH=src python -m benchmarks.hetero_fleet [--smoke] [--out DIR]
Prints ``name,value,unit`` CSV and writes BENCH_hetero_fleet.json.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

FULL = dict(
    model=dict(n_layers=2, d_model=128, n_heads=8, n_kv_heads=4, d_ff=256,
               vocab_size=512),
    n_slots=4, max_len=128, seq_buckets=(32,),
    level_caps=(64, 128, 256), page_size=16, prefill_chunk=32,
    speed_factors=(1.0, 0.25),
    n_long=6, long_prefill=24, long_decode=80,
    n_short=10, short_prefill=16, short_decode=8,
)
SMOKE = dict(
    model=dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
               vocab_size=256),
    n_slots=2, max_len=64, seq_buckets=(32,),
    level_caps=(32, 64, 128), page_size=16, prefill_chunk=16,
    speed_factors=(1.0, 0.25),
    n_long=3, long_prefill=12, long_decode=32,
    n_short=5, short_prefill=8, short_decode=5,
)


def _skewed_workload(cfg, seed: int):
    """Long decodes at ODD rid positions: round-robin over 2 replicas sends
    every long request to the SLOW replica (index 1), and speed-blind LPT
    balances the halves as if the replicas were equal."""
    from repro.core import Request

    rng = np.random.default_rng(seed)
    reqs = []
    n_total = cfg["n_long"] + cfg["n_short"]
    longs_placed = 0
    for rid in range(n_total):
        if rid % 2 == 1 and longs_placed < cfg["n_long"]:
            p = cfg["long_prefill"] + int(rng.integers(0, 4))
            d = cfg["long_decode"] + int(rng.integers(0, 4))
            longs_placed += 1
        else:
            p = cfg["short_prefill"] + int(rng.integers(0, 4))
            d = cfg["short_decode"] + int(rng.integers(0, 3))
        reqs.append(Request(rid=rid, n_prefill=p, n_decode=d))
    return reqs


def _build_fleet(cfg, model, params, mode: str):
    from repro.core import CostModel, ReplicaSpec
    from repro.serving.engine import EngineConfig
    from repro.serving.fleet import Fleet, FleetConfig

    assign = {
        "hetero_lpt": "lpt",
        "hetero_lpt_steal": "lpt",
        "blind_lpt": "lpt_blind",
        "round_robin": "round_robin",
    }[mode]
    fc = FleetConfig(
        n_replicas=len(cfg["speed_factors"]),
        assign=assign,
        dispatch="round_robin" if mode == "round_robin" else "least_load",
        work_stealing=(mode == "hetero_lpt_steal"),
    )
    # per-token dispatch + alternating stages: every decode round costs one
    # measured round time, so makespans reflect ROUND COUNTS × speed and
    # the measured-median lower bound is conservative (no fused-dispatch
    # amortization undercutting the per-round model)
    ecfg = EngineConfig(
        n_slots=cfg["n_slots"], max_len=cfg["max_len"],
        prefill_seq_buckets=cfg["seq_buckets"],
        kv_layout="paged", page_size=cfg["page_size"],
        prefill_chunk=cfg["prefill_chunk"],
        decode_horizon=1, mixed_schedule=False,
    )
    return Fleet(
        model, params, ecfg, fc,
        cost_model=CostModel(level_caps=cfg["level_caps"]),
        replica_specs=[ReplicaSpec(speed_factor=s)
                       for s in cfg["speed_factors"]],
    )


def _fleet_metrics(report, wall_s: float):
    from .bench_io import fleet_recovery_metrics

    s = report.summary()
    return {
        "makespan_s": s["makespan_s"],
        "fleet_utilization": s["fleet_utilization"],
        "busy_window_utilization": s["busy_window_utilization"],
        "generation_speed_tok_s": s["generation_speed_tok_s"],
        "steal_events": s["steal_events"],
        "offline_solver": s["offline_solver"],
        "offline_gap": s["offline_gap"],
        "speed_factors": s["speed_factors"],
        "replica_makespans_s": s["replica_makespans_s"],
        "replica_requests": s["replica_requests"],
        "lb_ratio_live_cm": s["lb_ratio"],
        "wall_s": wall_s,
        **fleet_recovery_metrics(report),
    }


def _measured_replica_cms(cfg, report):
    """Per-replica cost models from each replica's OWN trace stage-time
    medians (decode_overhead = median per-round time with per_token = 0;
    prefill priced per token) — the same robust-median construction
    ``benchmarks/fleet.py`` uses, done per replica so the emulated speed
    asymmetry lands in the models the R||Cmax bound is evaluated with.
    A replica that happened to receive no work derives its model from
    replica 0's medians re-scaled by the emulated speed ratio."""
    from repro.core import CostModel

    raw = []
    for trace in report.traces:
        round_samples = [
            s.duration / max(s.rounds, 1)
            for s in trace.stages if s.kind.value in ("decode", "mixed")
        ]
        prefill_samples = [
            s.duration / s.tokens
            for s in trace.stages if s.kind.value == "prefill" and s.tokens > 0
        ]
        raw.append((round_samples, prefill_samples))
    speeds = cfg["speed_factors"]
    cms = []
    for j, (round_samples, prefill_samples) in enumerate(raw):
        if not round_samples:
            scale = speeds[0] / speeds[j]
            round_samples = [x * scale for x in raw[0][0]]
            prefill_samples = [x * scale for x in raw[0][1]]
        cms.append(
            CostModel(
                prefill_per_token=float(np.median(prefill_samples or [0.0])),
                prefill_overhead=0.0,
                decode_per_token=0.0,
                decode_overhead=float(np.median(round_samples)),
                level_caps=cfg["level_caps"],
            )
        )
    return cms


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (seconds, not minutes)")
    ap.add_argument("--out", default=None, help="directory for BENCH_*.json")
    args = ap.parse_args()
    cfg = SMOKE if args.smoke else FULL

    import jax

    from repro.configs.base import ArchConfig
    from repro.core import LagrangianPolicy
    from repro.core.gantt import fleet_ascii_gantt
    from repro.core.hetero import hetero_theoretical_lower_bound

    from repro.models.layers import init_params
    from repro.models.transformer import TransformerLM

    from .bench_io import emit_json

    arch = ArchConfig(name="hetero-fleet-bench", family="dense", **cfg["model"])
    model = TransformerLM(arch)
    params = init_params(jax.random.key(0), model.param_defs())

    modes = ("round_robin", "blind_lpt", "hetero_lpt", "hetero_lpt_steal")
    fleets = {m: _build_fleet(cfg, model, params, m) for m in modes}
    # compile every reachable jit variant BEFORE any profiled stage so no
    # first-hit compile lands inside a measured serve. Deliberately NO warm
    # serve: each mode's partition is then priced on the per-replica
    # *priors* (which carry the exact emulated speed ratio), keeping every
    # partition deterministic across machines — profiler refits still
    # happen live inside the measured serve, identically for every mode.
    for fleet in fleets.values():
        fleet.warm_serving_shapes()

    results = {}
    for mode, fleet in fleets.items():
        reqs = _skewed_workload(cfg, seed=11)
        t0 = time.perf_counter()
        report = fleet.serve(reqs, LagrangianPolicy)
        wall = time.perf_counter() - t0
        report.validate()
        results[mode] = (fleet.generated, report, _fleet_metrics(report, wall))
    print(fleet_ascii_gantt(results["round_robin"][1], width=72))
    print(fleet_ascii_gantt(results["blind_lpt"][1], width=72))
    print(fleet_ascii_gantt(results["hetero_lpt"][1], width=72))

    # ---- R||Cmax lower bound from measured per-replica models ------------ #
    # each mode's bound is built from its OWN traces' stage-time medians
    # (machine-load drift between the sequentially-run modes would otherwise
    # let a mode that hit a quiet CPU window undercut a bound measured
    # during a noisy one); the bound must floor the makespan it came from
    reqs_lb = _skewed_workload(cfg, seed=11)
    lower_bounds = {}
    lb_ratios = {}
    for mode, (_, report, m) in results.items():
        cms = _measured_replica_cms(cfg, report)
        lb = hetero_theoretical_lower_bound(reqs_lb, cms, cfg["n_slots"])
        lower_bounds[mode] = lb.total
        lb_ratios[mode] = (
            m["makespan_s"] / lb.total if lb.total > 0 else float("inf")
        )

    # ---- parity: replica placement must never change tokens -------------- #
    reference = results["hetero_lpt"][0]
    parity = all(
        gen.keys() == reference.keys()
        and all(gen[r] == reference[r] for r in reference)
        for gen, _, _ in results.values()
    )

    print("name,value,unit")
    for mode, (_, _, m) in results.items():
        print(f"{mode}_makespan,{m['makespan_s']:.4f},s")
        print(f"{mode}_fleet_utilization,{m['fleet_utilization']:.4f},frac")
        print(f"{mode}_speed,{m['generation_speed_tok_s']:.1f},tok/s")
        print(f"{mode}_steals,{m['steal_events']},events")
        print(f"{mode}_lb_ratio,{lb_ratios[mode]:.3f},x")
    print(f"token_parity,{int(parity)},bool")

    payload = {
        "modes": {m: v[2] for m, v in results.items()},
        "token_parity": bool(parity),
        "speed_factors": list(cfg["speed_factors"]),
        "lower_bounds_measured_s": lower_bounds,
        "lb_ratios_measured": lb_ratios,
    }
    path = emit_json("hetero_fleet", payload, smoke=args.smoke, out_dir=args.out)
    print(f"# wrote {path}")

    # ---- hard-fail gates (stable signals only) --------------------------- #
    if not parity:
        raise SystemExit(
            "token parity violated: replica assignment changed results"
        )
    het = results["hetero_lpt"][2]
    for base in ("blind_lpt", "round_robin"):
        b = results[base][2]
        if not het["makespan_s"] < b["makespan_s"]:
            raise SystemExit(
                f"ordering violated: hetero-aware LPT makespan "
                f"{het['makespan_s']:.3f}s not strictly below {base} "
                f"{b['makespan_s']:.3f}s"
            )
        if not het["fleet_utilization"] > b["fleet_utilization"]:
            raise SystemExit(
                f"ordering violated: hetero-aware LPT fleet utilization "
                f"{het['fleet_utilization']:.4f} not strictly above {base} "
                f"{b['fleet_utilization']:.4f}"
            )
    for mode, ratio in lb_ratios.items():
        if ratio < 1.0 - 1e-9:
            raise SystemExit(
                f"R||Cmax lower bound exceeded by {mode}: achieved makespan "
                f"is {ratio:.3f}× the measured bound (must be ≥ 1.0)"
            )
    for mode, (_, _, m) in results.items():
        if not 0.0 < m["fleet_utilization"] <= 1.0 + 1e-9:
            raise SystemExit(
                f"{mode} fleet utilization out of range: "
                f"{m['fleet_utilization']}"
            )


if __name__ == "__main__":
    main()
