"""Benchmarks, one per paper table/figure (see DESIGN.md §6 experiment index).

Each function returns (name, us_per_call, derived) rows for the CSV contract
of ``benchmarks.run``. The derived column carries the figure's headline
metric (utilization %, seconds, tok/s, …).
"""
from __future__ import annotations

import statistics
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core import (
    CostModel,
    PAPER_COST_MODEL,
    LagrangianPolicy,
    OriginalMIP,
    PrefillFirstPolicy,
    SystemSnapshot,
    CandidateBatch,
    recost_trace_mip_semantics,
    simulate,
    theoretical_lower_bound,
    toy_instance,
)
from repro.core.types import Request
from repro.data import PAPER_PREDICTOR_NOISE_STD, PAPER_WORKLOAD_SPEC, gsm8k_like_workload

Row = Tuple[str, float, str]

N_CLIENTS = 200


def _paper_requests(seed: int = 0):
    return gsm8k_like_workload(
        PAPER_WORKLOAD_SPEC, seed=seed, estimate_noise_std=PAPER_PREDICTOR_NOISE_STD
    )


def _sim_row(name: str, mode: str, paper_util: float, paper_time: float,
             seed: int = 0) -> Row:
    t0 = time.perf_counter()
    tr = simulate(_paper_requests(seed), N_CLIENTS, PAPER_COST_MODEL, mode=mode)
    wall = (time.perf_counter() - t0) * 1e6
    s = tr.summary()
    derived = (
        f"util={s['utilization'] * 100:.2f}% (paper {paper_util}%) "
        f"makespan={s['makespan_s']:.2f}s (paper {paper_time}s) "
        f"speed={s['generation_speed_tok_s']:.1f}tok/s bins={s['num_bins']}"
    )
    return (name, wall, derived)


def bench_baseline() -> List[Row]:
    """Fig. 6 — FCFS prefill-first baseline (80.2%, 201.00 s)."""
    return [_sim_row("fig6_baseline", "baseline", 80.2, 201.00)]


def bench_offline() -> List[Row]:
    """Fig. 7 — offline bin-packing only (85.5%, 197.08 s)."""
    return [_sim_row("fig7_offline", "offline", 85.5, 197.08)]


def bench_online_only() -> List[Row]:
    """Fig. 8 — online-only scheduling (86.19%, 193.33 s)."""
    return [_sim_row("fig8_online", "online", 86.19, 193.33)]


def bench_hybrid() -> List[Row]:
    """Fig. 9 — hybrid offline+online (89.06%, 190.58 s)."""
    return [_sim_row("fig9_hybrid", "hybrid", 89.06, 190.58)]


def bench_lower_bound() -> List[Row]:
    """Eq. 32 — theoretical lower bound (paper: 180 s = 13 + 167)."""
    reqs = _paper_requests()
    t0 = time.perf_counter()
    lb = theoretical_lower_bound(reqs, N_CLIENTS, PAPER_COST_MODEL)
    wall = (time.perf_counter() - t0) * 1e6
    tr = simulate(reqs, N_CLIENTS, PAPER_COST_MODEL, mode="hybrid")
    trb = simulate(reqs, N_CLIENTS, PAPER_COST_MODEL, mode="baseline")
    gap_b = trb.makespan - lb.total
    gap_h = tr.makespan - lb.total
    derived = (
        f"LB={lb.total:.2f}s (p*={lb.t_prefill_star:.2f} d*={lb.t_decode_star:.2f}; "
        f"paper 180=13+167) gap baseline={gap_b:.1f}s hybrid={gap_h:.1f}s "
        f"gap_closed={100 * (1 - gap_h / gap_b):.1f}% (paper 52.4%)"
    )
    return [("eq32_lower_bound", wall, derived)]


def bench_hundred_cases(n_cases: int = 100) -> List[Row]:
    """Figs. 10–11 — 100 random cases: mean utilization +8.0 pp, +100.63
    tok/s for hybrid vs baseline in the paper."""
    d_util, d_speed, wins = [], [], 0
    t0 = time.perf_counter()
    for seed in range(n_cases):
        reqs = _paper_requests(seed)
        trb = simulate(reqs, N_CLIENTS, PAPER_COST_MODEL, mode="baseline")
        trh = simulate(reqs, N_CLIENTS, PAPER_COST_MODEL, mode="hybrid")
        d_util.append((trh.utilization - trb.utilization) * 100)
        d_speed.append(trh.generation_speed - trb.generation_speed)
        wins += trh.utilization > trb.utilization
    wall = (time.perf_counter() - t0) * 1e6 / n_cases
    derived = (
        f"mean Δutil=+{statistics.mean(d_util):.2f}pp (paper +8.0) "
        f"mean Δspeed=+{statistics.mean(d_speed):.1f}tok/s (paper +100.63) "
        f"hybrid wins {wins}/{n_cases}"
    )
    return [("fig10_11_hundred_cases", wall, derived)]


def bench_decision_latency() -> List[Row]:
    """§IV — online decisions must land within 10 ms (paper reports <5 ms).
    Measured at the paper's scale (200 clients, 1319 pending)."""
    reqs = _paper_requests()
    tr = simulate(reqs, N_CLIENTS, PAPER_COST_MODEL, mode="hybrid")
    times = tr.decision_times_ms
    p50 = statistics.median(times)
    p99 = sorted(times)[int(0.99 * len(times))]
    mx = max(times)
    derived = (
        f"p50={p50 * 1000:.1f}us p99={p99 * 1000:.1f}us max={mx:.3f}ms "
        f"(budget 10ms, paper <5ms) n={len(times)}"
    )
    return [("decision_latency", p50 * 1e3, derived)]


def bench_mip_toy() -> List[Row]:
    """§III-C — the original MIP at toy scale: HiGHS optimum vs the hybrid
    heuristic re-costed under MIP semantics (optimality-gap check)."""
    rows = []
    ratios = []
    for seed in range(3):
        reqs, J, K, cm = toy_instance(n_requests=6, n_clients=2, n_bins=4, seed=seed)
        m = OriginalMIP(reqs, J, K, cm)
        t0 = time.perf_counter()
        sol = m.solve(time_limit_s=60)
        wall = (time.perf_counter() - t0) * 1e6
        tr = simulate(reqs, J, cm, mode="hybrid", oracle_estimates=True)
        hyb = recost_trace_mip_semantics(tr, cm, J)
        ratios.append(hyb / sol.objective)
        rows.append(
            (
                f"mip_toy_seed{seed}",
                wall,
                f"MIP*={sol.objective:.4f}s hybrid={hyb:.4f}s "
                f"ratio={hyb / sol.objective:.3f} ({sol.status})",
            )
        )
    rows.append(
        ("mip_toy_mean_ratio", 0.0, f"hybrid/MIP* mean={statistics.mean(ratios):.3f}")
    )
    return rows


def bench_offline_solver() -> List[Row]:
    """§V-B — offline bin-packing solve at paper scale (1319×200). The paper
    needed ~20 min with SCIP; LPT+local-search lands within the LP bound gap
    in milliseconds, with HiGHS verification at small scale."""
    from repro.core import solve_offline

    reqs = _paper_requests()
    t0 = time.perf_counter()
    res = solve_offline(reqs, N_CLIENTS, PAPER_COST_MODEL)
    wall = (time.perf_counter() - t0) * 1e6
    derived = (
        f"makespan={res.makespan_est:.2f}s lp_lb={res.lp_lower_bound:.2f}s "
        f"gap={res.gap * 100:.3f}% solver={res.solver}"
    )
    return [("offline_binpack_1319x200", wall, derived)]


def bench_beyond_paper_policies() -> List[Row]:
    """§Beyond-paper — improved iteration policies vs the paper's rule, on
    the paper's workload and two stress workloads (see EXPERIMENTS.md)."""
    import dataclasses

    from repro.core import AmortizedPolicy, BalancedLagrangianPolicy

    rows: List[Row] = []
    workloads = {
        "gsm8k": PAPER_WORKLOAD_SPEC,
        "long_prompts": dataclasses.replace(
            PAPER_WORKLOAD_SPEC, input_mean=400.0, input_std=120.0
        ),
    }
    for wname, spec in workloads.items():
        reqs = gsm8k_like_workload(
            spec, seed=0, estimate_noise_std=PAPER_PREDICTOR_NOISE_STD
        )
        for pname, pol in [
            ("paper_lagrangian", LagrangianPolicy()),
            ("balanced", BalancedLagrangianPolicy()),
            ("amortized", AmortizedPolicy()),
        ]:
            t0 = time.perf_counter()
            tr = simulate(reqs, N_CLIENTS, PAPER_COST_MODEL, mode="hybrid",
                          iteration_policy=pol)
            wall = (time.perf_counter() - t0) * 1e6
            rows.append((
                f"beyond_{wname}_{pname}", wall,
                f"util={tr.utilization * 100:.2f}% total={tr.makespan:.2f}s "
                f"bins={tr.num_bins}",
            ))
    return rows


def bench_beyond_hundred_cases(n_cases: int = 50) -> List[Row]:
    """§Beyond-paper — AmortizedPolicy vs the paper's rule over random cases
    (robustness statistics for the headline single-case win)."""
    from repro.core import AmortizedPolicy

    d_util, wins = [], 0
    t0 = time.perf_counter()
    for seed in range(n_cases):
        reqs = _paper_requests(seed)
        a = simulate(reqs, N_CLIENTS, PAPER_COST_MODEL, mode="hybrid",
                     iteration_policy=LagrangianPolicy())
        b = simulate(reqs, N_CLIENTS, PAPER_COST_MODEL, mode="hybrid",
                     iteration_policy=AmortizedPolicy())
        d_util.append((b.utilization - a.utilization) * 100)
        wins += b.utilization > a.utilization
    wall = (time.perf_counter() - t0) * 1e6 / n_cases
    derived = (
        f"amortized vs paper-lagrangian: mean Δutil=+{statistics.mean(d_util):.2f}pp "
        f"wins {wins}/{n_cases}"
    )
    return [("beyond_hundred_cases", wall, derived)]


ALL_BENCHES = [
    bench_baseline,
    bench_offline,
    bench_online_only,
    bench_hybrid,
    bench_lower_bound,
    bench_hundred_cases,
    bench_decision_latency,
    bench_mip_toy,
    bench_offline_solver,
    bench_beyond_paper_policies,
    bench_beyond_hundred_cases,
]

# Multi-simulation sweeps skipped by ``benchmarks.run --smoke`` (each runs
# 50–100 full paper-scale simulations; the single-case tables cover the
# same code paths in seconds).
SLOW_BENCHES = {"bench_hundred_cases", "bench_beyond_hundred_cases"}
