"""Mixed-step vs alternating-stage scheduling under Poisson arrivals.

The alternating engine (PR 1/2 shape) mirrors the paper literally: every
iteration either runs a prefill chunk round — freezing all decoders for its
duration — or a decode stage, delaying waiting prompts. The mixed-step path
dispatches ONE batch per iteration carrying the decode tokens of every
active slot plus a policy-priced share of prefill-chunk tokens, so prefill
piggybacks on decode and the stall stops existing. This benchmark drives
both modes over the SAME open-loop workload (Poisson arrivals over
GSM8K-shaped prompt/output lengths, via ``ArrivalQueueScheduler``) and
measures what the unification buys:

  * throughput — output tokens / s of engine stage-time;
  * p95 per-token decode latency *during prefill bursts* (stages that ran
    while prefill work was pending — the slice alternation hurts most);
  * prefill-stall seconds — wall-clock decoders spent frozen behind
    preempting prefill stages (≈ 0 in mixed mode by construction);
  * mixed rounds / dispatches per token;
  * exact token parity — unifying the dispatch must never change results.

Wall-clock varies with machine load; parity + stall + dispatch counts are
the stable CPU signals (throughput is reported, not asserted).

Run:  PYTHONPATH=src python -m benchmarks.mixed_batch [--smoke] [--out DIR]
Prints ``name,value,unit`` CSV and writes BENCH_mixed_batch.json.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs.base import ArchConfig
from repro.data import WorkloadSpec, gsm8k_like_workload

from .bench_io import emit_json, run_serving_benchmark

FULL = dict(
    arch=ArchConfig(
        name="bench", family="dense", n_layers=2, d_model=128, n_heads=8,
        n_kv_heads=4, d_ff=256, vocab_size=512,
    ),
    # GSM8K-shaped mix: mid-length prompts, decode-heavy outputs, so
    # arrivals land while earlier requests are mid-decode
    spec=WorkloadSpec(
        n_requests=24, input_mean=48, input_std=24, output_mean=36,
        output_std=14, output_max=56, input_max=96,
    ),
    n_slots=8, max_len=160, seq_buckets=(32, 64, 96),
    level_caps=(64, 128, 256), prefill_chunk=32,
    # mean inter-arrival time in *decode rounds* (Poisson process); < slots
    # keeps admission pressure high enough to create prefill bursts
    arrival_rounds=2.0,
    # cap the per-round chunk share at 2 chunks: an unbounded share lets a
    # single mixed round absorb a whole burst and its duration becomes the
    # burst p95 (measured 34.5 ms at cap 256 vs 9.5 ms at cap 64 on the
    # same workload, with ~7% throughput cost)
    mixed_token_buckets=(16, 32, 64),
)
SMOKE = dict(
    arch=ArchConfig(
        name="bench-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256,
    ),
    spec=WorkloadSpec(
        n_requests=8, input_mean=20, input_std=10, output_mean=14,
        output_std=6, output_max=20, input_max=40,
    ),
    n_slots=4, max_len=64, seq_buckets=(32,),
    level_caps=(32, 64, 128), prefill_chunk=16,
    arrival_rounds=2.0,
    mixed_token_buckets=(16, 32),
)


def _workload_factory(cfg, round_time_s: float):
    """GSM8K-shaped lengths with Poisson arrivals: exponential inter-arrival
    times with mean ``arrival_rounds`` decode rounds, scaled by the measured
    round time so the traffic intensity is machine-independent."""

    def make(seed: int):
        reqs = gsm8k_like_workload(cfg["spec"], seed=seed, known_lengths=True)
        rng = np.random.default_rng(seed + 1000)
        gaps = rng.exponential(
            cfg["arrival_rounds"] * round_time_s, size=len(reqs)
        )
        t = 0.0
        for r, g in zip(reqs, gaps):
            t += float(g)
            r.arrival = t
        return reqs

    return make


def _calibrate_round_time(cfg) -> float:
    """One closed-loop warm run to measure this machine's decode round time
    (and pre-compile most jit variants); both modes then see the exact same
    arrival timestamps. The median over measured decode stages is robust to
    the compile-time outliers a least-squares cost-model fit would absorb."""
    _, _, trace = run_serving_benchmark(
        cfg, kv_layout="paged", page_size=16,
        prefill_chunk=cfg["prefill_chunk"], mixed_schedule=True,
        mixed_token_buckets=cfg["mixed_token_buckets"],
    )
    samples = [
        s.duration / max(s.rounds, 1)
        for s in trace.stages
        if s.kind.value in ("decode", "mixed") and s.tokens - s.chunk_tokens > 0
    ]
    return float(np.median(samples))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (seconds, not minutes)")
    ap.add_argument("--out", default=None, help="directory for BENCH_*.json")
    args = ap.parse_args()
    cfg = SMOKE if args.smoke else FULL

    from repro.core import ArrivalQueueScheduler, LagrangianPolicy

    round_s = _calibrate_round_time(cfg)
    workload = _workload_factory(cfg, round_s)

    runs = {}
    for name, mixed in (("alternating", False), ("mixed", True)):
        # the paper's Lagrangian drives both modes: binary stage pricing in
        # alternating mode, the continuous prefill_share knob in mixed mode
        # (PrefillFirst would take the whole chunk budget every round and
        # pay maximal decode-latency inflation — the knob exists to bound it)
        eng, m, trace = run_serving_benchmark(
            cfg,
            workload_factory=workload,
            scheduler_factory=ArrivalQueueScheduler,
            policy_factory=LagrangianPolicy,
            warm_seed=11,            # warm on the measured workload: every
            kv_layout="paged",       # jit shape compiles before timing starts
            page_size=16,
            prefill_chunk=cfg["prefill_chunk"], mixed_schedule=mixed,
            mixed_token_buckets=cfg["mixed_token_buckets"],
        )
        runs[name] = (eng, m, trace)

    (eng_a, alt, _), (eng_m, mix, _) = runs["alternating"], runs["mixed"]
    parity = eng_a.generated.keys() == eng_m.generated.keys() and all(
        eng_a.generated[r] == eng_m.generated[r] for r in eng_a.generated
    )

    print("name,value,unit")
    for name, m in (("alternating", alt), ("mixed", mix)):
        print(f"{name}_throughput,{m['throughput_tok_s']:.1f},tok/s")
        print(f"{name}_prefill_stall,{m['prefill_stall_time_s']:.4f},s")
        print(f"{name}_mixed_rounds,{m['mixed_rounds']},rounds")
        print(f"{name}_dispatches_per_token,{m['dispatches_per_token']:.4f},1/tok")
        print(
            f"{name}_p95_burst_token_latency,"
            f"{m['p95_burst_token_latency_s'] * 1e3:.3f},ms"
        )
        print(f"{name}_p95_token_latency,{m['p95_token_latency_s'] * 1e3:.3f},ms")
    print(f"token_parity,{int(parity)},bool")

    payload = {
        "alternating": alt, "mixed": mix,
        "token_parity": bool(parity),
        "arrival_round_time_s": round_s,
        "stall_removed_s": alt["prefill_stall_time_s"] - mix["prefill_stall_time_s"],
    }
    path = emit_json("mixed_batch", payload, smoke=args.smoke, out_dir=args.out)
    print(f"# wrote {path}")
    if not parity:
        raise SystemExit("token parity violated between scheduling modes")
    if mix["prefill_stall_time_s"] != 0.0:
        raise SystemExit("mixed mode accumulated prefill stall time")
    if alt["prefill_stall_time_s"] <= 0.0:
        raise SystemExit(
            "alternating mode saw no prefill stall — workload too sparse "
            "to exercise the comparison"
        )


if __name__ == "__main__":
    main()
