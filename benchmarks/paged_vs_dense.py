"""Dense-slot vs paged+chunked engine on a mixed prompt-length workload.

Measures what the paged refactor actually buys on the serving hot path:

  * throughput — output tokens / s of engine wall-clock (the dense path pays
    a fresh ``cache_init`` + padded full-row scatter per prefill stage; the
    paged path writes chunks straight into pages);
  * peak KV memory — dense preallocates n_slots × max_len rows no matter
    what the slots hold; paged allocates pages-in-use.

The mixed workload (short conversational prompts next to long-document
prompts, short replies) is the shape the dense layout over-allocates worst
on — every 30-token prompt still owns a max_len row.

Run: PYTHONPATH=src python -m benchmarks.paged_vs_dense [--smoke] [--out DIR]
Prints ``name,value,unit`` CSV and writes BENCH_paged_vs_dense.json.
"""
from __future__ import annotations

import argparse

from repro.configs.base import ArchConfig
from repro.data import WorkloadSpec

from .bench_io import emit_json, run_serving_benchmark

FULL = dict(
    arch=ArchConfig(
        name="bench", family="dense", n_layers=2, d_model=128, n_heads=8,
        n_kv_heads=4, d_ff=256, vocab_size=512,
    ),
    # mixed prompt lengths: N(60, 45) clipped to [1, 180], short outputs
    spec=WorkloadSpec(
        n_requests=24, input_mean=60, input_std=45, output_mean=12,
        output_std=6, output_max=20, input_max=180,
    ),
    n_slots=8, max_len=208, seq_buckets=(64, 128, 192),
    level_caps=(64, 128, 256), prefill_chunk=48,
)
SMOKE = dict(
    arch=ArchConfig(
        name="bench-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256,
    ),
    spec=WorkloadSpec(
        n_requests=8, input_mean=24, input_std=16, output_mean=8,
        output_std=4, output_max=12, input_max=56,
    ),
    n_slots=4, max_len=80, seq_buckets=(32, 64),
    level_caps=(32, 64, 128), prefill_chunk=24,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (seconds, not minutes)")
    ap.add_argument("--out", default=None, help="directory for BENCH_*.json")
    args = ap.parse_args()
    cfg = SMOKE if args.smoke else FULL

    eng_d, dense, _ = run_serving_benchmark(cfg, kv_layout="dense")
    eng_p, paged, _ = run_serving_benchmark(
        cfg, kv_layout="paged", page_size=16,
        prefill_chunk=cfg["prefill_chunk"],
    )
    parity = all(
        eng_d.generated[r] == eng_p.generated[r] for r in eng_d.generated
    )
    print("name,value,unit")
    for name, m in (("dense", dense), ("paged", paged)):
        print(f"{name}_throughput,{m['throughput_tok_s']:.1f},tok/s")
        print(f"{name}_kv_capacity,{m['kv_capacity_bytes']},bytes")
        print(f"{name}_kv_peak,{m['peak_kv_bytes']},bytes")
        print(f"{name}_dispatches_per_token,{m['dispatches_per_token']:.4f},1/tok")
        print(f"{name}_p50_token_latency,{m['p50_token_latency_s'] * 1e3:.3f},ms")
        print(f"{name}_p95_token_latency,{m['p95_token_latency_s'] * 1e3:.3f},ms")
    print(f"token_parity,{int(parity)},bool")
    kv_ratio = paged["peak_kv_bytes"] / dense["peak_kv_bytes"]
    print(f"kv_peak_ratio,{kv_ratio:.3f},paged/dense")

    payload = {
        "dense": dense, "paged": paged,
        "token_parity": bool(parity), "kv_peak_ratio": kv_ratio,
    }
    path = emit_json("paged_vs_dense", payload, smoke=args.smoke, out_dir=args.out)
    print(f"# wrote {path}")
    if not parity:
        raise SystemExit("token parity violated between layouts")


if __name__ == "__main__":
    main()
