"""Dense-slot vs paged+chunked engine on a mixed prompt-length workload.

Measures what the paged refactor actually buys on the serving hot path:

  * throughput — output tokens / s of engine wall-clock (the dense path pays
    a fresh ``cache_init`` + padded full-row scatter per prefill stage; the
    paged path writes chunks straight into pages);
  * peak KV memory — dense preallocates n_slots × max_len rows no matter
    what the slots hold; paged allocates pages-in-use.

The mixed workload (short conversational prompts next to long-document
prompts, short replies) is the shape the dense layout over-allocates worst
on — every 30-token prompt still owns a max_len row.

Run: PYTHONPATH=src python -m benchmarks.paged_vs_dense
Prints ``name,value,unit`` CSV.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import CostModel, GlobalQueueScheduler, PrefillFirstPolicy, build_clients
from repro.data import WorkloadSpec, gsm8k_like_workload
from repro.models.layers import init_params
from repro.models.transformer import TransformerLM
from repro.serving.engine import Engine, EngineConfig

ARCH = ArchConfig(
    name="bench", family="dense", n_layers=2, d_model=128, n_heads=8,
    n_kv_heads=4, d_ff=256, vocab_size=512,
)
# mixed prompt lengths: N(60, 45) clipped to [1, 180], short outputs
SPEC = WorkloadSpec(
    n_requests=24, input_mean=60, input_std=45, output_mean=12,
    output_std=6, output_max=20, input_max=180,
)
N_SLOTS, MAX_LEN = 8, 208
CM = CostModel(level_caps=(64, 128, 256))


def _run(layout: str, **kw):
    model = TransformerLM(ARCH)
    params = init_params(jax.random.key(0), model.param_defs())
    reqs = gsm8k_like_workload(SPEC, seed=11, known_lengths=True)
    eng = Engine(
        model, params,
        EngineConfig(
            n_slots=N_SLOTS, max_len=MAX_LEN,
            prefill_seq_buckets=(64, 128, 192), kv_layout=layout, **kw,
        ),
    )
    eng.profiler.cost_model = CM
    clients = build_clients(N_SLOTS, reqs, None)
    # warm the jit caches so compile time doesn't pollute the comparison
    warm = gsm8k_like_workload(SPEC, seed=12, known_lengths=True)
    eng.serve(warm, build_clients(N_SLOTS, warm, None),
              GlobalQueueScheduler(warm), PrefillFirstPolicy())
    t0 = time.perf_counter()
    trace = eng.serve(reqs, clients, GlobalQueueScheduler(reqs), PrefillFirstPolicy())
    wall = time.perf_counter() - t0
    trace.validate()
    out_tokens = sum(r.n_decode for r in reqs)
    if layout == "paged":
        peak = eng.slots.peak_kv_bytes()
        cap = eng.slots.kv_bytes_capacity()
    else:
        peak = cap = eng.slots.cache["k"].nbytes + eng.slots.cache["v"].nbytes
    return eng, {
        "throughput_tok_s": out_tokens / wall,
        "wall_s": wall,
        "kv_capacity_bytes": cap,
        "kv_peak_bytes": peak,
    }


def main() -> None:
    eng_d, dense = _run("dense")
    eng_p, paged = _run("paged", page_size=16, prefill_chunk=48)
    parity = all(
        eng_d.generated[r] == eng_p.generated[r] for r in eng_d.generated
    )
    print("name,value,unit")
    for name, m in (("dense", dense), ("paged", paged)):
        print(f"{name}_throughput,{m['throughput_tok_s']:.1f},tok/s")
        print(f"{name}_kv_capacity,{m['kv_capacity_bytes']},bytes")
        print(f"{name}_kv_peak,{m['kv_peak_bytes']},bytes")
    print(f"token_parity,{int(parity)},bool")
    print(
        "kv_peak_ratio,"
        f"{paged['kv_peak_bytes'] / dense['kv_peak_bytes']:.3f},paged/dense"
    )


if __name__ == "__main__":
    main()
