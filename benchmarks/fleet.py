"""Fleet serving: offline bin-packed LPT+local-search assignment vs FCFS
round-robin, on REAL multi-replica engines.

This is the paper's offline-vs-baseline utilization study (Fig. 6) lifted
from the event-driven simulator to actual jitted execution: N ``Engine``
replicas (shared weights, independent KV pools) serve the same
skewed-length workload twice —

  * ``round_robin`` — ``round_robin_assign`` partitions the backlog,
    arrivals route round-robin, no work stealing (the unbalanced baseline);
  * ``lpt`` — ``solve_offline`` (LPT + local search) partitions, arrivals
    route least-estimated-load through the shared cost model, and drained
    replicas steal queued work from stragglers (the full hybrid).

Both closed-loop (everything available at t=0) and Poisson-arrival
workloads run. The skew is adversarial for round-robin by construction:
decode-heavy requests sit at every other queue position, so round-robin
piles all of them onto one replica while LPT spreads them — exactly the
failure mode the paper's offline model exists to prevent.

Hard-fail signals (stable on CPU): exact per-request token parity between
the two assignments (replica placement must never change results), and
LPT strictly beating round-robin on closed-loop fleet makespan AND fleet
utilization. Wall-clock magnitudes and the lower-bound ratio are reported,
not asserted (they move with machine load); the fleet utilization is
validated structurally (0 < util ≤ 1) and against
``theoretical_lower_bound`` at n_clients = replicas × slots via the
online-fitted cost model.

Run:  PYTHONPATH=src python -m benchmarks.fleet [--smoke] [--out DIR]
Prints ``name,value,unit`` CSV and writes BENCH_fleet.json.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

FULL = dict(
    model=dict(n_layers=2, d_model=128, n_heads=8, n_kv_heads=4, d_ff=256,
               vocab_size=512),
    n_replicas=2, n_slots=4, max_len=128, seq_buckets=(32,),
    level_caps=(64, 128, 256), page_size=16, prefill_chunk=32,
    n_long=4, long_prefill=24, long_decode=96,
    n_short=12, short_prefill=16, short_decode=8,
    arrival_rounds=1.5,
)
SMOKE = dict(
    model=dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
               vocab_size=256),
    n_replicas=2, n_slots=2, max_len=64, seq_buckets=(32,),
    level_caps=(32, 64, 128), page_size=16, prefill_chunk=16,
    n_long=3, long_prefill=12, long_decode=32,
    n_short=5, short_prefill=8, short_decode=5,
    arrival_rounds=1.5,
)


def _skewed_workload(cfg, seed: int, arrivals=None):
    """Skewed lengths with long requests at round-robin-adversarial
    positions (every other slot in rid order): round-robin assignment over
    2 replicas sends every long request to replica 0."""
    from repro.core import Request

    rng = np.random.default_rng(seed)
    reqs = []
    n_total = cfg["n_long"] + cfg["n_short"]
    longs_placed = 0
    for rid in range(n_total):
        if rid % 2 == 0 and longs_placed < cfg["n_long"]:
            p = cfg["long_prefill"] + int(rng.integers(0, 4))
            d = cfg["long_decode"] + int(rng.integers(0, 4))
            longs_placed += 1
        else:
            p = cfg["short_prefill"] + int(rng.integers(0, 4))
            d = cfg["short_decode"] + int(rng.integers(0, 3))
        reqs.append(Request(rid=rid, n_prefill=p, n_decode=d))
    if arrivals is not None:
        for r, a in zip(reqs, arrivals):
            r.arrival = float(a)
    return reqs


def _build_fleet(cfg, model, params, fleet_kind: str):
    from repro.core import CostModel
    from repro.serving.engine import EngineConfig
    from repro.serving.fleet import Fleet, FleetConfig

    if fleet_kind == "lpt":
        fc = FleetConfig(
            n_replicas=cfg["n_replicas"], assign="lpt",
            dispatch="least_load", work_stealing=True,
        )
    else:
        fc = FleetConfig(
            n_replicas=cfg["n_replicas"], assign="round_robin",
            dispatch="round_robin", work_stealing=False,
        )
    ecfg = EngineConfig(
        n_slots=cfg["n_slots"], max_len=cfg["max_len"],
        prefill_seq_buckets=cfg["seq_buckets"],
        kv_layout="paged", page_size=cfg["page_size"],
        prefill_chunk=cfg["prefill_chunk"],
    )
    return Fleet(
        model, params, ecfg, fc,
        cost_model=CostModel(level_caps=cfg["level_caps"]),
    )


def _fleet_metrics(report, wall_s: float):
    from .bench_io import fleet_recovery_metrics

    s = report.summary()
    return {
        "makespan_s": s["makespan_s"],
        "fleet_utilization": s["fleet_utilization"],
        "busy_window_utilization": s["busy_window_utilization"],
        "generation_speed_tok_s": s["generation_speed_tok_s"],
        "steal_events": s["steal_events"],
        "offline_solver": s["offline_solver"],
        "offline_gap": s["offline_gap"],
        "replica_makespans_s": s["replica_makespans_s"],
        "replica_requests": s["replica_requests"],
        "lb_ratio_initial_cm": s["lb_ratio"],
        "wall_s": wall_s,
        **fleet_recovery_metrics(report),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (seconds, not minutes)")
    ap.add_argument("--out", default=None, help="directory for BENCH_*.json")
    args = ap.parse_args()
    cfg = SMOKE if args.smoke else FULL

    import jax

    from repro.configs.base import ArchConfig
    from repro.core import LagrangianPolicy
    from repro.core.gantt import fleet_ascii_gantt
    from repro.core.offline import theoretical_lower_bound
    from repro.models.layers import init_params
    from repro.models.transformer import TransformerLM

    from .bench_io import emit_json

    arch = ArchConfig(name="fleet-bench", family="dense", **cfg["model"])
    model = TransformerLM(arch)
    params = init_params(jax.random.key(0), model.param_defs())

    fleets = {k: _build_fleet(cfg, model, params, k) for k in ("round_robin", "lpt")}
    # warm pass: same-shape workload compiles every jit variant each replica
    # can reach, so no compile lands inside a measured serve
    for fleet in fleets.values():
        fleet.serve(_skewed_workload(cfg, seed=21), LagrangianPolicy)
        fleet.warm_serving_shapes()

    # ---- closed-loop: the paper's offline utilization study -------------- #
    closed = {}
    for kind, fleet in fleets.items():
        reqs = _skewed_workload(cfg, seed=11)
        t0 = time.perf_counter()
        report = fleet.serve(reqs, LagrangianPolicy)
        wall = time.perf_counter() - t0
        closed[kind] = (fleet.generated, report, _fleet_metrics(report, wall))
    print(fleet_ascii_gantt(closed["round_robin"][1], width=72))
    print(fleet_ascii_gantt(closed["lpt"][1], width=72))

    # lower-bound validation against a cost model measured on THIS machine:
    # stage-duration medians from the measured traces (robust to the
    # outliers a least-squares fit of sub-millisecond CPU stages absorbs).
    # decode_overhead = median per-round time, decode_per_token = 0 makes
    # every bound term a pure round count × measured round time.
    from repro.core import CostModel

    lpt_stages = [s for t in closed["lpt"][1].traces for s in t.stages]
    round_samples = [
        s.duration / max(s.rounds, 1)
        for s in lpt_stages if s.kind.value in ("decode", "mixed")
    ]
    prefill_samples = [
        s.duration / s.tokens
        for s in lpt_stages if s.kind.value == "prefill" and s.tokens > 0
    ] or [0.0]
    cm_lb = CostModel(
        prefill_per_token=float(np.median(prefill_samples)),
        prefill_overhead=0.0,
        decode_per_token=0.0,
        decode_overhead=float(np.median(round_samples)),
        level_caps=cfg["level_caps"],
    )
    reqs_lb = _skewed_workload(cfg, seed=11)
    lb = theoretical_lower_bound(
        reqs_lb, cfg["n_replicas"] * cfg["n_slots"], cm_lb
    )
    lb_ratio = (
        closed["lpt"][2]["makespan_s"] / lb.total if lb.total > 0 else float("inf")
    )

    # ---- Poisson arrivals: online replica dispatch ----------------------- #
    # arrival spacing scales with the same measured round time the lower
    # bound uses, so traffic intensity is machine-independent
    round_s = float(np.median(round_samples))
    rng = np.random.default_rng(123)
    n_total = cfg["n_long"] + cfg["n_short"]
    gaps = rng.exponential(cfg["arrival_rounds"] * round_s, size=n_total)
    arrivals = np.cumsum(gaps)
    poisson = {}
    for kind, fleet in fleets.items():
        reqs = _skewed_workload(cfg, seed=11, arrivals=arrivals)
        t0 = time.perf_counter()
        report = fleet.serve(reqs, LagrangianPolicy)
        wall = time.perf_counter() - t0
        poisson[kind] = (fleet.generated, report, _fleet_metrics(report, wall))

    # ---- parity: replica placement must never change tokens -------------- #
    reference = closed["lpt"][0]
    parity = True
    for group in (closed, poisson):
        for kind, (gen, _, _) in group.items():
            parity &= gen.keys() == reference.keys() and all(
                gen[r] == reference[r] for r in reference
            )

    print("name,value,unit")
    for loop, group in (("closed", closed), ("poisson", poisson)):
        for kind, (_, _, m) in group.items():
            print(f"{loop}_{kind}_makespan,{m['makespan_s']:.4f},s")
            print(f"{loop}_{kind}_fleet_utilization,{m['fleet_utilization']:.4f},frac")
            print(
                f"{loop}_{kind}_busy_window_utilization,"
                f"{m['busy_window_utilization']:.4f},frac"
            )
            print(f"{loop}_{kind}_speed,{m['generation_speed_tok_s']:.1f},tok/s")
            print(f"{loop}_{kind}_steals,{m['steal_events']},events")
    print(f"token_parity,{int(parity)},bool")
    print(f"lb_ratio_measured,{lb_ratio:.3f},x")

    payload = {
        "closed_loop": {k: v[2] for k, v in closed.items()},
        "poisson": {k: v[2] for k, v in poisson.items()},
        "token_parity": bool(parity),
        "lower_bound_measured_s": lb.total,
        "lb_ratio_measured": lb_ratio,
        "arrival_round_time_s": round_s,
    }
    path = emit_json("fleet", payload, smoke=args.smoke, out_dir=args.out)
    print(f"# wrote {path}")

    # ---- hard-fail gates (stable signals only) --------------------------- #
    if not parity:
        raise SystemExit(
            "token parity violated: replica assignment changed results"
        )
    rr, lpt = closed["round_robin"][2], closed["lpt"][2]
    if not lpt["makespan_s"] < rr["makespan_s"]:
        raise SystemExit(
            f"ordering violated: LPT makespan {lpt['makespan_s']:.3f}s not "
            f"strictly below round-robin {rr['makespan_s']:.3f}s"
        )
    if not lpt["fleet_utilization"] > rr["fleet_utilization"]:
        raise SystemExit(
            f"ordering violated: LPT fleet utilization "
            f"{lpt['fleet_utilization']:.4f} not strictly above round-robin "
            f"{rr['fleet_utilization']:.4f}"
        )
    for loop, group in (("closed", closed), ("poisson", poisson)):
        for kind, (_, _, m) in group.items():
            if not 0.0 < m["fleet_utilization"] <= 1.0 + 1e-9:
                raise SystemExit(
                    f"{loop}/{kind} fleet utilization out of range: "
                    f"{m['fleet_utilization']}"
                )
    if lb_ratio < 0.25:
        # the measured makespan landing FAR below a bound built from the
        # same traces' own stage-time medians means the accounting broke —
        # that is structural, not wall-clock noise. (Ratios modestly under
        # 1.0 are legitimate: fused-horizon decode amortizes dispatch cost
        # below the per-round median the bound charges, especially at the
        # smoke scale.)
        raise SystemExit(
            f"fleet makespan implausibly beats the measured lower bound "
            f"(ratio {lb_ratio:.3f})"
        )


if __name__ == "__main__":
    main()
