"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth).

These share the masking semantics with ``repro.models.attention`` — the
kernels and the model reference path are validated against the same math.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def flash_attention_ref(
    q: jax.Array,                   # (B, H, Sq, D)
    k: jax.Array,                   # (B, KV, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, kv, sk, _ = k.shape
    g = h // kv
    scale = scale if scale is not None else d ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(b, kv, g, sq, d)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, k.astype(jnp.float32))
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = jnp.logical_and(mask, kpos <= qpos)
    if window > 0:
        mask = jnp.logical_and(mask, qpos - kpos < window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, sq, d).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,                   # (B, H, D)
    k: jax.Array,                   # (B, KV, S, D)
    v: jax.Array,
    lengths: jax.Array,             # (B,)
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    b, h, d = q.shape
    _, kv, s, _ = k.shape
    g = h // kv
    scale = scale if scale is not None else d ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(b, kv, g, d)
    sc = jnp.einsum("bkgd,bksd->bkgs", qf, k.astype(jnp.float32))
    valid = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
    sc = jnp.where(valid, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def paged_decode_attention_ref(
    q: jax.Array,                   # (B, H, D)
    k_pages: jax.Array,             # (KV, P, bs, D) page pool
    v_pages: jax.Array,
    block_tables: jax.Array,        # (B, MB) int32; -1 = unallocated
    lengths: jax.Array,             # (B,)
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    """Gather each slot's pages into a dense (B, KV, MB·bs, D) cache view and
    defer to the dense decode oracle — unallocated pages read page 0, which
    the length mask then hides (allocated pages always cover ``lengths``)."""
    kv, p, bs, d = k_pages.shape
    b, mb = block_tables.shape
    idx = jnp.arange(mb * bs)
    page = block_tables[:, idx // bs]                        # (B, MB·bs)
    flat = jnp.where(page >= 0, page * bs + idx % bs, 0).reshape(-1)
    k = k_pages.reshape(kv, p * bs, d)[:, flat].reshape(kv, b, mb * bs, d)
    v = v_pages.reshape(kv, p * bs, d)[:, flat].reshape(kv, b, mb * bs, d)
    return decode_attention_ref(
        q, jnp.swapaxes(k, 0, 1), jnp.swapaxes(v, 0, 1), lengths, scale=scale
    )


def rglru_scan_ref(
    a: jax.Array,                   # (B, S, R)
    x: jax.Array,
    h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    b, s, r = a.shape
    if h0 is None:
        h0 = jnp.zeros((b, r), jnp.float32)

    def step(h, inp):
        a_t, x_t = inp
        h = a_t * h + x_t
        return h, h

    h_fin, hs = jax.lax.scan(
        step,
        h0.astype(jnp.float32),
        (
            jnp.swapaxes(a.astype(jnp.float32), 0, 1),
            jnp.swapaxes(x.astype(jnp.float32), 0, 1),
        ),
    )
    return jnp.swapaxes(hs, 0, 1), h_fin
