"""Paged decode attention — Pallas TPU kernel for single-token GQA attention
against a block-table-indirected page pool.

The dense decode kernel streams a slot's whole (S_max, D) cache row; here a
slot's KV lives scattered across pages of a shared pool and the kernel
gathers them by *DMA indirection*: the block table rides in scalar-prefetch
memory (SMEM), so the K/V BlockSpec index maps can read it and point each
grid step's page DMA at the right pool row — the physical-page gather costs
zero extra copies.

Grid = (B, KV, pages_per_slot), page dim innermost/sequential so the online
softmax scratch carries across a slot's pages (same structure as
``decode_attention``). Pages past a slot's fill level are skipped with
``pl.when`` (their DMA index clamps to page 0); the tail page is masked
per-token against ``lengths``.

Page layout is (KV, P, page_size, D): the per-step block is a contiguous
(page_size, D) tile — sublane-aligned for page_size ≥ 8, unlike a layout
with KV innermost whose (1, D) rows would waste 7/8 sublanes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _paged_decode_kernel(
    tables_ref,                     # (B, MB) int32 SMEM — scalar prefetch
    length_ref,                     # (B,) int32 SMEM — scalar prefetch
    q_ref,                          # (1, 1, g, D)
    k_ref,                          # (1, 1, bs, D) — one page
    v_ref,
    o_ref,                          # (1, 1, g, D)
    acc_ref, m_ref, l_ref,
    *,
    scale: float,
    page_size: int,
    pages_per_slot: int,
):
    ib = pl.program_id(0)
    ij = pl.program_id(2)

    @pl.when(ij == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = length_ref[ib]
    page = tables_ref[ib, ij]
    k_start = ij * page_size

    @pl.when(jnp.logical_and(k_start < length, page >= 0))
    def _body():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale      # (g, D)
        k = k_ref[0, 0, :, :].astype(jnp.float32)              # (bs, D)
        v = v_ref[0, 0, :, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                      # (g, bs)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ij == pages_per_slot - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention(
    q: jax.Array,                   # (B, H, D) — one new token per slot
    k_pages: jax.Array,             # (KV, P, bs, D) page pool
    v_pages: jax.Array,
    block_tables: jax.Array,        # (B, MB) int32; -1 = unallocated
    lengths: jax.Array,             # (B,) int32 — valid tokens per slot
    *,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    b, h, d = q.shape
    kv, p, bs, _ = k_pages.shape
    _, mb = block_tables.shape
    if h % kv != 0:
        raise ValueError(f"H={h} not divisible by KV={kv}")
    g = h // kv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, kv, g, d)
    tables = block_tables.astype(jnp.int32)
    lens = lengths.astype(jnp.int32)

    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, page_size=bs, pages_per_slot=mb
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kv, mb),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda ib, ih, ij, tb, ln: (ib, ih, 0, 0)),
            # page DMA indirection: the block index along the pool axis is
            # the block table entry itself (clamped for unallocated pages,
            # whose grid steps the kernel skips)
            pl.BlockSpec(
                (1, 1, bs, d),
                lambda ib, ih, ij, tb, ln: (ih, jnp.maximum(tb[ib, ij], 0), 0, 0),
            ),
            pl.BlockSpec(
                (1, 1, bs, d),
                lambda ib, ih, ij, tb, ln: (ih, jnp.maximum(tb[ib, ij], 0), 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, d), lambda ib, ih, ij, tb, ln: (ib, ih, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, d), q.dtype),
        interpret=interpret,
    )(tables, lens, qg, k_pages, v_pages)
    return out.reshape(b, h, d)
