"""RG-LRU linear recurrence — Pallas TPU kernel.

The RecurrentGemma recurrence h_t = a_t ⊙ h_{t-1} + x_t is a per-channel
linear scan: embarrassingly parallel across channels, strictly sequential in
time. The jnp baseline lowers to a length-S ``lax.scan`` whose per-step work
(element-wise over R channels) is far too small to hide HBM latency — the
kernel instead:

  * blocks channels over the grid (each grid step owns R_blk channels,
    VPU-lane-aligned at 128), and
  * streams S_blk × R_blk tiles of (a, x) into VMEM, scanning time *inside*
    the block with the carry in a VMEM scratch register — one DMA per tile
    instead of one per step (S_blk× fewer round trips).

Grid = (B, R_blocks, S_blocks); S innermost/sequential so the carry flows.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(
    a_ref,                           # (1, bs, br) f32 decay
    x_ref,                           # (1, bs, br) f32 gated input
    h0_ref,                          # (1, br) f32 initial state
    o_ref,                           # (1, bs, br)
    hN_ref,                          # (1, br) final state
    carry_ref,                       # VMEM scratch (br,)
    *,
    block_s: int,
    num_s_blocks: int,
):
    is_ = pl.program_id(2)

    @pl.when(is_ == 0)
    def _init():
        carry_ref[...] = h0_ref[0, :]

    a = a_ref[0, :, :]               # (bs, br)
    x = x_ref[0, :, :]

    def step(t, h):
        h_new = a[t, :] * h + x[t, :]
        o_ref[0, t, :] = h_new.astype(o_ref.dtype)
        return h_new

    h = jax.lax.fori_loop(0, block_s, step, carry_ref[...])
    carry_ref[...] = h

    @pl.when(is_ == num_s_blocks - 1)
    def _finish():
        hN_ref[0, :] = h.astype(hN_ref.dtype)


def rglru_scan(
    a: jax.Array,                    # (B, S, R) decay in (0,1)
    x: jax.Array,                    # (B, S, R) gated input
    h0: Optional[jax.Array] = None,  # (B, R)
    *,
    block_s: int = 256,
    block_r: int = 128,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (h (B,S,R), h_final (B,R)), all f32."""
    b, s, r = a.shape
    if h0 is None:
        h0 = jnp.zeros((b, r), jnp.float32)
    bs = min(block_s, s)
    br = min(block_r, r)
    if s % bs or r % br:
        raise ValueError(f"(S,R)=({s},{r}) must divide blocks ({bs},{br})")
    ns, nr = s // bs, r // br

    kernel = functools.partial(_rglru_kernel, block_s=bs, num_s_blocks=ns)
    out, h_fin = pl.pallas_call(
        kernel,
        grid=(b, nr, ns),
        in_specs=[
            pl.BlockSpec((1, bs, br), lambda ib, ir, is_: (ib, is_, ir)),
            pl.BlockSpec((1, bs, br), lambda ib, ir, is_: (ib, is_, ir)),
            pl.BlockSpec((1, br), lambda ib, ir, is_: (ib, ir)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, br), lambda ib, ir, is_: (ib, is_, ir)),
            pl.BlockSpec((1, br), lambda ib, ir, is_: (ib, ir)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, r), jnp.float32),
            jax.ShapeDtypeStruct((b, r), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((br,), jnp.float32)],
        interpret=interpret,
    )(a.astype(jnp.float32), x.astype(jnp.float32), h0.astype(jnp.float32))
    return out, h_fin
