"""Decode attention — Pallas TPU kernel for single-token GQA attention
against a slot KV cache.

One grid step handles one (batch-slot, kv-head) pair and one cache block:
grid = (B, KV, cache_blocks), cache_blocks innermost/sequential. The g query
heads of the kv head ride together as the MXU's M dim: scores are (g, bk) —
for small g this underfills the MXU's 128 rows, which is exactly the
batching argument the paper's decode cost model encodes (decode is
bandwidth-bound; the roofline confirms it). Online softmax in VMEM scratch,
one (g, D) output tile per (slot, kv-head).

Valid-length masking reads ``length`` (B,1) from a tiny per-slot block —
slots in a continuous-batching engine have ragged fill levels.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _decode_kernel(
    length_ref,                     # (1, 1) int32
    q_ref,                          # (1, 1, g, D)
    k_ref,                          # (1, 1, bk, D)
    v_ref,
    o_ref,                          # (1, 1, g, D)
    acc_ref, m_ref, l_ref,
    *,
    scale: float,
    block_k: int,
    num_k_blocks: int,
):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = length_ref[0, 0]
    k_start = ik * block_k

    @pl.when(k_start < length)
    def _body():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale      # (g, D)
        k = k_ref[0, 0, :, :].astype(jnp.float32)              # (bk, D)
        v = v_ref[0, 0, :, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                      # (g, bk)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,                   # (B, H, D) — one new token per slot
    k: jax.Array,                   # (B, KV, S, D) slot cache
    v: jax.Array,
    lengths: jax.Array,             # (B,) int32 — valid entries per slot
    *,
    scale: Optional[float] = None,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, h, d = q.shape
    _, kv, s, _ = k.shape
    if h % kv != 0:
        raise ValueError(f"H={h} not divisible by KV={kv}")
    g = h // kv
    scale = scale if scale is not None else d ** -0.5
    bk = min(block_k, s)
    nk = -(-s // bk)                       # grid rounds up; tail block masked
    if s % bk:
        # pad K/V so the tail block's DMA stays in bounds; the padded
        # region sits at positions >= s >= lengths, so the kernel's
        # per-token length mask already hides it. The pad is a full-cache
        # copy per call — serving paths should keep bucketed cache lengths
        # a multiple of block_k (the engine's max_len buckets are); this
        # branch exists so ad-hoc lengths work instead of erroring
        pad = nk * bk - s
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qg = q.reshape(b, kv, g, d)
    len2 = lengths.reshape(b, 1).astype(jnp.int32)

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=bk, num_k_blocks=nk
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, kv, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda ib, ih, ik: (ib, 0)),
            pl.BlockSpec((1, 1, g, d), lambda ib, ih, ik: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, ik: (ib, ih, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, ik: (ib, ih, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda ib, ih, ik: (ib, ih, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
        interpret=interpret,
    )(len2, qg, k, v)
    return out.reshape(b, h, d)
