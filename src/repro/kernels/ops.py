"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute through Pallas interpret mode —
numerically identical, used by tests. On TPU the same call sites compile the
real kernels. ``use_pallas=`` flags in the model zoo route through these.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention as _decode
from .flash_attention import flash_attention as _flash
from .paged_decode_attention import paged_decode_attention as _paged_decode
from .rglru import rglru_scan as _rglru


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "block_q", "block_k")
)
def flash_attention(
    q, k, v, *, causal: bool = True, window: int = 0,
    scale: Optional[float] = None, block_q: int = 128, block_k: int = 128,
):
    """(B, H, Sq, D) × (B, KV, Sk, D) → (B, H, Sq, D)."""
    return _flash(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, interpret=_on_cpu(),
    )


@functools.partial(jax.jit, static_argnames=("scale", "block_k"))
def decode_attention(q, k, v, lengths, *, scale: Optional[float] = None,
                     block_k: int = 256):
    """(B, H, D) one token vs (B, KV, S, D) cache → (B, H, D)."""
    return _decode(
        q, k, v, lengths, scale=scale, block_k=block_k, interpret=_on_cpu()
    )


@functools.partial(jax.jit, static_argnames=("scale",))
def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths, *,
                           scale: Optional[float] = None):
    """(B, H, D) one token vs (KV, P, bs, D) page pool gathered through a
    (B, MB) block table → (B, H, D)."""
    return _paged_decode(
        q, k_pages, v_pages, block_tables, lengths, scale=scale,
        interpret=_on_cpu(),
    )


@functools.partial(jax.jit, static_argnames=("block_s", "block_r"))
def rglru_scan(a, x, h0=None, *, block_s: int = 256, block_r: int = 128
               ) -> Tuple[jax.Array, jax.Array]:
    """Linear recurrence h_t = a_t·h_{t-1} + x_t over (B, S, R)."""
    return _rglru(a, x, h0, block_s=block_s, block_r=block_r, interpret=_on_cpu())
