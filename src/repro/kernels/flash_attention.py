"""Flash attention (prefill) — Pallas TPU kernel.

TPU adaptation of FlashAttention (the paper's §II kernel-fusion foundation):
KV blocks stream HBM→VMEM while an online-softmax accumulator lives in VMEM
scratch (f32, VREG-friendly); the (bq × bk) score tile feeds the MXU with
128-aligned dims. Grid = (batch, q_head, q_blocks, k_blocks) with the
k_blocks dim innermost and sequential — TPU grids execute in order, so the
scratch accumulator carries across k steps and the output tile is written
once on the last k step.

Causal + sliding-window masking is position-based (matches
``models.attention``); fully-masked k blocks are skipped with ``pl.when``
(compute skipped; the block DMA still happens — acceptable because masked
blocks are the minority under the bq≈bk blocking and the DMA pipeline hides
them).

GQA: q heads map onto kv heads via integer division in the kv index_map.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_kernel(
    q_ref, k_ref, v_ref,            # VMEM blocks
    o_ref,                          # output block
    acc_ref, m_ref, l_ref,          # VMEM scratch
    *,
    scale: float,
    causal: bool,
    window: int,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    # block-level skip decisions (static per grid step)
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    run = True
    if causal:
        # whole block above the diagonal → nothing to do
        run = jnp.logical_and(True, k_start <= q_start + block_q - 1)
    if window > 0:
        # whole block left of the window → nothing to do
        run = jnp.logical_and(run, q_start - (k_start + block_k - 1) < window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale     # (bq, d)
        k = k_ref[0, 0, :, :].astype(jnp.float32)             # (bk, d)
        v = v_ref[0, 0, :, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                     # (bq, bk)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,                   # (B, H, Sq, D)
    k: jax.Array,                   # (B, KV, Sk, D)
    v: jax.Array,                   # (B, KV, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, kv, sk, _ = k.shape
    if h % kv != 0:
        raise ValueError(f"H={h} not divisible by KV={kv}")
    g = h // kv
    scale = scale if scale is not None else d ** -0.5
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    if sq % bq or sk % bk:
        raise ValueError(f"seq ({sq},{sk}) must divide blocks ({bq},{bk})")
    nq, nk = sq // bq, sk // bk

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=bq,
        block_k=bk,
        num_k_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, iq, ik, g=g: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, iq, ik, g=g: (ib, ih // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
