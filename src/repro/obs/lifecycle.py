"""Per-request timelines and the capacity-attribution rollup.

``request_timelines`` groups the span log into one ordered event list per
request; ``lifecycle_table`` renders them human-readable. The heavier
artifact is ``capacity_attribution``: every slot-second of every replica's
makespan classified into exactly one of

    busy · cache_hit · preempted · stall · migration · idle_gap

The engine emits one capacity sample per executed stage (each of its
``n_slots`` slots contributes exactly the stage duration to exactly one
class), and the time *between* stages — arrival fast-forwards, drained
tails — is attributed to ``idle_gap`` as the residual against
``makespan × n_slots``. The rollup therefore sums exactly to
makespan × slots per replica **by construction**, and
``check_capacity_conservation`` hard-fails if the per-stage samples ever
overrun the replica's capacity (which would make the residual negative).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

CAPACITY_CLASSES = (
    "busy", "cache_hit", "preempted", "stall", "migration", "idle_gap",
)


def request_timelines(obs) -> Dict[int, list]:
    """Ordered span events per request id (fleet instants excluded)."""
    out: Dict[int, list] = {}
    for ev in obs.spans.events:
        if ev.rid < 0:
            continue
        out.setdefault(ev.rid, []).append(ev)
    for evs in out.values():
        evs.sort(key=lambda e: (e.t, e.event_id))
    return out


def lifecycle_table(obs, rids: Optional[Sequence[int]] = None) -> str:
    """Render per-request timelines as an aligned text table."""
    timelines = request_timelines(obs)
    if rids is None:
        rids = sorted(timelines.keys())
    lines = [f"{'rid':>5s}  {'t(s)':>9s}  {'replica':>7s}  {'slot':>4s}  event"]
    for rid in rids:
        for ev in timelines.get(rid, []):
            slot = "-" if ev.slot is None else str(ev.slot)
            extra = ""
            if ev.attrs:
                extra = "  " + " ".join(
                    f"{k}={v}" for k, v in sorted(ev.attrs.items())
                )
            lines.append(
                f"{rid:5d}  {ev.t:9.4f}  {ev.replica:7d}  {slot:>4s}  "
                f"{ev.kind}{extra}"
            )
    return "\n".join(lines)


def capacity_attribution(obs) -> Dict[int, Dict[str, float]]:
    """Per-replica slot-seconds by class, summing to makespan × slots.

    Requires the serve to have finished (``finish_replica`` recorded each
    replica's makespan and slot count). Raises if per-stage attribution
    exceeds the replica's total capacity beyond float tolerance.
    """
    rows: Dict[int, Dict[str, float]] = {}
    for replica, info in obs.replicas.items():
        rows[replica] = {c: 0.0 for c in CAPACITY_CLASSES}
        rows[replica]["makespan_s"] = info["makespan"]
        rows[replica]["n_slots"] = float(info["n_slots"])
    for sample in obs.capacity_samples:
        row = rows.get(sample["replica"])
        if row is None:
            # stage from a replica that never finished (e.g. killed before
            # finish_serve) — no capacity denominator, skip
            continue
        for cls, v in sample["classes"].items():
            row[cls] = row.get(cls, 0.0) + v
    for replica, row in rows.items():
        capacity = row["makespan_s"] * row["n_slots"]
        attributed = sum(row[c] for c in CAPACITY_CLASSES)
        residual = capacity - attributed
        tol = 1e-6 * max(1.0, capacity)
        if residual < -tol:
            raise AssertionError(
                f"replica {replica}: attributed {attributed:.6f}s of "
                f"slot-time exceeds capacity {capacity:.6f}s "
                f"(makespan {row['makespan_s']:.6f}s x {row['n_slots']:.0f} "
                f"slots)"
            )
        # idle_gap absorbs the residual so rows sum EXACTLY to capacity:
        # in-stage free slots were already attributed per stage; this adds
        # the between-stage gaps (arrival fast-forwards, drained tails).
        row["idle_gap"] += max(0.0, residual)
        total = sum(row[c] for c in CAPACITY_CLASSES)
        row["total"] = total
        row["capacity"] = capacity
    return rows


def check_capacity_conservation(obs, tol: float = 1e-6) -> bool:
    """Hard check: per replica, class rows sum to makespan × slots."""
    rows = capacity_attribution(obs)
    for replica, row in rows.items():
        capacity = row["capacity"]
        err = abs(row["total"] - capacity)
        if err > tol * max(1.0, capacity):
            raise AssertionError(
                f"replica {replica}: capacity attribution sums to "
                f"{row['total']:.9f}s, expected {capacity:.9f}s"
            )
    return True


def capacity_table(obs) -> str:
    """Render the capacity-attribution rollup as an aligned text table."""
    rows = capacity_attribution(obs)
    cols = CAPACITY_CLASSES
    lines = [
        "replica  " + "  ".join(f"{c:>9s}" for c in cols)
        + "  " + f"{'total':>9s}" + "  " + f"{'capacity':>9s}"
    ]
    for replica in sorted(rows):
        row = rows[replica]
        lines.append(
            f"{replica:7d}  "
            + "  ".join(f"{row[c]:9.3f}" for c in cols)
            + f"  {row['total']:9.3f}  {row['capacity']:9.3f}"
        )
    return "\n".join(lines)
