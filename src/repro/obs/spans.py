"""Causal request-lifecycle span events in fleet virtual time.

Every request emits a chain of events — arrival → admit → first_token →
prefill_done → (preempt → resume)* → (migrate_out → migrate_in)* →
complete — each carrying the replica and slot where it happened. Events
are causally linked: each event's ``parent`` is the id of the previous
event for the same request, and because a :class:`~repro.obs.Observation`
is shared by every replica of a fleet, a ``migrate_out`` on replica 0 is
the parent of the ``migrate_in`` on replica 1 — one chain per request
across the whole fleet, no per-replica stitching needed.

Fleet-level instants (faults, fencing, steals, COW copies, health
transitions) use ``rid=-1`` and carry no parent: they are points on the
global timeline, not members of a request's causal chain.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional


@dataclasses.dataclass
class SpanEvent:
    """One point on a request's causal timeline."""

    event_id: int
    rid: int                      # request id; -1 for fleet-level instants
    kind: str                     # "arrival", "admit", "preempt", ...
    t: float                      # fleet virtual time (seconds)
    replica: int = 0
    slot: Optional[int] = None
    parent: Optional[int] = None  # event_id of the previous event for rid
    attrs: Dict[str, object] = dataclasses.field(default_factory=dict)


class SpanLog:
    """Append-only event log with per-request causal chaining."""

    def __init__(self) -> None:
        self.events: List[SpanEvent] = []
        self._last: Dict[int, int] = {}   # rid -> event_id of latest event

    def has(self, rid: int) -> bool:
        return rid in self._last

    def emit(
        self,
        rid: int,
        kind: str,
        t: float,
        replica: int = 0,
        slot: Optional[int] = None,
        attrs: Optional[Dict[str, object]] = None,
        **kw,
    ) -> SpanEvent:
        # attrs may arrive as an explicit dict (keys like "rid"/"slot" that
        # would collide with the positional parameters) or as keywords
        ev = SpanEvent(
            event_id=len(self.events),
            rid=rid,
            kind=kind,
            t=float(t),
            replica=replica,
            slot=slot,
            parent=self._last.get(rid) if rid >= 0 else None,
            attrs={**(attrs or {}), **kw},
        )
        self.events.append(ev)
        if rid >= 0:
            self._last[rid] = ev.event_id
        return ev

    def by_request(self, rid: int) -> List[SpanEvent]:
        return [e for e in self.events if e.rid == rid]

    def request_ids(self) -> List[int]:
        return sorted(self._last.keys())

    def chain(self, rid: int) -> List[SpanEvent]:
        """Walk the parent links back from the request's latest event.

        Returns the chain oldest-first; equals ``by_request(rid)`` exactly
        when the parent links are intact — tests assert that equivalence.
        """
        out: List[SpanEvent] = []
        cur = self._last.get(rid)
        while cur is not None:
            ev = self.events[cur]
            out.append(ev)
            cur = ev.parent
        out.reverse()
        return out

    # ---------------------------------------------------------------- #
    # Checkpointing (JSON string: survives tree_map(np.asarray))        #
    # ---------------------------------------------------------------- #
    def state_dict(self) -> str:
        return json.dumps({
            "events": [dataclasses.asdict(e) for e in self.events],
            "last": {str(k): v for k, v in self._last.items()},
        })

    def load_state_dict(self, blob: str) -> None:
        state = json.loads(blob)
        self.events = [SpanEvent(**e) for e in state.get("events", [])]
        self._last = {int(k): v for k, v in state.get("last", {}).items()}
