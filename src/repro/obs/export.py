"""Perfetto / Chrome ``trace_event`` exporter.

Converts an :class:`~repro.obs.Observation` into the JSON object format
that `ui.perfetto.dev <https://ui.perfetto.dev>`_ (and chrome://tracing)
load directly:

  * one *process* per replica, one *thread* per slot — so the track
    layout mirrors the fleet: replica rows, slot lanes;
  * ``ph:"X"`` complete events for each request's prefill and decode
    phases on the slot where they ran (preempt/migrate split the phase);
  * ``ph:"i"`` instant events for faults, condemnations, steals, COW
    copies, fencings and health transitions on a per-replica control lane;
  * ``ph:"M"`` metadata events naming every track.

Timestamps are fleet virtual time converted to microseconds.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from .lifecycle import request_timelines

_US = 1e6
CONTROL_LANE = 9999        # tid for per-replica instant events

# fleet-level span kinds exported as instants
_INSTANT_KINDS = {
    "fault", "injected_fault", "condemn", "steal", "cow_copy", "fenced",
    "health_transition", "migration", "overload_defer",
}


def _phase_events(obs) -> List[dict]:
    """``ph:"X"`` slices: each request's prefill/decode segments per slot."""
    out: List[dict] = []
    for rid, evs in sorted(request_timelines(obs).items()):
        open_t = None        # (t, replica, slot, phase_name)
        for ev in evs:
            if ev.kind in ("admit", "resume") and ev.slot is not None:
                if open_t is None:
                    open_t = (ev.t, ev.replica, ev.slot, f"prefill r{rid}")
            elif ev.kind in ("prefill_done", "first_token"):
                if open_t is not None:
                    t0, rep, slot, name = open_t
                    out.append(_complete(name, rep, slot, t0, ev.t, rid))
                if ev.slot is not None:
                    open_t = (ev.t, ev.replica, ev.slot, f"decode r{rid}")
            elif ev.kind in ("preempt", "migrate_out", "complete"):
                if open_t is not None:
                    t0, rep, slot, name = open_t
                    out.append(_complete(name, rep, slot, t0, ev.t, rid))
                    open_t = None
            elif ev.kind == "migrate_in" and ev.slot is not None:
                open_t = (ev.t, ev.replica, ev.slot, f"decode r{rid}")
        # phase left open (e.g. request in flight at checkpoint): close at
        # its last event so the trace stays well-formed
        if open_t is not None and evs:
            t0, rep, slot, name = open_t
            t1 = max(e.t for e in evs)
            if t1 > t0:
                out.append(_complete(name, rep, slot, t0, t1, rid))
    return out


def _complete(
    name: str, replica: int, slot: int, t0: float, t1: float, rid: int
) -> dict:
    return {
        "name": name,
        "ph": "X",
        "pid": replica,
        "tid": slot,
        "ts": t0 * _US,
        "dur": max(0.0, (t1 - t0)) * _US,
        "cat": "request",
        "args": {"rid": rid},
    }


def _instant_events(obs) -> List[dict]:
    out: List[dict] = []
    for ev in obs.spans.events:
        if ev.kind not in _INSTANT_KINDS:
            continue
        args = {k: v for k, v in ev.attrs.items()}
        if ev.rid >= 0:
            args["rid"] = ev.rid
        out.append({
            "name": ev.kind,
            "ph": "i",
            "s": "p",            # process-scoped instant
            "pid": ev.replica,
            "tid": CONTROL_LANE if ev.slot is None else ev.slot,
            "ts": ev.t * _US,
            "cat": "control",
            "args": args,
        })
    return out


def _metadata_events(obs) -> List[dict]:
    out: List[dict] = []
    slots_of: Dict[int, int] = {
        r: int(info["n_slots"]) for r, info in obs.replicas.items()
    }
    # replicas seen only via events (e.g. killed before finish)
    for ev in obs.spans.events:
        slots_of.setdefault(ev.replica, 0)
    for replica in sorted(slots_of):
        out.append({
            "name": "process_name", "ph": "M", "pid": replica, "tid": 0,
            "args": {"name": f"replica {replica}"},
        })
        for slot in range(slots_of[replica]):
            out.append({
                "name": "thread_name", "ph": "M",
                "pid": replica, "tid": slot,
                "args": {"name": f"slot {slot}"},
            })
        out.append({
            "name": "thread_name", "ph": "M",
            "pid": replica, "tid": CONTROL_LANE,
            "args": {"name": "control"},
        })
    return out


def perfetto_trace(obs) -> dict:
    """The full trace as a Chrome ``trace_event`` JSON object."""
    events = _metadata_events(obs) + _phase_events(obs) + _instant_events(obs)
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "source": "repro.obs",
            "metrics": obs.registry.scalars(),
        },
    }


def write_trace(obs, path: str) -> str:
    """Write the Perfetto trace JSON to ``path``; returns the path."""
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(perfetto_trace(obs), f)
    return path
