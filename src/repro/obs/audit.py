"""Scheduler decision audit log.

Every priced decision the serving stack makes — the Lagrangian
``prefill_share`` evaluation, dispatch ``_placement_cost`` comparison,
steal/migration gates, replica condemnations, overload deferrals — is
recorded with the inputs it priced and the output it chose, so any
decision in a serve is explainable post-hoc ("why did the policy insert a
prefill here?") and two ablation runs diff structurally instead of by
eyeballing Gantts.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional


@dataclasses.dataclass
class AuditRecord:
    """One priced decision: what was weighed, what was chosen."""

    kind: str            # "prefill_share", "dispatch", "steal_gate", ...
    t: float             # fleet virtual time of the decision
    replica: int         # replica evaluating (or being decided about)
    inputs: Dict[str, object]   # the priced inputs, as computed
    chosen: object       # the decision output (share, replica id, verdict)


class AuditLog:
    """Append-only log of :class:`AuditRecord`."""

    def __init__(self) -> None:
        self.records: List[AuditRecord] = []

    def record(
        self,
        kind: str,
        t: float,
        replica: int,
        inputs: Dict[str, object],
        chosen: object,
    ) -> AuditRecord:
        rec = AuditRecord(
            kind=kind, t=float(t), replica=replica,
            inputs=dict(inputs), chosen=chosen,
        )
        self.records.append(rec)
        return rec

    def of_kind(self, kind: str) -> List[AuditRecord]:
        return [r for r in self.records if r.kind == kind]

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.records:
            out[r.kind] = out.get(r.kind, 0) + 1
        return out

    # ---------------------------------------------------------------- #
    # Checkpointing (JSON string: survives tree_map(np.asarray))        #
    # ---------------------------------------------------------------- #
    def state_dict(self) -> str:
        return json.dumps([dataclasses.asdict(r) for r in self.records])

    def load_state_dict(self, blob: str) -> None:
        self.records = [AuditRecord(**r) for r in json.loads(blob)]
