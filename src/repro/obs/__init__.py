"""Unified serve observability: typed metrics, causal spans, audit log.

One :class:`Observation` instance records a whole serve — single engine or
an N-replica fleet (every replica shares the same instance, which is what
makes cross-replica span parenting work). Opt in per serve:

    from repro.obs import Observation
    obs = Observation()
    eng = Engine(model, params, EngineConfig(observe=obs, ...))
    eng.serve(...)
    obs.registry.scalars()             # typed metrics
    lifecycle_table(obs)               # per-request timelines
    write_trace(obs, "serve.trace.json")   # open in ui.perfetto.dev

The default is ``observe=None`` and every emission site in the serving
stack is guarded by a single ``if self.obs is not None`` — a disabled
serve executes **zero** observability callbacks (enforced in tests via
the class-level :attr:`Observation.tripwire` hook, which fires on every
public recording method).

An Observation records exactly one serve: create a fresh instance per
serve (checkpoint restore of the *same* serve round-trips through
``state_dict``/``load_state_dict``).
"""
from __future__ import annotations

import json
from typing import Callable, Dict, List, Optional

from .audit import AuditLog, AuditRecord
from .export import perfetto_trace, write_trace
from .lifecycle import (
    CAPACITY_CLASSES,
    capacity_attribution,
    capacity_table,
    check_capacity_conservation,
    lifecycle_table,
    request_timelines,
)
from .metrics import MetricDeclarationError, MetricSpec, MetricsRegistry
from .spans import SpanEvent, SpanLog

__all__ = [
    "Observation",
    "MetricsRegistry", "MetricSpec", "MetricDeclarationError",
    "SpanLog", "SpanEvent",
    "AuditLog", "AuditRecord",
    "CAPACITY_CLASSES", "capacity_attribution", "capacity_table",
    "check_capacity_conservation", "lifecycle_table", "request_timelines",
    "perfetto_trace", "write_trace",
]


class Observation:
    """Facade over the registry, span log, audit log and capacity samples."""

    # Test hook: when set (class-level), called once at the top of every
    # public recording method. Lets tests count obs callbacks — and prove
    # the count is zero for an ``observe=None`` serve.
    tripwire: Optional[Callable[[], None]] = None

    def __init__(self) -> None:
        self.registry = MetricsRegistry()
        self.spans = SpanLog()
        self.audit = AuditLog()
        # per-stage slot-second attribution samples emitted by engines
        self.capacity_samples: List[dict] = []
        # replica -> {"makespan": s, "n_slots": n}, recorded at finish_serve
        self.replicas: Dict[int, dict] = {}

    def _trip(self) -> None:
        if Observation.tripwire is not None:
            Observation.tripwire()

    # ---------------------------------------------------------------- #
    # Spans                                                            #
    # ---------------------------------------------------------------- #
    def span(
        self,
        rid: int,
        kind: str,
        t: float,
        replica: int = 0,
        slot: Optional[int] = None,
        **attrs,
    ) -> SpanEvent:
        self._trip()
        return self.spans.emit(
            rid, kind, t, replica=replica, slot=slot, attrs=attrs
        )

    def instant(
        self, kind: str, t: float, replica: int = 0, **attrs
    ) -> SpanEvent:
        """Fleet-level point event (fault, steal, COW copy, ...). Attrs may
        reference a request by ``rid`` without joining its causal chain."""
        self._trip()
        return self.spans.emit(-1, kind, t, replica=replica, attrs=attrs)

    # ---------------------------------------------------------------- #
    # Audit                                                            #
    # ---------------------------------------------------------------- #
    def audit_record(
        self,
        kind: str,
        t: float,
        replica: int,
        inputs: Dict[str, object],
        chosen: object,
    ) -> AuditRecord:
        self._trip()
        return self.audit.record(kind, t, replica, inputs, chosen)

    # ---------------------------------------------------------------- #
    # Capacity attribution                                             #
    # ---------------------------------------------------------------- #
    def capacity(
        self, replica: int, t0: float, t1: float, classes: Dict[str, float]
    ) -> None:
        """One per-stage sample: slot-seconds of [t0, t1] by class."""
        self._trip()
        self.capacity_samples.append({
            "replica": replica, "t0": float(t0), "t1": float(t1),
            "classes": {k: float(v) for k, v in classes.items()},
        })

    def finish_replica(self, replica: int, makespan: float, n_slots: int) -> None:
        """Record a replica's capacity denominator at end of serve."""
        self._trip()
        self.replicas[replica] = {
            "makespan": float(makespan), "n_slots": int(n_slots),
        }

    # ---------------------------------------------------------------- #
    # Metrics passthrough                                              #
    # ---------------------------------------------------------------- #
    def declare(
        self, name: str, kind: str, unit: str = "", help: str = ""
    ) -> MetricSpec:
        self._trip()
        return self.registry.declare(name, kind, unit=unit, help=help)

    def inc(self, name: str, value: float = 1.0) -> None:
        self._trip()
        self.registry.inc(name, value)

    def set(self, name: str, value: float) -> None:
        self._trip()
        self.registry.set(name, value)

    def observe_value(self, name: str, value: float) -> None:
        self._trip()
        self.registry.observe(name, value)

    def log(self, channel: str, entry: dict) -> None:
        self._trip()
        self.registry.append_log(channel, entry)

    def set_log(self, channel: str, entries: List[dict]) -> None:
        self._trip()
        self.registry.set_log(channel, entries)

    # ---------------------------------------------------------------- #
    # Checkpointing (JSON string leaf: survives tree_map(np.asarray))   #
    # ---------------------------------------------------------------- #
    def state_dict(self) -> str:
        return json.dumps({
            "registry": self.registry.state_dict(),
            "spans": self.spans.state_dict(),
            "audit": self.audit.state_dict(),
            "capacity_samples": self.capacity_samples,
            "replicas": {str(k): v for k, v in self.replicas.items()},
        })

    def load_state_dict(self, blob: str) -> None:
        state = json.loads(blob)
        self.registry.load_state_dict(state["registry"])
        self.spans.load_state_dict(state["spans"])
        self.audit.load_state_dict(state["audit"])
        self.capacity_samples = list(state.get("capacity_samples", []))
        self.replicas = {
            int(k): v for k, v in state.get("replicas", {}).items()
        }
