"""Typed metrics registry: the declared, unit-carrying replacement for the
stringly-typed ``ScheduleTrace.meta`` / ``FleetReport.meta`` grab-bags.

Every metric is declared once (name, kind, unit, help) before it is
recorded; re-declaring with identical attributes is an idempotent no-op,
re-declaring with *different* attributes raises — two subsystems cannot
silently publish incompatible series under one name. Three kinds:

  * ``counter``   — monotone accumulation (``inc``); fleet-wide counters
    sum across replicas by construction because every replica ``inc``s the
    same registry entry.
  * ``gauge``     — last-write-wins level (``set``).
  * ``histogram`` — raw observations (``observe``), summarized to
    count/sum/percentiles on demand.

``scalars()`` exports exactly the numeric view a ``summary()`` dict wants
(counters and gauges as floats, histograms as ``<name>_count``/
``<name>_sum``). Structured event records — fault logs, fenced logs,
per-event journals — go through the ``logs`` side-channel instead
(``set_log``/``append_log``): they are *typed as what they are* (lists of
dicts), never smuggled through a ``Dict[str, float]`` as JSON strings, and
``scalars()`` never includes them.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

KINDS = ("counter", "gauge", "histogram")


class MetricDeclarationError(ValueError):
    """Raised when a metric is re-declared with conflicting attributes."""


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One declared metric: its identity and documentation."""

    name: str
    kind: str                              # "counter" | "gauge" | "histogram"
    unit: str = ""                         # "s", "tokens", "pages", "" (count)
    help: str = ""

    def __post_init__(self):
        if self.kind not in KINDS:
            raise MetricDeclarationError(
                f"metric {self.name!r}: unknown kind {self.kind!r}; "
                f"have {KINDS}"
            )


class MetricsRegistry:
    """Declared counters/gauges/histograms plus the typed log side-channel."""

    def __init__(self) -> None:
        self.specs: Dict[str, MetricSpec] = {}
        self._values: Dict[str, float] = {}           # counters + gauges
        self._samples: Dict[str, List[float]] = {}    # histograms
        # structured event records, typed as lists of dicts — the explicit
        # side-channel that used to be JSON strings inside meta dicts
        self.logs: Dict[str, List[dict]] = {}

    # ---------------------------------------------------------------- #
    # Declaration                                                      #
    # ---------------------------------------------------------------- #
    def declare(
        self, name: str, kind: str, unit: str = "", help: str = ""
    ) -> MetricSpec:
        spec = MetricSpec(name=name, kind=kind, unit=unit, help=help)
        have = self.specs.get(name)
        if have is not None:
            if have != spec:
                raise MetricDeclarationError(
                    f"metric {name!r} re-declared with conflicting "
                    f"attributes: {have} vs {spec}"
                )
            return have
        self.specs[name] = spec
        if kind == "histogram":
            self._samples[name] = []
        else:
            self._values[name] = 0.0
        return spec

    def _spec(self, name: str, expect: tuple) -> MetricSpec:
        spec = self.specs.get(name)
        if spec is None:
            raise KeyError(f"metric {name!r} was never declared")
        if spec.kind not in expect:
            raise MetricDeclarationError(
                f"metric {name!r} is a {spec.kind}, not one of {expect}"
            )
        return spec

    # ---------------------------------------------------------------- #
    # Recording                                                        #
    # ---------------------------------------------------------------- #
    def inc(self, name: str, value: float = 1.0) -> None:
        self._spec(name, ("counter",))
        if value < 0:
            raise ValueError(f"counter {name!r} cannot decrease (by {value})")
        self._values[name] += float(value)

    def set(self, name: str, value: float) -> None:
        self._spec(name, ("gauge",))
        self._values[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        self._spec(name, ("histogram",))
        self._samples[name].append(float(value))

    def set_log(self, channel: str, entries: List[dict]) -> None:
        self.logs[channel] = [dict(e) for e in entries]

    def append_log(self, channel: str, entry: dict) -> None:
        self.logs.setdefault(channel, []).append(dict(entry))

    # ---------------------------------------------------------------- #
    # Reading                                                          #
    # ---------------------------------------------------------------- #
    def value(self, name: str) -> float:
        self._spec(name, ("counter", "gauge"))
        return self._values[name]

    def samples(self, name: str) -> List[float]:
        self._spec(name, ("histogram",))
        return list(self._samples[name])

    def percentile(self, name: str, q: float) -> float:
        vals = sorted(self.samples(name))
        if not vals:
            return 0.0
        idx = min(len(vals) - 1, int(q / 100.0 * len(vals)))
        return vals[idx]

    def scalars(self) -> Dict[str, float]:
        """Every metric as plain floats — counters and gauges verbatim,
        histograms as ``_count``/``_sum``. Never includes ``logs``."""
        out = dict(self._values)
        for name, vals in self._samples.items():
            out[f"{name}_count"] = float(len(vals))
            out[f"{name}_sum"] = float(sum(vals))
        return out

    def describe(self) -> List[Dict[str, str]]:
        """The registry's self-documentation (name/kind/unit/help rows)."""
        return [dataclasses.asdict(s) for s in self.specs.values()]

    # ---------------------------------------------------------------- #
    # Checkpointing (JSON string: survives tree_map(np.asarray))        #
    # ---------------------------------------------------------------- #
    def state_dict(self) -> str:
        return json.dumps({
            "specs": [dataclasses.asdict(s) for s in self.specs.values()],
            "values": self._values,
            "samples": self._samples,
            "logs": self.logs,
        })

    def load_state_dict(self, blob: str) -> None:
        state = json.loads(blob)
        self.specs = {
            s["name"]: MetricSpec(**s) for s in state.get("specs", [])
        }
        self._values = {k: float(v) for k, v in state.get("values", {}).items()}
        self._samples = {
            k: [float(x) for x in v]
            for k, v in state.get("samples", {}).items()
        }
        self.logs = {k: list(v) for k, v in state.get("logs", {}).items()}
