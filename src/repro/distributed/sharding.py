"""Logical-axis → mesh-axis sharding rules (MaxText-style).

Every parameter is declared with logical axes (see ``models.layers``); this
module maps them to ``PartitionSpec``s for a concrete mesh and strategy:

  * TP ("model" axis): vocab, mlp, heads, experts, rnn widths
  * DP/FSDP ("data" [+ "pod"] axes): batch dim of activations; optionally the
    "embed" dim of ≥2-D weights (fully-sharded weights — required for the
    biggest archs to fit 16 GB/chip even at inference, see DESIGN.md §5)
  * SP/CP: KV-cache sequence dim shards over "model" when the arch's KV-head
    count cannot (GQA with few KV heads)

Every rule is *shape-checked*: an axis whose dim is not divisible by the
mesh axes it maps to silently degrades to replication (e.g. batch=1 in
``long_500k``). That makes one rule-set serve all 40 (arch × shape) cells.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Tree = Any
AxisName = Union[str, Tuple[str, ...], None]


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    """Strategy knobs (the §Perf hillclimb levers)."""

    dp_axes: Tuple[str, ...] = ("data",)       # ("pod","data") when multi-pod
    tp_axis: str = "model"
    fsdp_weights: bool = True                  # shard "embed" of ≥2D weights over dp
    shard_cache_seq: bool = True               # CP the KV seq when kv_heads can't TP
    logical_rules: Tuple[Tuple[str, AxisName], ...] = ()  # extra overrides

    def rules(self) -> Dict[str, AxisName]:
        base: Dict[str, AxisName] = {
            "layers": None,
            "batch": self.dp_axes,
            "seq": None,
            "cache_seq": None,           # upgraded per-arch (see build_cache_specs)
            "vocab": self.tp_axis,
            "embed": None,               # upgraded to dp for ≥2D weights if fsdp
            "mlp": self.tp_axis,
            "heads": self.tp_axis,
            "kv_heads": self.tp_axis,
            "head_dim": None,
            "experts": self.tp_axis,
            "rnn": self.tp_axis,
        }
        base.update(dict(self.logical_rules))
        return base


def _axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _mesh_size(mesh: Mesh, name: AxisName) -> int:
    if name is None:
        return 1
    sizes = _axis_sizes(mesh)
    if isinstance(name, str):
        return sizes[name]
    return int(np.prod([sizes[n] for n in name]))


def _spec_for(
    shape: Tuple[int, ...],
    axes: Tuple[Optional[str], ...],
    rules: Dict[str, AxisName],
    mesh: Mesh,
    fsdp_weights: bool,
    dp_axes: Tuple[str, ...],
) -> P:
    """Shape-checked spec: drop any mapping whose dim is not divisible or
    whose mesh axis is already used by an earlier dim."""
    used: set = set()
    entries = []
    axes = tuple(axes)
    is_weight = len([a for a in axes if a not in (None, "layers")]) >= 2
    # Vocab-dim weights (embedding/unembedding tables) stay out of FSDP:
    # a table sharded on BOTH dims defeats GSPMD's gather partitioning
    # (involuntary full rematerialization) — vocab-sharding alone suffices.
    fsdp_ok = is_weight and "vocab" not in axes
    for dim, ax in zip(shape, axes):
        target: AxisName = rules.get(ax) if ax is not None else None
        if ax == "embed" and fsdp_weights and fsdp_ok and target is None:
            target = dp_axes
        if target is None:
            entries.append(None)
            continue
        names = (target,) if isinstance(target, str) else tuple(target)
        names = tuple(n for n in names if n not in used)
        if not names:
            entries.append(None)
            continue
        size = _mesh_size(mesh, names)
        if size <= 1 or dim % size != 0:
            entries.append(None)
            continue
        used.update(names)
        entries.append(names[0] if len(names) == 1 else names)
    # strip trailing Nones for tidiness
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def ambient_dp_axes() -> Optional[Tuple[str, ...]]:
    """Data-parallel axes of the mesh currently in context, or None.

    Model code uses this to constrain *internally created* state (zero-init
    recurrent states, caches built inside ``forward``) to batch sharding —
    GSPMD cannot infer useful shardings for such intermediates, and leaving
    them replicated multiplies their footprint by the mesh size. Outside a
    mesh context (CPU smoke tests) this returns None and no constraint is
    applied.
    """
    try:
        from jax.interpreters import pxla  # noqa: PLC0415

        mesh = pxla.thread_resources.env.physical_mesh
        if mesh.empty:
            return None
        return tuple(a for a in mesh.axis_names if a in ("pod", "data")) or None
    except Exception:  # noqa: BLE001
        return None


def constrain_batch_dim(x, batch_dim: int = 1):
    """with_sharding_constraint(batch dim → dp axes) if a mesh is ambient."""
    import jax.numpy as jnp  # noqa: PLC0415
    from jax.sharding import PartitionSpec  # noqa: PLC0415

    dp = ambient_dp_axes()
    if dp is None:
        return x
    if x.ndim <= batch_dim or x.shape[batch_dim] % _grid(dp) != 0:
        return x
    spec = [None] * x.ndim
    spec[batch_dim] = dp if len(dp) > 1 else dp[0]
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec))


def _grid(dp: Tuple[str, ...]) -> int:
    from jax.interpreters import pxla  # noqa: PLC0415

    mesh = pxla.thread_resources.env.physical_mesh
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = 1
    for a in dp:
        out *= sizes[a]
    return out


# --------------------------------------------------------------------------- #
# Sequence parallelism (runtime toggle)                                       #
# --------------------------------------------------------------------------- #
# When ON, the residual stream between transformer blocks is constrained to
# P(dp, tp, None) — the Korthikanti-style layout: norms/residuals run
# seq-sharded, GSPMD inserts all-gather before qkv/mlp and reduce-scatter
# after, replacing the plain TP all-reduces (half the bytes) and dividing
# layer-boundary activation storage by the TP width. Toggled per dry-run
# variant (see launch.plan / EXPERIMENTS.md §Perf).
_SEQUENCE_PARALLEL = {"on": False}


def set_sequence_parallel(on: bool) -> None:
    _SEQUENCE_PARALLEL["on"] = bool(on)


def sequence_parallel_enabled() -> bool:
    return _SEQUENCE_PARALLEL["on"]


def constrain_kv_for_cache(k, n_kv_heads: int, seq_dim: int = 1):
    """Align freshly-computed prefill K/V (B, S, KV, D) with the cache's
    layout *before* the cache write.

    When KV heads don't divide the TP axis the cache shards its sequence dim
    over "model" (context parallelism); the K/V produced inside the block
    inherit a heads/replicated layout, and the per-layer cache writes then
    reshard 2·L times per prefill — tens of seconds of all-gather at 32k
    (§Perf H2). Constraining here makes the write layout-aligned.
    """
    from jax.interpreters import pxla  # noqa: PLC0415
    from jax.sharding import PartitionSpec  # noqa: PLC0415

    try:
        mesh = pxla.thread_resources.env.physical_mesh
        if mesh.empty:
            return k
    except Exception:  # noqa: BLE001
        return k
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = "model" if "model" in sizes else None
    if tp is None or n_kv_heads % sizes[tp] == 0:
        return k  # heads shard fine; no CP needed
    if k.shape[seq_dim] % sizes[tp] != 0:
        return k
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    spec = [None] * k.ndim
    if dp and k.shape[0] % _grid(dp) == 0:
        spec[0] = dp if len(dp) > 1 else dp[0]
    spec[seq_dim] = tp
    return jax.lax.with_sharding_constraint(k, PartitionSpec(*spec))


def constrain_logits(x):
    """Constrain logits (..., V) to batch-dp × vocab-tp when a mesh is
    ambient (same GSPMD-propagation insurance as ``embed_tokens``)."""
    from jax.interpreters import pxla  # noqa: PLC0415
    from jax.sharding import PartitionSpec  # noqa: PLC0415

    try:
        mesh = pxla.thread_resources.env.physical_mesh
        if mesh.empty:
            return x
    except Exception:  # noqa: BLE001
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    tp = "model" if "model" in sizes else None
    spec = [None] * x.ndim
    if dp and x.shape[0] % _grid(dp) == 0:
        spec[0] = dp if len(dp) > 1 else dp[0]
    if tp and x.shape[-1] % sizes[tp] == 0:
        spec[-1] = tp
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec))


def constrain_residual(h):
    """Apply the residual-stream constraint to (B, S, D) activations."""
    if not _SEQUENCE_PARALLEL["on"] or h.ndim != 3:
        return h
    from jax.interpreters import pxla  # noqa: PLC0415
    from jax.sharding import PartitionSpec  # noqa: PLC0415

    try:
        mesh = pxla.thread_resources.env.physical_mesh
        if mesh.empty:
            return h
    except Exception:  # noqa: BLE001
        return h
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    tp = "model" if "model" in sizes else None
    b_ok = dp and h.shape[0] % _grid(dp) == 0
    s_ok = tp and h.shape[1] % sizes[tp] == 0
    spec = PartitionSpec(
        (dp if len(dp) > 1 else dp[0]) if b_ok else None,
        tp if s_ok else None,
        None,
    )
    return jax.lax.with_sharding_constraint(h, spec)


def build_param_specs(
    abstract_params: Tree,
    logical_axes: Tree,
    mesh: Mesh,
    scfg: ShardingConfig,
) -> Tree:
    rules = scfg.rules()

    def one(aval, axes):
        return NamedSharding(
            mesh,
            _spec_for(aval.shape, axes, rules, mesh, scfg.fsdp_weights, scfg.dp_axes),
        )

    # abstract_params' leaves (ShapeDtypeStruct) align with logical_axes'
    # tuple leaves via flatten_up_to, so no custom is_leaf is needed.
    return jax.tree_util.tree_map(one, abstract_params, logical_axes)


# --------------------------------------------------------------------------- #
# Cache sharding (serve steps)                                                #
# --------------------------------------------------------------------------- #
def _cache_axes_for_key(path: Tuple[str, ...], shape: Tuple[int, ...], kv_shardable: bool):
    """Logical axes for cache arrays, keyed by their dict path/rank."""
    key = path[-1]
    if key in ("k", "v", "cross_k", "cross_v"):
        # (L, B, S, KV, HD): TP the KV heads when possible, else CP the seq.
        return (
            "layers", "batch",
            "cache_seq" if kv_shardable else "cache_seq_tp",
            "kv_heads", "head_dim",
        )
    if key == "pos":
        return ("batch", None)
    if key == "length":
        return ("batch",)
    if key == "rnn_h":
        return ("layers", "batch", "rnn")
    if key == "conv_buf":
        return ("layers", "batch", None, "rnn")
    if key in ("m_C",):
        return ("layers", "batch", "heads", "head_dim", None)
    if key in ("m_n", "s_c", "s_n", "s_h"):
        return ("layers", "batch", "heads", "head_dim")
    if key in ("m_m", "s_m"):
        return ("layers", "batch", "heads")
    # fallback: batch-shard dim 1 if rank >= 2
    return tuple(
        "batch" if i == 1 else ("layers" if i == 0 else None) for i in range(len(shape))
    )


def build_cache_specs(
    cache_shape_tree: Tree,
    mesh: Mesh,
    scfg: ShardingConfig,
    n_kv_heads: int,
) -> Tree:
    """Shardings for a serve cache. If the KV-head count divides the TP axis
    the KV heads shard (TP); otherwise the cache *sequence* dim shards over
    the TP axis (context parallelism) when ``shard_cache_seq``."""
    tp = _mesh_size(mesh, scfg.tp_axis)
    kv_shardable = n_kv_heads % tp == 0 if tp > 1 else False
    rules = scfg.rules()
    rules = dict(rules)
    rules["cache_seq"] = None
    rules["cache_seq_tp"] = scfg.tp_axis if scfg.shard_cache_seq else None
    if not kv_shardable:
        rules["kv_heads"] = None

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape_tree)
    out = []
    for path, aval in flat:
        keys = tuple(
            p.key if hasattr(p, "key") else str(p) for p in path
        )
        axes = _cache_axes_for_key(keys, aval.shape, kv_shardable)
        out.append(
            NamedSharding(
                mesh,
                _spec_for(aval.shape, axes, rules, mesh, False, scfg.dp_axes),
            )
        )
    return jax.tree_util.tree_unflatten(treedef, out)


# --------------------------------------------------------------------------- #
# Model-input ShapeDtypeStructs + shardings per shape cell                    #
# --------------------------------------------------------------------------- #
def input_specs_for(
    cfg,
    cell,
    mesh: Mesh,
    scfg: ShardingConfig,
) -> Tuple[Dict[str, jax.ShapeDtypeStruct], Dict[str, NamedSharding]]:
    """ShapeDtypeStruct stand-ins + shardings for every model input of a
    shape cell (tokens/labels for train; tokens for serve; stub modality
    embeddings for vlm/audio). No device allocation happens here."""
    import jax.numpy as jnp

    b, s = cell.global_batch, cell.seq_len
    f = jax.ShapeDtypeStruct
    rules = scfg.rules()

    def sh(shape, axes):
        return NamedSharding(
            mesh, _spec_for(shape, axes, rules, mesh, False, scfg.dp_axes)
        )

    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    shards: Dict[str, NamedSharding] = {}
    if cell.kind == "train":
        specs["tokens"] = f((b, s), jnp.int32)
        specs["labels"] = f((b, s), jnp.int32)
        shards["tokens"] = sh((b, s), ("batch", "seq"))
        shards["labels"] = sh((b, s), ("batch", "seq"))
        if cfg.family == "vlm":
            p = cfg.num_patch_tokens
            specs["patch_embeds"] = f((b, p, cfg.d_model), jnp.bfloat16)
            shards["patch_embeds"] = sh((b, p, cfg.d_model), ("batch", None, "embed"))
        if cfg.family == "audio":
            specs["frames"] = f((b, s, cfg.d_model), jnp.bfloat16)
            shards["frames"] = sh((b, s, cfg.d_model), ("batch", "seq", "embed"))
    elif cell.kind == "prefill":
        specs["tokens"] = f((b, s), jnp.int32)
        shards["tokens"] = sh((b, s), ("batch", "seq"))
        if cfg.family == "vlm":
            p = cfg.num_patch_tokens
            specs["patch_embeds"] = f((b, p, cfg.d_model), jnp.bfloat16)
            shards["patch_embeds"] = sh((b, p, cfg.d_model), ("batch", None, "embed"))
        if cfg.family == "audio":
            specs["frames"] = f((b, s, cfg.d_model), jnp.bfloat16)
            shards["frames"] = sh((b, s, cfg.d_model), ("batch", "seq", "embed"))
    elif cell.kind == "decode":
        specs["tokens"] = f((b,), jnp.int32)
        shards["tokens"] = sh((b,), ("batch",))
    else:
        raise ValueError(cell.kind)
    return specs, shards
