"""Elastic scaling & failure recovery.

The recovery contract at fleet scale:

  1. every state tree is checkpointed unsharded + self-describing
     (``repro.checkpoint``), so restore is mesh-shape independent;
  2. on node failure, the controller rebuilds the largest healthy mesh that
     preserves the ``model`` axis width (TP width is baked into kernels'
     efficiency; DP width is the elastic dimension), re-derives shardings
     from the same logical rules, and restores;
  3. the data pipeline resumes from the checkpointed cursor; the scheduler
     (paper layer) re-enqueues in-flight requests — its state is tiny
     (queues + remain_token) and rides in checkpoint metadata.

``remesh_plan`` computes the new mesh; ``reshard_restore`` does 1+2. The
round-trip is exercised on fake devices in tests/test_distributed.py.
Straggler mitigation at the request level is the paper's Algorithm 1 (work
stealing); at the step level the engine re-buckets slow prefills (see
serving.engine).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from ..checkpoint import restore_checkpoint
from .sharding import ShardingConfig, build_param_specs

Tree = Any


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    lost_devices: int

    @property
    def healthy_fraction(self) -> float:
        return float(np.prod(self.new_shape)) / float(np.prod(self.old_shape))


def remesh_plan(
    old_shape: Tuple[int, ...],
    axis_names: Tuple[str, ...],
    n_healthy: int,
    model_axis: str = "model",
) -> RemeshPlan:
    """Largest mesh ≤ n_healthy devices that keeps the model-axis width.

    The DP axes shrink to the largest power-of-two product that fits; the
    TP axis is preserved (weights' shard layout and per-chip working set
    stay identical, so restart needs no retuning).
    """
    sizes = dict(zip(axis_names, old_shape))
    tp = sizes.get(model_axis, 1)
    if n_healthy < tp:
        raise ValueError(
            f"cannot preserve model axis {tp} with only {n_healthy} devices"
        )
    dp_budget = n_healthy // tp
    # distribute the dp budget over the non-model axes, largest-first
    dp_axes = [a for a in axis_names if a != model_axis]
    new_sizes = dict(sizes)
    # shrink to powers of two that fit
    total_dp = 1
    for a in dp_axes:
        total_dp *= sizes[a]
    scale = 1
    while total_dp // scale > dp_budget:
        scale *= 2
    remaining = scale
    for a in reversed(dp_axes):  # shrink innermost dp axis first
        while remaining > 1 and new_sizes[a] > 1:
            new_sizes[a] //= 2
            remaining //= 2
    new_shape = tuple(new_sizes[a] for a in axis_names)
    return RemeshPlan(
        old_shape=tuple(old_shape),
        new_shape=new_shape,
        axis_names=tuple(axis_names),
        lost_devices=int(np.prod(old_shape)) - n_healthy,
    )


def build_mesh(plan: RemeshPlan):
    """Materialize the plan's mesh, dropping axes that shrank to 1 if they
    are leading pod axes (a 1-pod mesh is just (data, model))."""
    shape, names = [], []
    for s, a in zip(plan.new_shape, plan.axis_names):
        if s == 1 and a == "pod":
            continue
        shape.append(s)
        names.append(a)
    return jax.make_mesh(tuple(shape), tuple(names))


def reshard_restore(
    checkpoint_dir,
    abstract_tree: Tree,
    logical_axes: Tree,
    mesh,
    scfg: Optional[ShardingConfig] = None,
    step: Optional[int] = None,
) -> Tuple[Tree, Dict[str, Any]]:
    """Restore a checkpoint onto a (possibly different) mesh."""
    scfg = scfg or ShardingConfig(
        dp_axes=tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    )
    specs = build_param_specs(abstract_tree, logical_axes, mesh, scfg)
    return restore_checkpoint(checkpoint_dir, step, abstract_tree, specs)
