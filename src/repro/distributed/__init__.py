from .sharding import ShardingConfig, build_param_specs, build_cache_specs, input_specs_for
