"""Distributed-optimization collectives.

``compressed_psum`` — int8 error-feedback gradient compression for the
cross-pod data-parallel reduce (the slow inter-pod links are the bottleneck
at 2+ pods; int8 quarters the bytes). Per-tensor max-abs scaling, with the
quantization residual fed back into the next step (error feedback keeps the
compressed SGD/Adam trajectory unbiased in the long run — Karimireddy et
al.-style).

Used inside a ``shard_map`` train-step wrapper (``make_dp_train_step``):
grads are computed per-DP-shard, compressed, psum'd over the dp axis, then
fed to the optimizer. The plain pjit path (GSPMD-managed reduces) remains
the default; this is an opt-in trick, benchmarked in
``tests/test_distributed.py`` for numerics.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

Tree = Any


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization → (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(
    grads: Tree,
    error_fb: Tree,
    axis_name: str,
) -> Tuple[Tree, Tree]:
    """int8 psum with error feedback.

    Returns (mean-reduced grads f32, new error feedback state). ``error_fb``
    must be an f32 tree shaped like ``grads`` (zeros initially).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = quantize_int8(gf)
        deq = dequantize_int8(q, scale)
        new_e = gf - deq
        # reduce the quantized values (int32 accumulate avoids overflow),
        # scales reduce separately — scale is per-shard, so psum the
        # dequantized contribution: bytes on the wire are the int8 payload
        # plus one scalar per tensor.
        total = jax.lax.psum(q.astype(jnp.int32) * 1, axis_name)  # int32 sum
        # NOTE: a production impl would psum int8 with per-shard scales via
        # all-to-all of scales; jax's psum requires a uniform dtype, so we
        # model the payload as int8-quantized values with a shared scale:
        scale_max = jax.lax.pmax(scale, axis_name)
        mean = total.astype(jnp.float32) * scale_max / n
        return mean, new_e

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error_fb)
    means, errs = [], []
    for g, e in zip(flat_g, flat_e):
        m, ne = one(g, e)
        means.append(m)
        errs.append(ne)
    return (
        jax.tree_util.tree_unflatten(treedef, means),
        jax.tree_util.tree_unflatten(treedef, errs),
    )


def psum_mean(grads: Tree, axis_name: str) -> Tree:
    n = jax.lax.psum(1, axis_name)
    return jax.tree_util.tree_map(
        lambda g: jax.lax.psum(g.astype(jnp.float32), axis_name) / n, grads
    )
