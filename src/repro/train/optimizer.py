"""AdamW, implemented directly (no optax in the container).

Moments are f32 regardless of param dtype; the update is computed in f32 and
cast back (bf16 params + f32 m/v is the standard large-scale recipe — a
separate f32 master copy is intentionally omitted; see DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Tree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    warmup_steps: int = 100


def adamw_init(params: Tree) -> Dict[str, Tree]:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(f32, params),
        "v": jax.tree_util.tree_map(f32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_params: Tree) -> Dict[str, Tree]:
    """ShapeDtypeStruct tree for the dry-run."""
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(f32, abstract_params),
        "v": jax.tree_util.tree_map(f32, abstract_params),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, count: jax.Array) -> jax.Array:
    """Linear warmup to cfg.lr, then constant (simple and robust)."""
    warm = jnp.minimum(count.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree: Tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(
    grads: Tree,
    opt_state: Dict[str, Tree],
    params: Tree,
    cfg: AdamWConfig,
) -> Tuple[Tree, Dict[str, Tree], Dict[str, jax.Array]]:
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = _schedule(cfg, count)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(params)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        np_, nm, nv = upd(g, m, v, p)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    new_params = jax.tree_util.tree_unflatten(treedef, new_p)
    new_state = {
        "m": jax.tree_util.tree_unflatten(treedef, new_m),
        "v": jax.tree_util.tree_unflatten(treedef, new_v),
        "count": count,
    }
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
