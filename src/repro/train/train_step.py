"""Train / eval step factories with gradient accumulation.

``make_train_step`` returns a pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
suitable for ``jax.jit`` with explicit shardings. Microbatching is a
``lax.scan`` over leading-dim splits of the batch — the standard way to keep
activation peaks bounded at large global batch (the MoE archs need it; see
DESIGN.md §5). Gradients average across microbatches; under pjit the
cross-device reduction is GSPMD's (the int8-compressed shard_map DP variant
lives in ``repro.distributed.collectives``).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .optimizer import AdamWConfig, adamw_update

Tree = Any


def _split_batch(batch: Dict[str, jax.Array], n: int) -> Dict[str, jax.Array]:
    def sp(x):
        b = x.shape[0]
        if b % n != 0:
            raise ValueError(f"batch dim {b} not divisible by {n} microbatches")
        return x.reshape(n, b // n, *x.shape[1:])

    return {k: sp(v) for k, v in batch.items()}


def make_train_step(
    model,
    opt_cfg: Optional[AdamWConfig] = None,
    microbatches: int = 1,
    remat: bool = True,
    accum_dtype=jnp.bfloat16,
) -> Callable:
    """``accum_dtype``: gradient-accumulation dtype across microbatches.
    Cotangents of bf16 params are already bf16; accumulating in bf16 halves
    the accumulator footprint (GBs/device for the 141B arch). bf16 has an
    8-bit mantissa — with ≤32 microbatches the accumulated relative error
    stays ~2^-8·√mb, well under optimizer noise; pass jnp.float32 to opt
    out (the smoke tests validate both against each other)."""
    opt_cfg = opt_cfg or AdamWConfig()

    def loss_fn(params, mb):
        return model.loss(params, mb, remat=remat)

    def train_step(params, opt_state, batch):
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = _split_batch(batch, microbatches)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params
            )

            def accum(carry, mb):
                loss_sum, gsum = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                # scale each microbatch's contribution before accumulating to
                # keep bf16 accumulation well-conditioned
                gsum = jax.tree_util.tree_map(
                    lambda a, g: a + (g / microbatches).astype(accum_dtype),
                    gsum, grads,
                )
                return (loss_sum + loss, gsum), None

            (loss_sum, gsum), _ = jax.lax.scan(
                accum, (jnp.zeros((), jnp.float32), zero), mbs
            )
            loss = loss_sum / microbatches
            grads = gsum
        new_params, new_opt, metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(model, remat: bool = False) -> Callable:
    def eval_step(params, batch):
        return model.loss(params, batch, remat=remat)

    return eval_step
