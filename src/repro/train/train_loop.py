"""Training loop with checkpoint/restart and synthetic data.

The end-to-end driver behind ``repro.launch.train``: builds a model from an
arch config, shards over the ambient mesh (or runs on CPU for smoke
configs), and trains with AdamW + grad accumulation, checkpointing every N
steps and resuming from the latest complete checkpoint on restart (tested by
killing/restarting in tests/test_train.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs.base import ArchConfig
from ..models.layers import init_params
from ..models.registry import get_model
from .optimizer import AdamWConfig, adamw_init
from .train_step import make_train_step

Tree = Any


@dataclass
class TrainConfig:
    steps: int = 50
    batch: int = 8
    seq: int = 64
    microbatches: int = 1
    remat: bool = False
    seed: int = 0
    checkpoint_dir: Optional[str] = None
    save_every: int = 20
    log_every: int = 10


def synthetic_batches(cfg: ArchConfig, tc: TrainConfig) -> Iterator[Dict[str, jax.Array]]:
    """Deterministic synthetic LM data: modular successor sequences
    (tokens[t+1] = tokens[t] + stride mod V) — a static next-token mapping a
    tiny model learns in tens of steps, so loss decrease is a crisp test."""
    rng = np.random.default_rng(tc.seed)
    step = 0
    v = max(cfg.vocab_size - 1, 2)
    while True:
        start = rng.integers(0, v, size=(tc.batch, 1))
        stride = rng.integers(1, 4, size=(tc.batch, 1))
        t = np.arange(tc.seq + 1)[None, :]
        seqs = (start + stride * t) % v + 1
        batch = {
            "tokens": jnp.asarray(seqs[:, :-1], jnp.int32),
            "labels": jnp.asarray(seqs[:, 1:], jnp.int32),
        }
        if cfg.family == "audio":
            frames = rng.normal(size=(tc.batch, tc.seq, cfg.d_model)).astype(np.float32)
            batch["frames"] = jnp.asarray(frames, jnp.bfloat16)
        if cfg.family == "vlm":
            pe = rng.normal(size=(tc.batch, cfg.num_patch_tokens, cfg.d_model))
            batch["patch_embeds"] = jnp.asarray(pe, jnp.bfloat16)
        step += 1
        yield batch


def train(
    cfg: ArchConfig,
    tc: TrainConfig,
    opt_cfg: Optional[AdamWConfig] = None,
) -> Dict[str, Any]:
    """Run the loop; returns summary metrics (resumes if checkpoints exist)."""
    opt_cfg = opt_cfg or AdamWConfig(warmup_steps=10)
    model = get_model(cfg)
    params = init_params(jax.random.key(tc.seed), model.param_defs())
    opt_state = adamw_init(params)
    start_step = 0
    mgr = None
    if tc.checkpoint_dir:
        mgr = CheckpointManager(tc.checkpoint_dir, save_every=tc.save_every)
        restored, start_step, meta = mgr.resume({"params": params, "opt": opt_state})
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]

    step_fn = jax.jit(
        make_train_step(model, opt_cfg, microbatches=tc.microbatches, remat=tc.remat)
    )
    data = synthetic_batches(cfg, tc)
    # skip already-consumed batches on resume (deterministic pipeline cursor)
    for _ in range(start_step):
        next(data)

    losses = []
    t0 = time.perf_counter()
    for step in range(start_step, tc.steps):
        batch = next(data)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if mgr is not None:
            mgr.maybe_save(
                step + 1,
                {"params": params, "opt": opt_state},
                metadata={"loss": loss, "step": step + 1},
            )
        if tc.log_every and (step + 1) % tc.log_every == 0:
            print(f"step {step + 1}: loss={loss:.4f}", flush=True)
    wall = time.perf_counter() - t0
    return {
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "steps_run": len(losses),
        "start_step": start_step,
        "wall_s": wall,
        "params": params,
        "opt_state": opt_state,
    }
