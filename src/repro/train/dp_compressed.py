"""Data-parallel train step with int8 error-feedback gradient compression.

A ``shard_map`` wrapper: each DP shard computes grads on its microbatch,
compresses, psums over the dp axis (int8 payload — 4× fewer bytes on the
slow cross-pod links), applies error feedback, then a replicated AdamW
update. Opt-in alternative to the GSPMD-managed pjit path for bandwidth-
constrained multi-pod DP of replicated-weight models (the small archs);
numerics validated against the uncompressed path in tests.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..distributed.collectives import compressed_psum, psum_mean
from .optimizer import AdamWConfig, adamw_update

Tree = Any


def make_dp_train_step(
    model,
    mesh,
    opt_cfg: Optional[AdamWConfig] = None,
    dp_axis: str = "data",
    compress: bool = True,
    remat: bool = False,
) -> Callable:
    """Returns step(params, opt_state, error_fb, batch) → (params, opt,
    error_fb, metrics). Params replicated; batch sharded over ``dp_axis``."""
    opt_cfg = opt_cfg or AdamWConfig()

    def loss_fn(params, mb):
        return model.loss(params, mb, remat=remat)

    def shard_body(params, opt_state, error_fb, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if compress:
            grads, error_fb = compressed_psum(grads, error_fb, dp_axis)
        else:
            grads = psum_mean(grads, dp_axis)
        loss = jax.lax.pmean(loss, dp_axis)
        new_params, new_opt, metrics = adamw_update(grads, opt_state, params, opt_cfg)
        return new_params, new_opt, error_fb, dict(metrics, loss=loss)

    from jax.experimental.shard_map import shard_map

    rep = P()
    batch_spec = P(dp_axis)

    def batch_specs(batch):
        return {k: batch_spec for k in batch}

    def step(params, opt_state, error_fb, batch):
        return shard_map(
            shard_body,
            mesh=mesh,
            in_specs=(rep, rep, rep, {k: batch_spec for k in batch}),
            out_specs=(rep, rep, rep, rep),
            check_rep=False,
        )(params, opt_state, error_fb, batch)

    return jax.jit(step, donate_argnums=(0, 1, 2))


def init_error_feedback(params: Tree) -> Tree:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
