from .optimizer import AdamWConfig, adamw_init, adamw_update, abstract_opt_state
from .train_step import make_train_step, make_eval_step
