"""nemotron-4-15b [dense] — GQA, squared-ReLU [arXiv:2402.16819; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    mlp_kind="squared_relu",
    norm_kind="layernorm",
    norm_eps=1e-5,
)

SMOKE_CONFIG = ArchConfig(
    name="nemotron-4-15b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=256,
    mlp_kind="squared_relu",
    norm_kind="layernorm",
    norm_eps=1e-5,
)
