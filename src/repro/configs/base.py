"""Architecture + shape-cell configuration schema.

Each assigned architecture gets one module in this package defining
``CONFIG`` (full size, exact dims from the brief) and ``SMOKE_CONFIG``
(reduced same-family config for CPU smoke tests). ``shapes.py`` defines the
four input-shape cells and the applicability rules (which cells run for
which family).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | vlm | ssm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 → d_model // n_heads

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 512         # scatter-dispatch group (seq-chunk) size

    # Attention details
    qk_norm: bool = False
    sliding_window: int = 0           # 0 = full attention
    rope_theta: float = 10_000.0
    m_rope: bool = False
    m_rope_sections: Tuple[int, int, int] = (16, 24, 24)

    # Block internals
    mlp_kind: str = "swiglu"          # swiglu | squared_relu | gelu
    norm_kind: str = "rmsnorm"        # rmsnorm | layernorm
    norm_eps: float = 1e-6
    use_bias: bool = False
    tie_embeddings: bool = False

    # Hybrid / recurrent structure (recurrentgemma, xlstm)
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    rnn_width: int = 0                # RG-LRU recurrence width (0 → d_model)
    conv1d_width: int = 4             # RG-LRU temporal conv window

    # Encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0

    # VLM stub frontend
    num_patch_tokens: int = 0         # precomputed patch embeddings per sample

    dtype: str = "bfloat16"

    # ---------------------------------------------------------------- #
    def __post_init__(self):
        if self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: heads {self.n_heads} % kv {self.n_kv_heads}")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if decode memory/compute per token is bounded (long_500k ok)."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window > 0
        )

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS = 6·N·D accounting."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * (self.n_heads * hd) + d * (2 * self.n_kv_heads * hd) + (self.n_heads * hd) * d
        if self.is_moe:
            per_expert = (3 if self.mlp_kind == "swiglu" else 2) * d * self.d_ff
            mlp = self.n_experts * per_expert + d * self.n_experts  # + router
        else:
            mlp = (3 if self.mlp_kind == "swiglu" else 2) * d * self.d_ff
        block = attn + mlp
        n_blocks = self.n_layers + self.encoder_layers
        return emb + n_blocks * block

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only top-k experts count)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        per_expert = (3 if self.mlp_kind == "swiglu" else 2) * d * self.d_ff
        inactive = self.n_layers * (self.n_experts - self.experts_per_token) * per_expert
        return self.param_count() - inactive
