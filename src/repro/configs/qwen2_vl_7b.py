"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution (stub frontend)
[arXiv:2409.12191; hf]. The vision tower is a STUB: input_specs provide
precomputed patch embeddings merged into the sequence prefix."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    m_rope=True,
    m_rope_sections=(16, 24, 24),
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=1_000_000.0,
    num_patch_tokens=256,
)

SMOKE_CONFIG = ArchConfig(
    name="qwen2-vl-7b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    m_rope=True,
    m_rope_sections=(2, 3, 3),
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    num_patch_tokens=4,
)
