"""recurrentgemma-9b [hybrid] — RG-LRU + local attn, 1:2
[arXiv:2402.19427; unverified]. 38 layers = 12×(rec,rec,attn) + 2 rec tail."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    sliding_window=2048,
    block_pattern=("rec", "rec", "attn"),
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
)

SMOKE_CONFIG = ArchConfig(
    name="recurrentgemma-9b-smoke",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab_size=256,
    sliding_window=8,
    block_pattern=("rec", "rec", "attn"),
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
)
