"""starcoder2-7b [dense] — GQA, RoPE, GELU MLP + biases [arXiv:2402.19173; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    mlp_kind="gelu",
    norm_kind="layernorm",
    use_bias=True,
    norm_eps=1e-5,
)

SMOKE_CONFIG = ArchConfig(
    name="starcoder2-7b-smoke",
    family="dense",
    n_layers=2,
    d_model=72,
    n_heads=6,
    n_kv_heads=2,
    d_ff=288,
    vocab_size=256,
    mlp_kind="gelu",
    norm_kind="layernorm",
    use_bias=True,
    norm_eps=1e-5,
)
