"""whisper-small [audio] — enc-dec, conv frontend STUB [arXiv:2212.04356;
unverified]. input_specs provide precomputed frame embeddings."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    mlp_kind="gelu",
    norm_kind="layernorm",
    norm_eps=1e-5,
    use_bias=True,
    tie_embeddings=True,
    is_encoder_decoder=True,
    encoder_layers=12,
)

SMOKE_CONFIG = ArchConfig(
    name="whisper-small-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    mlp_kind="gelu",
    norm_kind="layernorm",
    norm_eps=1e-5,
    use_bias=True,
    tie_embeddings=True,
    is_encoder_decoder=True,
    encoder_layers=2,
)
