"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].
d_ff=0: xLSTM blocks carry their own projections; no separate FFN."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "slstm"),
)

SMOKE_CONFIG = ArchConfig(
    name="xlstm-350m-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=256,
    block_pattern=("mlstm", "slstm"),
)
