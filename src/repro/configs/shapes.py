"""The four assigned input-shape cells and family applicability rules."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from .base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def cell_applicable(cfg: ArchConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped). Skips follow DESIGN.md §Arch-applicability:
    ``long_500k`` requires sub-quadratic attention; every assigned arch has a
    decode step (whisper is enc-dec, not encoder-only)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full quadratic attention — 500k decode KV infeasible (per brief)"
    return True, ""


def applicable_cells(cfg: ArchConfig) -> List[ShapeCell]:
    return [s for s in SHAPES if cell_applicable(cfg, s)[0]]
