"""Architecture registry: ``get_config(name)`` / ``get_smoke_config(name)``.

The 10 assigned architectures (exact dims from the brief) plus the paper's
own LLaMA-65B (used by the serving reproduction, not part of the 40-cell
dry-run table).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from .base import ArchConfig
from .shapes import SHAPES, SHAPES_BY_NAME, ShapeCell, applicable_cells, cell_applicable

ARCH_IDS: List[str] = [
    "mixtral_8x22b",
    "olmoe_1b_7b",
    "qwen3_8b",
    "starcoder2_7b",
    "granite_3_8b",
    "nemotron_4_15b",
    "qwen2_vl_7b",
    "xlstm_350m",
    "recurrentgemma_9b",
    "whisper_small",
]

EXTRA_IDS = ["llama_65b"]


def _module(name: str):
    key = name.replace("-", "_")
    if key not in ARCH_IDS + EXTRA_IDS:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_IDS + EXTRA_IDS}")
    return importlib.import_module(f".{key}", __package__)


def get_config(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    return _module(name).SMOKE_CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
