"""mixtral-8x22b [moe] — 8 experts top-2, SWA [arXiv:2401.04088; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    n_experts=8,
    experts_per_token=2,
    moe_group_size=256,
    sliding_window=4096,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=1_000_000.0,
)

SMOKE_CONFIG = ArchConfig(
    name="mixtral-8x22b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    n_experts=4,
    experts_per_token=2,
    sliding_window=8,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    moe_capacity_factor=2.0,
)
