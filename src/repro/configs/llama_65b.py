"""llama-65b — the paper's own serving model (Table III). Not part of the
assigned 40-cell table; used by the reproduction narrative and engine demos."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-65b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=64,
    d_ff=22016,
    vocab_size=32000,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
)

SMOKE_CONFIG = ArchConfig(
    name="llama-65b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=160,
    vocab_size=256,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
)
