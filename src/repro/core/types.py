"""Core datatypes for the hybrid offline-online LLM inference scheduler.

These types are framework-agnostic (pure Python) so the same scheduler code
drives both the event-driven simulator (paper reproduction) and the real JAX
serving engine (``repro.serving.engine``).

Notation follows the paper (TABLE II):
  I  — set of requests, each with prefill tokens N_i^p and decode tokens N_i^d
  J  — set of clients (= decode batch slots in the engine)
  K  — bins; bin k = one prefill stage followed by one decode stage
  L  — prefill levels (token-capacity buckets with duration T_l^p)
"""
from __future__ import annotations

import enum
import json
from dataclasses import dataclass, field, asdict
from typing import Dict, List, Optional, Sequence, Tuple


class Phase(enum.Enum):
    """Lifecycle phase of a request."""

    WAITING = "waiting"      # not yet prefilled
    PREFILL = "prefill"      # currently in a prefill stage
    DECODE = "decode"        # prefilled, decoding (possibly preempted)
    DONE = "done"


class StageKind(enum.Enum):
    """PD-Competition stage type — the system runs exactly one at a time.

    ``MIXED`` is the continuous-batching stage the mixed-step engine path
    dispatches: one decode round for every active slot *plus* a budget of
    prefill-chunk tokens co-processed in the same call, so prefill
    piggybacks on decode instead of preempting it.
    """

    PREFILL = "prefill"
    DECODE = "decode"
    MIXED = "mixed"


@dataclass
class Request:
    """One inference request.

    ``n_decode`` is the *true* output length (unknown to the scheduler until
    the EOS materializes); ``n_decode_est`` is what offline planning may use
    (the paper plans with estimates and executes under uncertainty).
    """

    rid: int
    n_prefill: int
    n_decode: int
    n_decode_est: Optional[int] = None
    arrival: float = 0.0
    # Latency SLOs (None = no deadline declared). ``ttft_slo_s`` bounds
    # time-to-first-token (first token time − arrival); ``tbt_slo_s`` bounds
    # the request's mean time-between-tokens. Goodput counts only the output
    # tokens of requests that met every SLO they declared (HyGen's metric).
    ttft_slo_s: Optional[float] = None
    tbt_slo_s: Optional[float] = None
    # Shared-prefix identity: the first ``prefix_len`` prompt tokens are
    # drawn from the group's stream instead of the request's own, so
    # requests in the same group share a byte-identical prompt prefix the
    # prefix cache can serve. Both are workload identity (like n_prefill),
    # not execution bookkeeping — reset() leaves them alone and prompts
    # stay reconstructible from the Request after migration or restore.
    prefix_group: Optional[int] = None
    prefix_len: int = 0

    # Execution bookkeeping (filled by simulator/engine).
    client: Optional[int] = None
    prefill_bin: Optional[int] = None
    decoded: int = 0
    t_prefill_start: Optional[float] = None
    t_prefill_end: Optional[float] = None
    t_done: Optional[float] = None
    # First-token time: set at the FIRST prefill completion only. Preemption
    # recomputes a prefill (t_prefill_end moves), but TTFT is pinned to when
    # the request's first token actually emerged.
    t_first_token: Optional[float] = None
    # Times this request was preempted from a bound slot (pages evicted,
    # re-queued with its generated prefix). A preempted request re-prefills,
    # so trace validation expects 1 + preemptions prefill completions.
    preemptions: int = 0
    # Times this request was pulled off a SUSPECT replica's queue and
    # re-placed on a healthy one (deadline-aware backoff redispatch). The
    # request never started on the suspect, so redispatch — unlike
    # preemption — changes no prefill accounting.
    redispatches: int = 0
    # Prompt tokens served from the prefix cache at the last admission
    # (pages adopted instead of recomputed). Execution bookkeeping for
    # cache-aware pricing — every layer that prices prefill should charge
    # ``uncached_prefill``, not the nominal prompt length.
    cached_prefill: int = 0

    def __post_init__(self) -> None:
        if self.n_prefill <= 0:
            raise ValueError(f"request {self.rid}: n_prefill must be positive")
        if self.n_decode <= 0:
            raise ValueError(f"request {self.rid}: n_decode must be positive")
        if not 0 <= self.prefix_len <= self.n_prefill:
            raise ValueError(
                f"request {self.rid}: prefix_len {self.prefix_len} outside "
                f"[0, n_prefill={self.n_prefill}]"
            )
        if self.n_decode_est is None:
            self.n_decode_est = self.n_decode

    @property
    def total_tokens(self) -> int:
        return self.n_prefill + self.n_decode

    @property
    def est_total_tokens(self) -> int:
        return self.n_prefill + int(self.n_decode_est or self.n_decode)

    @property
    def remaining_decode(self) -> int:
        return self.n_decode - self.decoded

    @property
    def uncached_prefill(self) -> int:
        """Prompt tokens that actually need compute given the last cache
        probe/admission — what cache-aware pricing charges for prefill."""
        return max(self.n_prefill - self.cached_prefill, 0)

    def _t_first(self) -> Optional[float]:
        # executors that predate first-token tracking (the simulator) only
        # stamp t_prefill_end — equivalent when nothing is ever preempted
        if self.t_first_token is not None:
            return self.t_first_token
        return self.t_prefill_end

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (None until the first token emerges)."""
        t1 = self._t_first()
        if t1 is None:
            return None
        return t1 - self.arrival

    @property
    def mean_tbt(self) -> Optional[float]:
        """Mean time between tokens over the decode phase, preemption gaps
        included (an evicted request honestly pays its recompute delay
        here). None until done; 0.0 for single-token outputs."""
        t1 = self._t_first()
        if self.t_done is None or t1 is None:
            return None
        if self.n_decode <= 1:
            return 0.0
        return (self.t_done - t1) / (self.n_decode - 1)

    @property
    def has_slo(self) -> bool:
        return self.ttft_slo_s is not None or self.tbt_slo_s is not None

    @property
    def slo_attained(self) -> bool:
        """True when every declared SLO was met (vacuously true with none
        declared). An unfinished request with a deadline counts as missed."""
        if self.ttft_slo_s is not None:
            if self.ttft is None or self.ttft > self.ttft_slo_s:
                return False
        if self.tbt_slo_s is not None:
            tbt = self.mean_tbt
            if tbt is None or tbt > self.tbt_slo_s:
                return False
        return True

    def reset(self) -> None:
        """Clear execution bookkeeping (so one workload can be re-simulated)."""
        self.client = None
        self.prefill_bin = None
        self.decoded = 0
        self.t_prefill_start = None
        self.t_prefill_end = None
        self.t_done = None
        self.t_first_token = None
        self.preemptions = 0
        self.redispatches = 0
        self.cached_prefill = 0


@dataclass
class ClientState:
    """State of one client (batch slot)."""

    cid: int
    current: Optional[Request] = None        # request being decoded
    backlog: List[Request] = field(default_factory=list)  # offline-assigned queue
    busy_time: float = 0.0                   # accumulated busy client-time

    @property
    def idle(self) -> bool:
        return self.current is None

    def remain_token(self) -> int:
        """Expected remaining tokens in this client's backlog (Algorithm 1)."""
        return sum(r.est_total_tokens for r in self.backlog)


@dataclass
class StageRecord:
    """One executed stage, for the Gantt chart and utilization accounting."""

    kind: StageKind
    t_start: float
    t_end: float
    bin_index: int
    # Clients busy during this stage and the request they worked on.
    busy: Dict[int, int] = field(default_factory=dict)  # cid -> rid
    # Clients running a *non-final* prefill chunk (chunked-prefill engine):
    # they are busy for utilization accounting but the request is not yet
    # fully prefilled — validate() counts a request's prefill at the stage
    # where its last chunk lands (the stage that puts it in ``busy``).
    busy_partial: Dict[int, int] = field(default_factory=dict)  # cid -> rid
    tokens: int = 0          # tokens processed in this stage
    rounds: int = 0          # decode rounds contained (decode stages only)
    level: Optional[int] = None  # prefill level index (prefill stages only)
    # Mixed stages: prefill-chunk tokens co-processed with the decode round
    # (tokens - chunk_tokens = decode tokens emitted), and the requests whose
    # *final* chunk landed here (validate counts their prefill at this stage;
    # a mixed stage's ``busy`` also holds slots that were merely decoding).
    chunk_tokens: int = 0
    prefilled: Dict[int, int] = field(default_factory=dict)  # cid -> rid
    # True when prefill work was pending or in flight while this stage ran —
    # the "during a prefill burst" tag the latency benchmarks slice on.
    burst: bool = False

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start


@dataclass
class ScheduleTrace:
    """Full execution trace of one simulated (or real) serve run."""

    num_clients: int
    stages: List[StageRecord] = field(default_factory=list)
    requests: List[Request] = field(default_factory=list)
    decision_times_ms: List[float] = field(default_factory=list)
    policy_name: str = ""
    # Executor-side counters that have no stage-level representation (the
    # engine fills e.g. mixed_rounds / prefill_stall_time_s); merged into
    # ``summary()`` so serve() results carry them without schema changes.
    meta: Dict[str, float] = field(default_factory=dict)
    # rid -> prefill completions the request performed on OTHER traces
    # before it was live-migrated (KV page-copy) into this one. A migrated
    # request arrives mid-decode without ever prefilling here, so validate()
    # credits these against the 1 + preemptions expectation; the exporter
    # drops the request from its own trace, keeping fleet-level accounting
    # exactly-once.
    external_prefills: Dict[int, int] = field(default_factory=dict)

    @property
    def makespan(self) -> float:
        return self.stages[-1].t_end if self.stages else 0.0

    @property
    def total_prefill_time(self) -> float:
        """Wall-clock spent on prefill work; a MIXED stage contributes its
        duration weighted by the chunk-token share of the batch."""
        out = 0.0
        for s in self.stages:
            if s.kind is StageKind.PREFILL:
                out += s.duration
            elif s.kind is StageKind.MIXED and s.tokens > 0:
                out += s.duration * s.chunk_tokens / s.tokens
        return out

    @property
    def total_decode_time(self) -> float:
        """Wall-clock spent on decode work (MIXED stages weighted by their
        decode-token share)."""
        out = 0.0
        for s in self.stages:
            if s.kind is StageKind.DECODE:
                out += s.duration
            elif s.kind is StageKind.MIXED and s.tokens > 0:
                out += s.duration * (s.tokens - s.chunk_tokens) / s.tokens
        return out

    @property
    def busy_client_time(self) -> float:
        """Σ over stages of (busy clients × stage duration)."""
        return sum(
            (len(s.busy) + len(s.busy_partial)) * s.duration for s in self.stages
        )

    @property
    def idle_gap_time(self) -> float:
        """Wall-clock inside the makespan during which NO stage ran.

        Closed-loop serves tile the timeline and report 0 here. Open-loop
        serves fast-forward the stage clock across empty arrival gaps (the
        engine idles until ``next_arrival``), which leaves real holes between
        consecutive stages — forced idle the *workload* caused, not the
        scheduler. Splitting it out lets ``utilization`` (the paper's
        closed-loop Gantt metric, gaps included) and
        ``busy_window_utilization`` (gaps excluded — how well the scheduler
        used the time it actually had work) be reported side by side instead
        of silently conflated.
        """
        if not self.stages:
            return 0.0
        gap = max(self.stages[0].t_start, 0.0)
        prev_end = self.stages[0].t_end
        for s in self.stages[1:]:
            gap += max(s.t_start - prev_end, 0.0)
            prev_end = s.t_end
        return gap

    @property
    def busy_window(self) -> float:
        """Makespan minus forced-idle arrival gaps: the wall-clock during
        which at least one stage was running."""
        return self.makespan - self.idle_gap_time

    @property
    def utilization(self) -> float:
        """Busy client-time over total client-time — the paper's Gantt metric.

        Includes forced-idle arrival gaps in the denominator (an open-loop
        serve that waits for traffic reports lower utilization); see
        ``busy_window_utilization`` for the gap-excluded view.
        """
        if not self.stages:
            return 0.0
        return self.busy_client_time / (self.makespan * self.num_clients)

    @property
    def busy_window_utilization(self) -> float:
        """Busy client-time over the busy window (arrival gaps excluded) —
        the scheduler-quality metric an open-loop run should be judged on.
        Equal to ``utilization`` for closed-loop serves (no gaps)."""
        window = self.busy_window
        if window <= 0:
            return 0.0
        return self.busy_client_time / (window * self.num_clients)

    @property
    def computed_prefill_tokens(self) -> int:
        """Prefill tokens that actually ran through the model: PREFILL-stage
        tokens plus the chunk share of MIXED stages. Cached (prefix-cache
        adopted) tokens never enter a stage, so utilization accounting sees
        only real work — the cached count is reported beside this
        (``cached_prefill_tokens`` in meta / summary), never inside it."""
        return sum(
            s.tokens if s.kind is StageKind.PREFILL else s.chunk_tokens
            for s in self.stages
            if s.kind in (StageKind.PREFILL, StageKind.MIXED)
        )

    @property
    def cached_prefill_tokens(self) -> int:
        """Prompt tokens served from the prefix cache instead of computed
        (engine-filled meta counter; 0 for executors without a cache)."""
        return int(self.meta.get("cached_prefill_tokens", 0))

    @property
    def total_generated_tokens(self) -> int:
        return sum(r.n_decode for r in self.requests)

    @property
    def generation_speed(self) -> float:
        """Output tokens per second (the paper's Fig. 11 metric). Divides by
        the full makespan, arrival gaps included — the open-loop analogue is
        ``busy_window_generation_speed``."""
        if self.makespan <= 0:
            return 0.0
        return self.total_generated_tokens / self.makespan

    @property
    def busy_window_generation_speed(self) -> float:
        """Output tokens per second of *busy* wall-clock (arrival gaps
        excluded) — what the engine sustains while it actually has work."""
        window = self.busy_window
        if window <= 0:
            return 0.0
        return self.total_generated_tokens / window

    @property
    def num_bins(self) -> int:
        return 1 + max((s.bin_index for s in self.stages), default=-1)

    # -- SLO attainment + goodput (the overload-control objective) ------ #
    @property
    def slo_tracked_requests(self) -> List[Request]:
        """Requests that declared at least one SLO."""
        return [r for r in self.requests if r.has_slo]

    @property
    def slo_attainment(self) -> float:
        """Fraction of SLO-declaring requests that met every declared SLO
        (1.0 when none declared any — nothing to miss)."""
        tracked = self.slo_tracked_requests
        if not tracked:
            return 1.0
        return sum(r.slo_attained for r in tracked) / len(tracked)

    @property
    def goodput_tokens(self) -> int:
        """Output tokens of requests that met their SLOs (requests with no
        SLO count in full — there was no deadline to miss)."""
        return sum(r.n_decode for r in self.requests if r.slo_attained)

    @property
    def goodput(self) -> float:
        """SLO-attaining output tokens per second of makespan (HyGen's
        goodput). Equals ``generation_speed`` when every SLO is met or no
        request declared one; the gap between the two is the throughput
        the serve delivered too late to count."""
        if self.makespan <= 0:
            return 0.0
        return self.goodput_tokens / self.makespan

    def ttft_p95(self) -> float:
        """p95 TTFT over SLO-tracked requests (0.0 with none tracked)."""
        vals = sorted(
            r.ttft for r in self.slo_tracked_requests if r.ttft is not None
        )
        if not vals:
            return 0.0
        return vals[min(len(vals) - 1, int(0.95 * len(vals)))]

    @property
    def preemption_count(self) -> int:
        return sum(r.preemptions for r in self.requests)

    def summary(self) -> Dict[str, float]:
        return {
            "policy": self.policy_name,
            "num_requests": len(self.requests),
            "num_clients": self.num_clients,
            "num_bins": self.num_bins,
            "makespan_s": round(self.makespan, 4),
            "utilization": round(self.utilization, 6),
            "busy_window_utilization": round(self.busy_window_utilization, 6),
            "idle_gap_s": round(self.idle_gap_time, 4),
            "generation_speed_tok_s": round(self.generation_speed, 3),
            "busy_window_generation_speed_tok_s": round(
                self.busy_window_generation_speed, 3
            ),
            "goodput_tok_s": round(self.goodput, 3),
            "slo_attainment": round(self.slo_attainment, 6),
            "slo_tracked": len(self.slo_tracked_requests),
            "preemptions": self.preemption_count,
            "prefill_time_s": round(self.total_prefill_time, 4),
            "decode_time_s": round(self.total_decode_time, 4),
            # cached vs computed prefill: cached tokens were adopted from
            # the prefix cache, not processed — they are not "busy" work
            "computed_prefill_tokens": self.computed_prefill_tokens,
            "cached_prefill_tokens": self.cached_prefill_tokens,
            "max_decision_ms": round(max(self.decision_times_ms), 4)
            if self.decision_times_ms
            else 0.0,
            "mean_decision_ms": round(
                sum(self.decision_times_ms) / len(self.decision_times_ms), 5
            )
            if self.decision_times_ms
            else 0.0,
            # meta is declared Dict[str, float] and summary() guarantees it:
            # only scalar leaves pass through. Structured event records
            # (fault logs, fenced logs, ...) belong in the observability
            # registry's typed log side-channel (repro.obs), never here.
            **{
                k: v for k, v in self.meta.items()
                if isinstance(v, (int, float, bool))
            },
        }

    def validate(self) -> None:
        """Invariant checks (used by tests and after every simulation).

        - stages tile the timeline with no overlap and no negative durations
        - every request decoded exactly n_decode tokens and completed a
          prefill exactly 1 + preemptions times (each preemption-by-eviction
          recomputes the prefill from the generated prefix)
        - a client is never busy with two requests in one stage
        """
        t = 0.0
        for s in self.stages:
            if s.t_start < t - 1e-9:
                raise AssertionError(f"stage overlap at t={s.t_start} (< {t})")
            if s.duration < -1e-12:
                raise AssertionError("negative stage duration")
            t = s.t_end
        prefilled: Dict[int, int] = {}
        for s in self.stages:
            if s.busy.keys() & s.busy_partial.keys():
                raise AssertionError(
                    "client both finishing and mid-chunk in one stage"
                )
            if s.kind is StageKind.PREFILL:
                for cid, rid in s.busy.items():
                    prefilled[rid] = prefilled.get(rid, 0) + 1
            elif s.kind is StageKind.MIXED:
                # a mixed stage's ``busy`` mixes decoders with finishing
                # prefills — only ``prefilled`` names completed prefills
                for cid, rid in s.prefilled.items():
                    prefilled[rid] = prefilled.get(rid, 0) + 1
        for r in self.requests:
            expected = 1 + r.preemptions
            actual = prefilled.get(r.rid, 0) + self.external_prefills.get(r.rid, 0)
            if actual != expected:
                raise AssertionError(
                    f"request {r.rid} prefilled {actual} "
                    f"times (expected {expected} for {r.preemptions} "
                    f"preemptions; "
                    f"{self.external_prefills.get(r.rid, 0)} external)"
                )
            if r.decoded != r.n_decode:
                raise AssertionError(
                    f"request {r.rid} decoded {r.decoded}/{r.n_decode} tokens"
                )
            if r.t_done is None:
                raise AssertionError(f"request {r.rid} never finished")

    def to_json(self) -> str:
        return json.dumps(
            {
                "summary": self.summary(),
                "stages": [
                    {
                        "kind": s.kind.value,
                        "t_start": s.t_start,
                        "t_end": s.t_end,
                        "bin": s.bin_index,
                        "busy": s.busy,
                        "tokens": s.tokens,
                        "rounds": s.rounds,
                        "level": s.level,
                        "chunk_tokens": s.chunk_tokens,
                    }
                    for s in self.stages
                ],
            }
        )


@dataclass
class FleetReport:
    """Aggregate of N replica ``ScheduleTrace``s — one fleet-level serve.

    Replicas run in parallel wall-clock (each trace's stage clock starts at
    0), so the fleet makespan is the *max* replica makespan, fleet busy
    client-time is the *sum* of replica busy client-times, and utilization
    divides speed-weighted busy time by makespan × speed-weighted capacity.
    ``lower_bound_s`` is ``theoretical_lower_bound`` evaluated at
    n_clients = replicas × slots for a homogeneous fleet — the whole fleet
    treated as one flat pool of clients, exactly the paper's bound — and
    ``core.hetero.hetero_theoretical_lower_bound`` (the R||Cmax
    generalization, which recovers the flat-pool bound at equal speeds)
    whenever replicas differ. Either way it is a floor no partitioned
    execution can beat (``lb_ratio`` ≥ 1 up to cost-model fit error).
    """

    policy_name: str
    n_replicas: int
    slots_per_replica: int
    traces: List[ScheduleTrace] = field(default_factory=list)
    lower_bound_s: float = 0.0
    steal_events: int = 0
    offline_solver: str = ""
    offline_gap: float = 0.0
    # Per-replica relative speeds (1.0 = baseline). Empty means homogeneous.
    # Utilization weights busy time and capacity by these factors: a
    # replica's capacity is speed × slots, and a busy-second on it is worth
    # speed × one baseline busy-second — so an idle *slow* replica wastes
    # proportionally less fleet capacity than an idle fast one, and a
    # deliberately-slow replica no longer deflates fleet utilization on an
    # otherwise well-balanced run.
    speed_factors: List[float] = field(default_factory=list)
    meta: Dict[str, float] = field(default_factory=dict)

    @property
    def total_slots(self) -> int:
        return self.n_replicas * self.slots_per_replica

    def _replica_speeds(self) -> List[float]:
        if self.speed_factors and len(self.speed_factors) == len(self.traces):
            return [float(s) for s in self.speed_factors]
        return [1.0] * len(self.traces)

    @property
    def makespan(self) -> float:
        return max((t.makespan for t in self.traces), default=0.0)

    @property
    def busy_client_time(self) -> float:
        return sum(t.busy_client_time for t in self.traces)

    @property
    def weighted_busy_client_time(self) -> float:
        """Busy client-time in *capacity units*: each replica's busy time
        weighted by its speed (a speed-0.5 replica busy for 1 s did half a
        baseline-replica-second of work)."""
        return sum(
            s * t.busy_client_time
            for s, t in zip(self._replica_speeds(), self.traces)
        )

    @property
    def weighted_capacity_slots(self) -> float:
        """Speed-weighted slot count: Σ_j speed_j × slots — the fleet's
        aggregate capacity per unit wall-clock. Equals ``total_slots`` for
        a homogeneous fleet."""
        return self.slots_per_replica * sum(self._replica_speeds())

    @property
    def utilization(self) -> float:
        """Speed-weighted fleet busy time over makespan × speed-weighted
        capacity — the paper's Gantt metric lifted to replica granularity,
        with both numerator and denominator in capacity units so mixed-speed
        fleets are judged against what they could actually do. Reduces
        exactly to Σ busy / (makespan × N·slots) when all speeds are 1.0. A
        straggler replica drags this down for everyone, which is what the
        offline bin packing + work stealing exist to prevent."""
        span = self.makespan
        cap = self.weighted_capacity_slots
        if span <= 0 or cap <= 0:
            return 0.0
        return self.weighted_busy_client_time / (span * cap)

    @property
    def busy_window_utilization(self) -> float:
        """Gap-excluded fleet utilization: speed-weighted busy client-time
        over the fleet-wide max busy window × speed-weighted capacity (see
        ``ScheduleTrace.busy_window_utilization``)."""
        window = max((t.busy_window for t in self.traces), default=0.0)
        cap = self.weighted_capacity_slots
        if window <= 0 or cap <= 0:
            return 0.0
        return self.weighted_busy_client_time / (window * cap)

    @property
    def generation_speed(self) -> float:
        span = self.makespan
        if span <= 0:
            return 0.0
        return sum(t.total_generated_tokens for t in self.traces) / span

    @property
    def goodput(self) -> float:
        """Fleet goodput: SLO-attaining output tokens across every replica
        per second of fleet makespan (replicas run in parallel)."""
        span = self.makespan
        if span <= 0:
            return 0.0
        return sum(t.goodput_tokens for t in self.traces) / span

    @property
    def slo_attainment(self) -> float:
        tracked = [r for t in self.traces for r in t.slo_tracked_requests]
        if not tracked:
            return 1.0
        return sum(r.slo_attained for r in tracked) / len(tracked)

    @property
    def preemption_count(self) -> int:
        return sum(t.preemption_count for t in self.traces)

    @property
    def lb_ratio(self) -> float:
        """Fleet makespan over the flat-pool lower bound (≥ 1 ideally)."""
        if self.lower_bound_s <= 0:
            return 0.0 if self.makespan <= 0 else float("inf")
        return self.makespan / self.lower_bound_s

    def summary(self) -> Dict[str, float]:
        per_replica = [t.summary() for t in self.traces]
        return {
            "policy": self.policy_name,
            "n_replicas": self.n_replicas,
            "slots_per_replica": self.slots_per_replica,
            "num_requests": sum(len(t.requests) for t in self.traces),
            "makespan_s": round(self.makespan, 4),
            "fleet_utilization": round(self.utilization, 6),
            "busy_window_utilization": round(self.busy_window_utilization, 6),
            "generation_speed_tok_s": round(self.generation_speed, 3),
            "goodput_tok_s": round(self.goodput, 3),
            "slo_attainment": round(self.slo_attainment, 6),
            "preemptions": self.preemption_count,
            "lower_bound_s": round(self.lower_bound_s, 4),
            "lb_ratio": round(self.lb_ratio, 4),
            "steal_events": self.steal_events,
            "offline_solver": self.offline_solver,
            "offline_gap": round(self.offline_gap, 6),
            "speed_factors": [round(s, 4) for s in self.speed_factors],
            "replica_makespans_s": [round(t.makespan, 4) for t in self.traces],
            "replica_requests": [len(t.requests) for t in self.traces],
            "replica_summaries": per_replica,
            # scalar leaves only — structured logs live in the observability
            # registry's typed side-channel (repro.obs), never in meta
            **{
                k: v for k, v in self.meta.items()
                if isinstance(v, (int, float, bool))
            },
        }

    def validate(self) -> None:
        """Fleet-level invariants: every replica trace is internally valid,
        and no request appears in (was served by) two replicas."""
        seen: Dict[int, int] = {}
        for idx, t in enumerate(self.traces):
            t.validate()
            for r in t.requests:
                if r.rid in seen:
                    raise AssertionError(
                        f"request {r.rid} served by replicas "
                        f"{seen[r.rid]} and {idx}"
                    )
                seen[r.rid] = idx


def make_requests(
    prefill_lens: Sequence[int],
    decode_lens: Sequence[int],
    decode_ests: Optional[Sequence[int]] = None,
) -> List[Request]:
    """Convenience constructor used by tests and workload generators."""
    if len(prefill_lens) != len(decode_lens):
        raise ValueError("prefill/decode length mismatch")
    reqs = []
    for i, (p, d) in enumerate(zip(prefill_lens, decode_lens)):
        est = None if decode_ests is None else int(decode_ests[i])
        reqs.append(Request(rid=i, n_prefill=int(p), n_decode=int(d), n_decode_est=est))
    return reqs
