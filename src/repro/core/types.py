"""Core datatypes for the hybrid offline-online LLM inference scheduler.

These types are framework-agnostic (pure Python) so the same scheduler code
drives both the event-driven simulator (paper reproduction) and the real JAX
serving engine (``repro.serving.engine``).

Notation follows the paper (TABLE II):
  I  — set of requests, each with prefill tokens N_i^p and decode tokens N_i^d
  J  — set of clients (= decode batch slots in the engine)
  K  — bins; bin k = one prefill stage followed by one decode stage
  L  — prefill levels (token-capacity buckets with duration T_l^p)
"""
from __future__ import annotations

import enum
import json
from dataclasses import dataclass, field, asdict
from typing import Dict, List, Optional, Sequence, Tuple


class Phase(enum.Enum):
    """Lifecycle phase of a request."""

    WAITING = "waiting"      # not yet prefilled
    PREFILL = "prefill"      # currently in a prefill stage
    DECODE = "decode"        # prefilled, decoding (possibly preempted)
    DONE = "done"


class StageKind(enum.Enum):
    """PD-Competition stage type — the system runs exactly one at a time.

    ``MIXED`` is the continuous-batching stage the mixed-step engine path
    dispatches: one decode round for every active slot *plus* a budget of
    prefill-chunk tokens co-processed in the same call, so prefill
    piggybacks on decode instead of preempting it.
    """

    PREFILL = "prefill"
    DECODE = "decode"
    MIXED = "mixed"


@dataclass
class Request:
    """One inference request.

    ``n_decode`` is the *true* output length (unknown to the scheduler until
    the EOS materializes); ``n_decode_est`` is what offline planning may use
    (the paper plans with estimates and executes under uncertainty).
    """

    rid: int
    n_prefill: int
    n_decode: int
    n_decode_est: Optional[int] = None
    arrival: float = 0.0

    # Execution bookkeeping (filled by simulator/engine).
    client: Optional[int] = None
    prefill_bin: Optional[int] = None
    decoded: int = 0
    t_prefill_start: Optional[float] = None
    t_prefill_end: Optional[float] = None
    t_done: Optional[float] = None

    def __post_init__(self) -> None:
        if self.n_prefill <= 0:
            raise ValueError(f"request {self.rid}: n_prefill must be positive")
        if self.n_decode <= 0:
            raise ValueError(f"request {self.rid}: n_decode must be positive")
        if self.n_decode_est is None:
            self.n_decode_est = self.n_decode

    @property
    def total_tokens(self) -> int:
        return self.n_prefill + self.n_decode

    @property
    def est_total_tokens(self) -> int:
        return self.n_prefill + int(self.n_decode_est or self.n_decode)

    @property
    def remaining_decode(self) -> int:
        return self.n_decode - self.decoded

    def reset(self) -> None:
        """Clear execution bookkeeping (so one workload can be re-simulated)."""
        self.client = None
        self.prefill_bin = None
        self.decoded = 0
        self.t_prefill_start = None
        self.t_prefill_end = None
        self.t_done = None


@dataclass
class ClientState:
    """State of one client (batch slot)."""

    cid: int
    current: Optional[Request] = None        # request being decoded
    backlog: List[Request] = field(default_factory=list)  # offline-assigned queue
    busy_time: float = 0.0                   # accumulated busy client-time

    @property
    def idle(self) -> bool:
        return self.current is None

    def remain_token(self) -> int:
        """Expected remaining tokens in this client's backlog (Algorithm 1)."""
        return sum(r.est_total_tokens for r in self.backlog)


@dataclass
class StageRecord:
    """One executed stage, for the Gantt chart and utilization accounting."""

    kind: StageKind
    t_start: float
    t_end: float
    bin_index: int
    # Clients busy during this stage and the request they worked on.
    busy: Dict[int, int] = field(default_factory=dict)  # cid -> rid
    # Clients running a *non-final* prefill chunk (chunked-prefill engine):
    # they are busy for utilization accounting but the request is not yet
    # fully prefilled — validate() counts a request's prefill at the stage
    # where its last chunk lands (the stage that puts it in ``busy``).
    busy_partial: Dict[int, int] = field(default_factory=dict)  # cid -> rid
    tokens: int = 0          # tokens processed in this stage
    rounds: int = 0          # decode rounds contained (decode stages only)
    level: Optional[int] = None  # prefill level index (prefill stages only)
    # Mixed stages: prefill-chunk tokens co-processed with the decode round
    # (tokens - chunk_tokens = decode tokens emitted), and the requests whose
    # *final* chunk landed here (validate counts their prefill at this stage;
    # a mixed stage's ``busy`` also holds slots that were merely decoding).
    chunk_tokens: int = 0
    prefilled: Dict[int, int] = field(default_factory=dict)  # cid -> rid
    # True when prefill work was pending or in flight while this stage ran —
    # the "during a prefill burst" tag the latency benchmarks slice on.
    burst: bool = False

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start


@dataclass
class ScheduleTrace:
    """Full execution trace of one simulated (or real) serve run."""

    num_clients: int
    stages: List[StageRecord] = field(default_factory=list)
    requests: List[Request] = field(default_factory=list)
    decision_times_ms: List[float] = field(default_factory=list)
    policy_name: str = ""
    # Executor-side counters that have no stage-level representation (the
    # engine fills e.g. mixed_rounds / prefill_stall_time_s); merged into
    # ``summary()`` so serve() results carry them without schema changes.
    meta: Dict[str, float] = field(default_factory=dict)

    @property
    def makespan(self) -> float:
        return self.stages[-1].t_end if self.stages else 0.0

    @property
    def total_prefill_time(self) -> float:
        """Wall-clock spent on prefill work; a MIXED stage contributes its
        duration weighted by the chunk-token share of the batch."""
        out = 0.0
        for s in self.stages:
            if s.kind is StageKind.PREFILL:
                out += s.duration
            elif s.kind is StageKind.MIXED and s.tokens > 0:
                out += s.duration * s.chunk_tokens / s.tokens
        return out

    @property
    def total_decode_time(self) -> float:
        """Wall-clock spent on decode work (MIXED stages weighted by their
        decode-token share)."""
        out = 0.0
        for s in self.stages:
            if s.kind is StageKind.DECODE:
                out += s.duration
            elif s.kind is StageKind.MIXED and s.tokens > 0:
                out += s.duration * (s.tokens - s.chunk_tokens) / s.tokens
        return out

    @property
    def busy_client_time(self) -> float:
        """Σ over stages of (busy clients × stage duration)."""
        return sum(
            (len(s.busy) + len(s.busy_partial)) * s.duration for s in self.stages
        )

    @property
    def utilization(self) -> float:
        """Busy client-time over total client-time — the paper's Gantt metric."""
        if not self.stages:
            return 0.0
        return self.busy_client_time / (self.makespan * self.num_clients)

    @property
    def total_generated_tokens(self) -> int:
        return sum(r.n_decode for r in self.requests)

    @property
    def generation_speed(self) -> float:
        """Output tokens per second (the paper's Fig. 11 metric)."""
        if self.makespan <= 0:
            return 0.0
        return self.total_generated_tokens / self.makespan

    @property
    def num_bins(self) -> int:
        return 1 + max((s.bin_index for s in self.stages), default=-1)

    def summary(self) -> Dict[str, float]:
        return {
            "policy": self.policy_name,
            "num_requests": len(self.requests),
            "num_clients": self.num_clients,
            "num_bins": self.num_bins,
            "makespan_s": round(self.makespan, 4),
            "utilization": round(self.utilization, 6),
            "generation_speed_tok_s": round(self.generation_speed, 3),
            "prefill_time_s": round(self.total_prefill_time, 4),
            "decode_time_s": round(self.total_decode_time, 4),
            "max_decision_ms": round(max(self.decision_times_ms), 4)
            if self.decision_times_ms
            else 0.0,
            "mean_decision_ms": round(
                sum(self.decision_times_ms) / len(self.decision_times_ms), 5
            )
            if self.decision_times_ms
            else 0.0,
            **self.meta,
        }

    def validate(self) -> None:
        """Invariant checks (used by tests and after every simulation).

        - stages tile the timeline with no overlap and no negative durations
        - every request decoded exactly n_decode tokens, prefilled exactly once
        - a client is never busy with two requests in one stage
        """
        t = 0.0
        for s in self.stages:
            if s.t_start < t - 1e-9:
                raise AssertionError(f"stage overlap at t={s.t_start} (< {t})")
            if s.duration < -1e-12:
                raise AssertionError("negative stage duration")
            t = s.t_end
        prefilled: Dict[int, int] = {}
        for s in self.stages:
            if s.busy.keys() & s.busy_partial.keys():
                raise AssertionError(
                    "client both finishing and mid-chunk in one stage"
                )
            if s.kind is StageKind.PREFILL:
                for cid, rid in s.busy.items():
                    prefilled[rid] = prefilled.get(rid, 0) + 1
            elif s.kind is StageKind.MIXED:
                # a mixed stage's ``busy`` mixes decoders with finishing
                # prefills — only ``prefilled`` names completed prefills
                for cid, rid in s.prefilled.items():
                    prefilled[rid] = prefilled.get(rid, 0) + 1
        for r in self.requests:
            if prefilled.get(r.rid, 0) != 1:
                raise AssertionError(
                    f"request {r.rid} prefilled {prefilled.get(r.rid, 0)} times"
                )
            if r.decoded != r.n_decode:
                raise AssertionError(
                    f"request {r.rid} decoded {r.decoded}/{r.n_decode} tokens"
                )
            if r.t_done is None:
                raise AssertionError(f"request {r.rid} never finished")

    def to_json(self) -> str:
        return json.dumps(
            {
                "summary": self.summary(),
                "stages": [
                    {
                        "kind": s.kind.value,
                        "t_start": s.t_start,
                        "t_end": s.t_end,
                        "bin": s.bin_index,
                        "busy": s.busy,
                        "tokens": s.tokens,
                        "rounds": s.rounds,
                        "level": s.level,
                        "chunk_tokens": s.chunk_tokens,
                    }
                    for s in self.stages
                ],
            }
        )


def make_requests(
    prefill_lens: Sequence[int],
    decode_lens: Sequence[int],
    decode_ests: Optional[Sequence[int]] = None,
) -> List[Request]:
    """Convenience constructor used by tests and workload generators."""
    if len(prefill_lens) != len(decode_lens):
        raise ValueError("prefill/decode length mismatch")
    reqs = []
    for i, (p, d) in enumerate(zip(prefill_lens, decode_lens)):
        est = None if decode_ests is None else int(decode_ests[i])
        reqs.append(Request(rid=i, n_prefill=int(p), n_decode=int(d), n_decode_est=est))
    return reqs
