"""Online requests scheduling — Algorithm 1 (Sorting and Online Preemptive
Method) plus the no-stealing baselines.

A *request scheduler* answers one question at prefill-scheduling time:
"client ``j`` is idle — which request should it take next?" Three variants:

  * ``StaticBacklogScheduler`` — clients only consume their own offline
    backlog (baseline & offline-only configurations; Figs. 6–7).
  * ``SortingPreemptiveScheduler`` — Algorithm 1: backlogs are sorted by
    N_i^p + N_i^d descending; an idle client with an empty backlog *steals*
    the longest request from the client with the largest ``remain_token``
    (online-only & hybrid configurations; Figs. 8–9).
  * ``GlobalQueueScheduler`` — a single FCFS queue (what vLLM actually does);
    used for ablations.

``peek`` takes a ``claimed`` set so a whole prefill batch can be *proposed*
(one request per idle client) without mutating any backlog; the iteration
policy then decides whether the batch actually runs, and only then is it
committed. All schedulers operate on the same ``ClientState`` objects the
simulator and the real engine share.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from .types import ClientState, Request


class RequestScheduler:
    """Interface: proposes and commits requests for idle clients."""

    def has_pending(self) -> bool:
        raise NotImplementedError

    def pending_count(self) -> int:
        raise NotImplementedError

    def peek(self, client: ClientState, claimed: Set[int]) -> Optional[Request]:
        """Which request would ``client`` take next, ignoring ids in
        ``claimed``? Must not mutate state."""
        raise NotImplementedError

    def commit(self, client: ClientState, request: Request) -> None:
        """Remove ``request`` from whatever backlog ``peek`` found it in."""
        raise NotImplementedError

    # -- fleet hooks: external admission + cross-replica work stealing -- #
    def push(self, request: Request) -> None:
        """Admit a request from outside (fleet dispatch / stolen work).
        Optional — only queue-backed schedulers support it."""
        raise NotImplementedError(
            f"{type(self).__name__} does not accept external admissions"
        )

    def steal_longest(self) -> Optional[Request]:
        """Give up the longest not-yet-started request (for a starving
        replica), or None. Optional — queue-backed schedulers only."""
        return None

    def peek_longest(self) -> Optional[Request]:
        """The request ``steal_longest`` *would* surrender, without removing
        it — the fleet prices a candidate steal through both replicas' cost
        models before committing (popping and pushing back would reshuffle
        the queue order). Optional — queue-backed schedulers only."""
        return None

    @property
    def queued(self) -> Tuple[Request, ...]:
        """Snapshot of not-yet-started requests (fleet load estimation).
        Schedulers that cannot enumerate their backlog return ()."""
        return ()

    # ------------------------------------------------------------------ #
    def propose_batch(
        self,
        idle_clients: Sequence[ClientState],
        max_tokens: int,
        exclude: Set[int] = frozenset(),
    ) -> List[Tuple[ClientState, Request]]:
        """One candidate request per idle client, total prefill tokens ≤
        ``max_tokens`` (Eq. 6/16). A single request larger than the cap is
        admitted alone (the engine runs it as an oversize stage).
        ``exclude`` rids are skipped as if already claimed — an overload
        policy that defers an FCFS queue head re-proposes with the deferred
        rids excluded, so deferral cannot shadow admissible requests queued
        behind them (livelock otherwise: the idle slot would be offered the
        same deferred head forever)."""
        claimed: Set[int] = set(exclude)
        batch: List[Tuple[ClientState, Request]] = []
        total = 0
        for client in idle_clients:
            req = self.peek(client, claimed)
            if req is None:
                continue
            if batch and total + req.n_prefill > max_tokens:
                continue  # try remaining idle clients with smaller requests
            claimed.add(req.rid)
            batch.append((client, req))
            total += req.n_prefill
            if total >= max_tokens:
                break
        return batch

    def commit_batch(self, batch: Sequence[Tuple[ClientState, Request]]) -> None:
        for client, req in batch:
            self.commit(client, req)


def _sort_backlog(backlog: List[Request]) -> None:
    """Sort by N_i^p + N_i^d descending (Algorithm 1's required ordering)."""
    backlog.sort(key=lambda r: -r.est_total_tokens)


def _first_unclaimed(backlog: Sequence[Request], claimed: Set[int]) -> Optional[Request]:
    for r in backlog:
        if r.rid not in claimed:
            return r
    return None


class StaticBacklogScheduler(RequestScheduler):
    """Clients consume only their own backlog, in the given order."""

    def __init__(self, clients: Sequence[ClientState], sort_longest_first: bool = False):
        self._clients = list(clients)
        if sort_longest_first:
            for c in self._clients:
                _sort_backlog(c.backlog)

    def has_pending(self) -> bool:
        return any(c.backlog for c in self._clients)

    def pending_count(self) -> int:
        return sum(len(c.backlog) for c in self._clients)

    def peek(self, client: ClientState, claimed: Set[int]) -> Optional[Request]:
        return _first_unclaimed(client.backlog, claimed)

    def commit(self, client: ClientState, request: Request) -> None:
        client.backlog.remove(request)


class SortingPreemptiveScheduler(RequestScheduler):
    """Algorithm 1: sorted backlogs + work stealing from argmax remain_token.

    Faithful to the listing:

        for client j in J:
            if queue for client j is empty and I_j != ∅:  pop I_j to client j
            elif max(remain_token) > 0: pop argmax(remain_token) to client j

    ``remain_token(j) = Σ_{i∈I_j} (N_i^p + N_i^d)`` over the *backlog* (work
    not yet started). Stealing takes the longest request from the most-loaded
    backlog, so the makespan tail shrinks — this is the paper's request-level
    straggler mitigation.

    ``remain_token`` is maintained incrementally (updated on commit) and
    donor selection uses a heap, so a whole-batch proposal costs
    O(J + batch·log J) — well inside the paper's <10 ms decision budget even
    at thousands of clients (see ``benchmarks`` decision-latency table).
    """

    def __init__(self, clients: Sequence[ClientState]):
        self._clients = list(clients)
        self._by_cid = {c.cid: c for c in self._clients}
        for c in self._clients:
            _sort_backlog(c.backlog)
        self._remain = {c.cid: c.remain_token() for c in self._clients}
        self._total_pending = sum(len(c.backlog) for c in self._clients)

    def has_pending(self) -> bool:
        return self._total_pending > 0

    def pending_count(self) -> int:
        return self._total_pending

    def peek(self, client: ClientState, claimed: Set[int]) -> Optional[Request]:
        own = _first_unclaimed(client.backlog, claimed)
        if own is not None:
            return own
        # Steal from the client with the largest (unclaimed) remaining backlog.
        best, best_rem = None, 0
        for c in self._clients:
            rem = self._remain[c.cid] - sum(
                r.est_total_tokens for r in c.backlog if r.rid in claimed
            )
            if rem > best_rem:
                best, best_rem = c, rem
        if best is None:
            return None
        return _first_unclaimed(best.backlog, claimed)  # longest-first order

    def propose_batch(
        self,
        idle_clients: Sequence[ClientState],
        max_tokens: int,
        exclude: Set[int] = frozenset(),
    ) -> List[Tuple[ClientState, Request]]:
        """Heap-based batch proposal (same semantics as the generic one)."""
        import heapq

        claimed: Set[int] = set(exclude)
        batch: List[Tuple[ClientState, Request]] = []
        total = 0
        # Lazy max-heap over adjusted remain_token.
        rem = dict(self._remain)
        heap = [(-v, cid) for cid, v in rem.items() if v > 0]
        heapq.heapify(heap)
        for client in idle_clients:
            req = _first_unclaimed(client.backlog, claimed)
            if req is None:
                # steal from argmax remain_token
                while heap:
                    neg, cid = heap[0]
                    if -neg != rem[cid] or rem[cid] <= 0:
                        heapq.heappop(heap)
                        if rem[cid] > 0:
                            heapq.heappush(heap, (-rem[cid], cid))
                        continue
                    cand = _first_unclaimed(self._by_cid[cid].backlog, claimed)
                    if cand is None:
                        heapq.heappop(heap)
                        continue
                    req = cand
                    break
                if req is None:
                    continue
            if batch and total + req.n_prefill > max_tokens:
                continue
            claimed.add(req.rid)
            owner_cid = self._owner_cid(req, hint=client)
            rem[owner_cid] -= req.est_total_tokens
            heapq.heappush(heap, (-rem[owner_cid], owner_cid))
            batch.append((client, req))
            total += req.n_prefill
            if total >= max_tokens:
                break
        return batch

    def _owner_cid(self, request: Request, hint: ClientState) -> int:
        if request in hint.backlog:
            return hint.cid
        for c in self._clients:
            if request in c.backlog:
                return c.cid
        raise ValueError(f"request {request.rid} not found in any backlog")

    def commit(self, client: ClientState, request: Request) -> None:
        owner = self._by_cid[self._owner_cid(request, hint=client)]
        owner.backlog.remove(request)
        self._remain[owner.cid] -= request.est_total_tokens
        self._total_pending -= 1


class GlobalQueueScheduler(RequestScheduler):
    """Single FCFS queue shared by all clients (vLLM-style, for ablations)."""

    def __init__(self, requests: Sequence[Request], sort_longest_first: bool = False):
        self._queue: List[Request] = list(requests)
        if sort_longest_first:
            _sort_backlog(self._queue)

    def has_pending(self) -> bool:
        return bool(self._queue)

    def pending_count(self) -> int:
        return len(self._queue)

    def peek(self, client: ClientState, claimed: Set[int]) -> Optional[Request]:
        return _first_unclaimed(self._queue, claimed)

    def commit(self, client: ClientState, request: Request) -> None:
        self._queue.remove(request)

    def push(self, request: Request) -> None:
        self._queue.append(request)

    def steal_longest(self) -> Optional[Request]:
        if not self._queue:
            return None
        victim = max(self._queue, key=lambda r: r.est_total_tokens)
        self._queue.remove(victim)
        return victim

    def peek_longest(self) -> Optional[Request]:
        if not self._queue:
            return None
        return max(self._queue, key=lambda r: r.est_total_tokens)

    @property
    def queued(self) -> Tuple[Request, ...]:
        return tuple(self._queue)


class ArrivalQueueScheduler(GlobalQueueScheduler):
    """FCFS queue where a request only becomes schedulable once its
    ``arrival`` time has passed (open-loop online traffic, e.g. Poisson
    arrivals — ``benchmarks/mixed_batch.py``).

    The executor publishes its stage clock through ``set_now`` before every
    batch proposal; ``peek`` then surfaces only arrived requests, and
    ``next_arrival`` lets an idle engine fast-forward through an empty gap
    instead of spinning or deadlocking. ``has_pending`` counts *all*
    undelivered requests (including future arrivals) so the serve loop does
    not drain early."""

    def __init__(self, requests: Sequence[Request]):
        super().__init__(sorted(requests, key=lambda r: (r.arrival, r.rid)))
        self.now = 0.0

    def set_now(self, now: float) -> None:
        if now > self.now:
            self.now = now

    def pending_count(self) -> int:
        """Only *arrived* requests count as schedulable pressure — the
        policies price waiter pressure (w in prefill_share, the Lagrangian
        C_d) against work they could actually admit now, and a queue of
        far-future arrivals would inflate it. ``has_pending`` still counts
        everything so the serve loop does not drain early."""
        n = 0
        for r in self._queue:              # arrival-sorted: stop at the
            if r.arrival > self.now:       # first future request instead
                break                      # of scanning the whole queue
            n += 1
        return n

    def peek(self, client: ClientState, claimed: Set[int]) -> Optional[Request]:
        for r in self._queue:
            if r.arrival > self.now:
                break                      # queue is arrival-sorted
            if r.rid not in claimed:
                return r
        return None

    def next_arrival(self) -> Optional[float]:
        for r in self._queue:
            if r.arrival > self.now:
                return r.arrival
        return None

    def push(self, request: Request) -> None:
        """External admission preserving the arrival-sorted invariant peek /
        next_arrival rely on (a plain append would break early-exit scans)."""
        import bisect

        keys = [(r.arrival, r.rid) for r in self._queue]
        self._queue.insert(
            bisect.bisect_right(keys, (request.arrival, request.rid)), request
        )

    def steal_longest(self) -> Optional[Request]:
        """Only *arrived* requests are stealable — a future arrival is not
        work a starving replica could start now."""
        victim = self.peek_longest()
        if victim is not None:
            self._queue.remove(victim)
        return victim

    def peek_longest(self) -> Optional[Request]:
        arrived = [r for r in self._queue if r.arrival <= self.now]
        if not arrived:
            return None
        return max(arrived, key=lambda r: r.est_total_tokens)


def build_clients(
    n_clients: int,
    requests: Sequence[Request],
    assignment: Optional[List[List[int]]] = None,
) -> List[ClientState]:
    """Materialize ClientStates with backlogs from an assignment.

    ``assignment[j]`` is a list of request ids for client j (e.g. from
    ``offline.solve_offline`` or ``offline.round_robin_assign``). With no
    assignment, backlogs stay empty (use GlobalQueueScheduler then).
    """
    by_rid: Dict[int, Request] = {r.rid: r for r in requests}
    clients = [ClientState(cid=j) for j in range(n_clients)]
    if assignment is not None:
        if len(assignment) != n_clients:
            raise ValueError("assignment length != n_clients")
        seen: Set[int] = set()
        for j, rids in enumerate(assignment):
            for rid in rids:
                if rid in seen:
                    raise ValueError(f"request {rid} assigned twice")
                seen.add(rid)
                clients[j].backlog.append(by_rid[rid])
        if len(seen) != len(requests):
            missing = set(by_rid) - seen
            raise ValueError(f"requests not assigned: {sorted(missing)[:5]}...")
    return clients
