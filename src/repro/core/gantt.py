"""Gantt accounting and rendering (the paper's Figs. 6–9).

Renders a ScheduleTrace as (a) an ASCII Gantt (downsampled), (b) a CSV of
stage records, and (c) per-client busy/idle accounting. Terminal-friendly —
no plotting dependencies ship in the container.
"""
from __future__ import annotations

import io
from typing import List, Optional

from .types import ScheduleTrace, StageKind


def stage_csv(trace: ScheduleTrace) -> str:
    """CSV of stage records: kind,t_start,t_end,bin,n_busy,tokens,level."""
    buf = io.StringIO()
    buf.write("kind,t_start,t_end,bin,n_busy,tokens,level\n")
    for s in trace.stages:
        buf.write(
            f"{s.kind.value},{s.t_start:.6f},{s.t_end:.6f},{s.bin_index},"
            f"{len(s.busy) + len(s.busy_partial)},{s.tokens},"
            f"{s.level if s.level is not None else ''}\n"
        )
    return buf.getvalue()


def client_accounting(trace: ScheduleTrace) -> List[dict]:
    """Per-client busy time / utilization over the makespan."""
    busy = [0.0] * trace.num_clients
    for s in trace.stages:
        for cid in (*s.busy, *s.busy_partial):
            busy[cid] += s.duration
    span = trace.makespan or 1.0
    return [
        {"client": cid, "busy_s": round(b, 4), "utilization": round(b / span, 4)}
        for cid, b in enumerate(busy)
    ]


def ascii_gantt(
    trace: ScheduleTrace,
    width: int = 100,
    max_clients: int = 40,
    every_nth_client: Optional[int] = None,
    span: Optional[float] = None,
) -> str:
    """Downsampled ASCII Gantt.

    '#' = decoding, 'P' = in prefill, 'M' = mixed (decode + piggybacked
    prefill chunks), '.' = idle. One row per (sampled) client; columns are
    equal time buckets. A bucket shows the dominant state. ``span`` fixes
    the time axis (fleet rendering aligns every replica to the fleet
    makespan); default is the trace's own makespan.
    """
    if not trace.stages:
        return "(empty trace)"
    span = span or trace.makespan
    n = trace.num_clients
    step = every_nth_client or max(1, n // max_clients)
    rows = list(range(0, n, step))
    # occupancy[cid][col] in {0 idle, 1 prefill, 2 decode, 3 mixed}
    occ = {cid: [[0.0, 0.0, 0.0, 0.0] for _ in range(width)] for cid in rows}
    for s in trace.stages:
        c0 = int(s.t_start / span * width)
        c1 = max(c0 + 1, int(s.t_end / span * width + 0.999999))
        if s.kind is StageKind.PREFILL:
            kind = 1
        elif s.kind is StageKind.MIXED:
            kind = 3
        else:
            kind = 2
        for cid in rows:
            state = kind if (cid in s.busy or cid in s.busy_partial) else 0
            for col in range(c0, min(c1, width)):
                # apportion stage duration to bucket overlap (approximate)
                occ[cid][col][state] += s.duration / (c1 - c0)
    chars = {0: ".", 1: "P", 2: "#", 3: "M"}
    out = io.StringIO()
    slo_tag = ""
    if trace.slo_tracked_requests:
        slo_tag = (
            f" goodput={trace.goodput:.1f} tok/s "
            f"slo={trace.slo_attainment * 100:.0f}%"
        )
    cache_tag = ""
    if trace.cached_prefill_tokens:
        cache_tag = (
            f" prefill={trace.computed_prefill_tokens}tok computed"
            f"+{trace.cached_prefill_tokens}tok cached"
        )
    out.write(
        f"Gantt [{trace.policy_name}] makespan={trace.makespan:.2f}s "
        f"util={trace.utilization * 100:.2f}% "
        f"busy-window util={trace.busy_window_utilization * 100:.2f}% "
        f"speed={trace.generation_speed:.1f} tok/s{slo_tag}{cache_tag}\n"
    )
    for cid in rows:
        line = "".join(
            chars[max(range(4), key=lambda k: occ[cid][col][k])] for col in range(width)
        )
        out.write(f"c{cid:>4} |{line}|\n")
    out.write(
        f"       {'':<1}('#'=decode  'P'=prefill  'M'=mixed  '.'=idle; "
        f"{step} clients/row)\n"
    )
    return out.getvalue()


def fleet_ascii_gantt(
    report,
    width: int = 100,
    max_clients_per_replica: int = 8,
) -> str:
    """Per-replica Gantt rows on ONE shared time axis (the fleet makespan),
    so replica load imbalance is visible as trailing idle columns. Takes a
    ``FleetReport``."""
    span = report.makespan
    if span <= 0:
        return "(empty fleet trace)"
    speeds = report._replica_speeds()
    hetero = any(s != 1.0 for s in speeds)
    out = io.StringIO()
    slo_tag = ""
    if any(t.slo_tracked_requests for t in report.traces):
        slo_tag = (
            f" goodput={report.goodput:.1f} tok/s "
            f"slo={report.slo_attainment * 100:.0f}%"
        )
    fault_tag = ""
    if report.meta.get("dead_replicas"):
        fault_tag = (
            f" dead={int(report.meta['dead_replicas'])} "
            f"recovered={int(report.meta.get('recovered_requests', 0))}"
        )
        if report.meta.get("drained_replicas"):
            fault_tag += f" drained={int(report.meta['drained_replicas'])}"
    if report.meta.get("migration_events"):
        fault_tag += (
            f" migrations={int(report.meta['migration_events'])}"
            f"({int(report.meta.get('migrated_pages', 0))}pg)"
        )
    cached_total = sum(t.cached_prefill_tokens for t in report.traces)
    if cached_total:
        fault_tag += f" cached_prefill={cached_total}tok"
    out.write(
        f"Fleet Gantt [{report.policy_name}] replicas={report.n_replicas} "
        f"makespan={span:.2f}s util={report.utilization * 100:.2f}%"
        f"{' (speed-weighted)' if hetero else ''} "
        f"lb_ratio={report.lb_ratio:.2f} steals={report.steal_events}"
        f"{slo_tag}{fault_tag}\n"
    )
    for i, trace in enumerate(report.traces):
        # a slow replica's rows render visibly denser per request: the same
        # token count stretches over more of the shared fleet time axis
        speed_tag = f" speed=x{speeds[i]:g}" if hetero else ""
        out.write(
            f"-- replica {i}{speed_tag}: makespan={trace.makespan:.2f}s "
            f"util={trace.utilization * 100:.2f}% "
            f"requests={len(trace.requests)}\n"
        )
        out.write(
            ascii_gantt(
                trace, width=width, max_clients=max_clients_per_replica,
                span=span,
            )
        )
    return out.getvalue()


def utilization_timeline(trace: ScheduleTrace, buckets: int = 50) -> List[float]:
    """Utilization per time bucket (for Fig.-style summaries).

    Each stage's busy client-time is apportioned to buckets by overlap and
    then scaled so the bucket shares sum to exactly ``duration × n_busy`` —
    a stage ending on (or within float epsilon of) a bucket edge cannot
    leak a sliver of busy time into the next bucket, and bucket totals
    always reconcile with the trace's total busy time.
    """
    if not trace.stages:
        return []
    span = trace.makespan
    busy = [0.0] * buckets
    for s in trace.stages:
        n_busy = len(s.busy) + len(s.busy_partial)
        if n_busy == 0 or s.duration <= 0:
            continue
        b0 = s.t_start / span * buckets
        b1 = s.t_end / span * buckets
        i = min(int(b0), buckets - 1)
        parts = []                       # (bucket, overlap in bucket units)
        while i < buckets:
            lo, hi = max(b0, i), min(b1, i + 1)
            if hi - lo > 1e-12:          # skip float-epsilon edge slivers
                parts.append((i, hi - lo))
            if b1 <= i + 1:
                break
            i += 1
        total = sum(w_i for _, w_i in parts)
        if total <= 0:
            continue
        for i, w_i in parts:
            busy[i] += s.duration * n_busy * (w_i / total)
    denom = span / buckets * trace.num_clients
    return [round(b / denom, 4) for b in busy]
