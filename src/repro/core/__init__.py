# The paper's primary contribution: hybrid offline-online scheduling for
# LLM inference under PD Competition (MIP + binpack + Algorithm 1 +
# Lagrangian iteration rule), shared by the simulator and the real engine.
from .types import (
    Request,
    ClientState,
    StageKind,
    StageRecord,
    ScheduleTrace,
    FleetReport,
    Phase,
    make_requests,
)
from .cost_model import CostModel, PrefillLevel, PAPER_COST_MODEL
from .offline import (
    OfflineResult,
    LowerBound,
    solve_offline,
    lpt_assign,
    local_search,
    milp_assign,
    round_robin_assign,
    evaluate_assignment,
    split_requests,
    request_weights,
    theoretical_lower_bound,
)
from .hetero import (
    ReplicaSpec,
    replica_request_weight,
    hetero_weights,
    hetero_lpt_assign,
    hetero_local_search,
    hetero_lp_lower_bound,
    hetero_theoretical_lower_bound,
    solve_hetero,
    evaluate_hetero_assignment,
)
from .online import (
    RequestScheduler,
    StaticBacklogScheduler,
    SortingPreemptiveScheduler,
    GlobalQueueScheduler,
    ArrivalQueueScheduler,
    build_clients,
)
from .iteration import (
    IterationPolicy,
    PrefillFirstPolicy,
    DecodeFirstPolicy,
    LagrangianPolicy,
    BalancedLagrangianPolicy,
    AmortizedPolicy,
    UtilizationWeightedPolicy,
    DynamicBatchPolicy,
    TimedPolicy,
    SystemSnapshot,
    CandidateBatch,
    POLICIES,
    make_policy,
)
from .simulator import Simulator, SimConfig, simulate
from .mip import OriginalMIP, MIPSolution, toy_instance, recost_trace_mip_semantics
