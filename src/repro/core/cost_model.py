"""Linear prefill/decode cost model and prefill level table.

The paper measures (over 400 data groups, LLaMA-65B on an 8-device node):

    prefill_time(total_tokens)   = 25 ms + 0.13 ms * total_tokens
    decode_round_time(n_clients) = 29 ms + 0.21 ms * n_clients

and quantizes prefill stages into *levels* l ∈ L with token capacity N_l^cap
and duration T_l^p. Levels serve two purposes here:

  1. faithfulness to the paper's MIP (y_{k,l} indicator per stage), and
  2. in the real JAX engine, each level is one padded compilation shape, so
     the level table doubles as the jit bucketing table.

``CostModel.fit`` reproduces the paper's calibration: a least-squares linear
fit of measured stage times vs token counts, used by the engine's online
profiler to adapt the model to whatever hardware it actually runs on.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class PrefillLevel:
    """One prefill level: capacity in tokens and stage duration in seconds."""

    index: int
    cap_tokens: int
    duration_s: float


@dataclass
class CostModel:
    """Linear PD-competition cost model (all times in seconds).

    Defaults are the paper's Table III / §V-A measurements.
    """

    prefill_per_token: float = 0.13e-3
    prefill_overhead: float = 25e-3
    decode_per_token: float = 0.21e-3
    decode_overhead: float = 29e-3
    # Fixed host-side cost of *dispatching* one decode call (python loop,
    # jit-call overhead, host↔device sync) — the part a fused K-iteration
    # decode pays once instead of K times. The paper's per-round constants
    # fold it into decode_overhead; it only becomes separately identifiable
    # once the profiler sees fused stages of differing horizons (the
    # 3-parameter fit below). The default is a typical single-process
    # dispatch+sync cost, refined online.
    decode_dispatch: float = 2e-3
    # Mixed-batch (continuous-batching) timing model: one mixed round that
    # decodes n_d rows while co-processing n_p prefill-chunk tokens in the
    # same dispatch costs
    #
    #     t(n_d, n_p) = mixed_overhead
    #                   + mixed_decode_per_row · n_d
    #                   + mixed_prefill_per_token · n_p
    #
    # (separable: round overhead + per-decode-row + per-prefill-token). The
    # ``None`` defaults derive the mixed constants from the stage-level
    # model — a mixed round is a decode round whose duration inflates
    # linearly with the piggybacked prefill tokens — until the profiler's
    # fit (``mixed_samples`` below) replaces them with measured values.
    mixed_overhead: Optional[float] = None
    mixed_decode_per_row: Optional[float] = None
    mixed_prefill_per_token: Optional[float] = None
    level_caps: Tuple[int, ...] = (512, 1024, 2048, 3072, 4096, 5000)

    def __post_init__(self) -> None:
        if any(c <= 0 for c in self.level_caps):
            raise ValueError("level capacities must be positive")
        if list(self.level_caps) != sorted(set(self.level_caps)):
            raise ValueError("level capacities must be strictly increasing")

    # ------------------------------------------------------------------ #
    # Heterogeneous replicas                                             #
    # ------------------------------------------------------------------ #
    def scaled(self, speed_factor: float) -> "CostModel":
        """This model on a machine running ``speed_factor`` × as fast: every
        duration constant divides by the factor (2.0 → half the time per
        stage, 0.5 → twice). Level capacities are token counts, not times,
        and stay put. Used to seed per-replica cost-model priors for a
        mixed-generation fleet (``core.hetero.ReplicaSpec``)."""
        if speed_factor <= 0:
            raise ValueError("speed_factor must be positive")
        s = float(speed_factor)

        def scale(x: Optional[float]) -> Optional[float]:
            return None if x is None else x / s

        return CostModel(
            prefill_per_token=self.prefill_per_token / s,
            prefill_overhead=self.prefill_overhead / s,
            decode_per_token=self.decode_per_token / s,
            decode_overhead=self.decode_overhead / s,
            decode_dispatch=self.decode_dispatch / s,
            mixed_overhead=scale(self.mixed_overhead),
            mixed_decode_per_row=scale(self.mixed_decode_per_row),
            mixed_prefill_per_token=scale(self.mixed_prefill_per_token),
            level_caps=self.level_caps,
        )

    # ------------------------------------------------------------------ #
    # Raw linear model                                                   #
    # ------------------------------------------------------------------ #
    def prefill_time(self, total_tokens: int) -> float:
        """Un-quantized prefill stage duration for a packed token batch."""
        if total_tokens <= 0:
            return 0.0
        return self.prefill_overhead + self.prefill_per_token * total_tokens

    def decode_round_time(self, n_active_clients: int) -> float:
        """One decode round: every active client emits one token."""
        if n_active_clients <= 0:
            return 0.0
        return self.decode_overhead + self.decode_per_token * n_active_clients

    def fused_decode_time(self, n_active_clients: int, rounds: int) -> float:
        """One fused decode *stage* of ``rounds`` iterations: the dispatch
        cost is paid once, the per-round compute ``rounds`` times."""
        if n_active_clients <= 0 or rounds <= 0:
            return 0.0
        return self.decode_dispatch + rounds * self.decode_round_time(
            n_active_clients
        )

    # ------------------------------------------------------------------ #
    # Mixed-batch model (continuous batching: prefill inside decode)     #
    # ------------------------------------------------------------------ #
    @property
    def mixed_overhead_time(self) -> float:
        return (
            self.mixed_overhead
            if self.mixed_overhead is not None else self.decode_overhead
        )

    @property
    def mixed_decode_row_time(self) -> float:
        return (
            self.mixed_decode_per_row
            if self.mixed_decode_per_row is not None else self.decode_per_token
        )

    @property
    def mixed_prefill_token_time(self) -> float:
        """Decode-latency inflation per co-scheduled prefill token — the
        marginal price the ``prefill_share`` policies trade against."""
        return (
            self.mixed_prefill_per_token
            if self.mixed_prefill_per_token is not None
            else self.prefill_per_token
        )

    def mixed_round_time(self, n_decode: int, n_prefill_tokens: int) -> float:
        """One mixed round: ``n_decode`` decode rows plus ``n_prefill_tokens``
        prefill-chunk tokens in a single dispatch."""
        if n_decode <= 0 and n_prefill_tokens <= 0:
            return 0.0
        return (
            self.mixed_overhead_time
            + self.mixed_decode_row_time * max(n_decode, 0)
            + self.mixed_prefill_token_time * max(n_prefill_tokens, 0)
        )

    # ------------------------------------------------------------------ #
    # Levels (y_{k,l} in the MIP; jit buckets in the engine)             #
    # ------------------------------------------------------------------ #
    @property
    def levels(self) -> List[PrefillLevel]:
        return [
            PrefillLevel(index=l, cap_tokens=cap, duration_s=self.prefill_time(cap))
            for l, cap in enumerate(self.level_caps)
        ]

    @property
    def max_level(self) -> PrefillLevel:
        """Level L = argmax_l N_l^cap (used by the lower bound, Eq. 31)."""
        return self.levels[-1]

    def level_for(self, total_tokens: int) -> PrefillLevel:
        """Smallest level whose capacity fits ``total_tokens``.

        Raises if the batch exceeds the largest capacity — callers must split
        batches to the max level first (the simulator/engine do).
        """
        for lv in self.levels:
            if total_tokens <= lv.cap_tokens:
                return lv
        raise ValueError(
            f"prefill batch of {total_tokens} tokens exceeds max level "
            f"capacity {self.max_level.cap_tokens}"
        )

    def quantized_prefill_time(self, total_tokens: int) -> float:
        """T_l^p of the level the batch lands in (Eq. 5)."""
        return self.level_for(total_tokens).duration_s

    # ------------------------------------------------------------------ #
    # Aggregates used by schedulers                                      #
    # ------------------------------------------------------------------ #
    def decode_time_per_token_amortized(self, n_clients: int) -> float:
        """System-time to decode one token when n_clients run in parallel."""
        if n_clients <= 0:
            raise ValueError("n_clients must be positive")
        return self.decode_round_time(n_clients) / n_clients

    def estimated_decode_completion(self, n_decode: int, n_clients: int) -> float:
        """T_i of the offline model (Eq. 28): a client's *wall-clock* decode
        time for a request. Clients decode in lockstep rounds (one token per
        round), so a request of N_i^d tokens occupies its client for N_i^d
        rounds, each of the full-batch round duration."""
        return n_decode * self.decode_round_time(n_clients)

    # ------------------------------------------------------------------ #
    # Calibration (the paper's 400-group linear fit; engine profiler)    #
    # ------------------------------------------------------------------ #
    @staticmethod
    def fit_mixed_params(
        mixed_samples: Sequence[Tuple[int, int, float]],
    ) -> Optional[Tuple[float, float, float]]:
        """Separable mixed-batch fit → (overhead, per_decode_row,
        per_prefill_token), or None when the samples cannot identify the
        model (fewer than 3, or no variation in one of the regressors —
        lstsq on a collinear column returns a silently wrong minimum-norm
        solution)."""
        if len(mixed_samples) < 3:
            return None
        nd = np.asarray([s[0] for s in mixed_samples], dtype=np.float64)
        npf = np.asarray([s[1] for s in mixed_samples], dtype=np.float64)
        ym = np.asarray([s[2] for s in mixed_samples], dtype=np.float64)
        if len(set(nd.tolist())) < 2 or len(set(npf.tolist())) < 2:
            return None
        a = np.vstack([np.ones_like(nd), nd, npf]).T
        (oh, row, tok), *_ = np.linalg.lstsq(a, ym, rcond=None)
        return float(max(oh, 0.0)), float(max(row, 0.0)), float(max(tok, 0.0))

    @staticmethod
    def fit(
        prefill_samples: Sequence[Tuple[int, float]],
        decode_samples: Sequence[Tuple],
        level_caps: Sequence[int] = (512, 1024, 2048, 3072, 4096, 5000),
        decode_dispatch: float = 2e-3,
        mixed_samples: Sequence[Tuple[int, int, float]] = (),
    ) -> "CostModel":
        """Least-squares fit of measured stage samples → CostModel.

        ``prefill_samples``: (total_tokens, stage_seconds) pairs.
        ``decode_samples``: (n_active_clients, stage_seconds) pairs (one
        round) or (n_active_clients, rounds, stage_seconds) triples (fused
        stages). With ≥ 2 distinct horizons the fit is the 3-parameter model

            T(n, K) = dispatch + K · (overhead + per_token · n)

        which separates the per-dispatch host cost from per-round compute —
        the quantity the horizon-pricing policy needs. With a single horizon
        the dispatch column is collinear with the overhead column, so the fit
        degrades to the paper's 2-parameter per-round model and keeps
        ``decode_dispatch`` at the caller-provided prior.

        ``mixed_samples``: (n_decode_rows, n_prefill_tokens, seconds) triples
        from mixed-step stages. With enough variation in *both* regressors
        (≥ 3 samples, ≥ 2 distinct values each) the separable model
        ``t(n_d, n_p) = overhead + per_row·n_d + per_token·n_p`` is fit and
        the share-pricing policy adapts online; otherwise the mixed constants
        stay derived from the stage-level model.
        """

        def linfit(samples: Sequence[Tuple[int, float]]) -> Tuple[float, float]:
            if len(samples) < 2:
                raise ValueError("need >= 2 samples for a linear fit")
            x = np.asarray([s[0] for s in samples], dtype=np.float64)
            y = np.asarray([s[1] for s in samples], dtype=np.float64)
            a = np.vstack([x, np.ones_like(x)]).T
            (slope, intercept), *_ = np.linalg.lstsq(a, y, rcond=None)
            return float(slope), float(max(intercept, 0.0))

        p_slope, p_int = linfit(prefill_samples)

        tri = [(s[0], 1, s[1]) if len(s) == 2 else tuple(s) for s in decode_samples]
        if len(tri) < 2:
            raise ValueError("need >= 2 samples for a linear fit")
        n = np.asarray([s[0] for s in tri], dtype=np.float64)
        k = np.asarray([s[1] for s in tri], dtype=np.float64)
        y = np.asarray([s[2] for s in tri], dtype=np.float64)
        # the 3-parameter model needs ≥ 3 samples AND ≥ 2 distinct horizons
        # to be determined; lstsq on fewer returns a silently wrong
        # minimum-norm solution
        if len(tri) >= 3 and len(set(k.tolist())) >= 2:
            a = np.vstack([np.ones_like(k), k, k * n]).T
            (disp, d_int, d_slope), *_ = np.linalg.lstsq(a, y, rcond=None)
            decode_dispatch = float(max(disp, 0.0))
            d_int, d_slope = float(max(d_int, 0.0)), float(d_slope)
        else:
            # normalize to per-round times and fit the 2-parameter model
            d_slope, d_int = linfit(list(zip(n.tolist(), (y / k).tolist())))

        m_oh = m_row = m_tok = None
        mixed_fit = CostModel.fit_mixed_params(mixed_samples)
        if mixed_fit is not None:
            m_oh, m_row, m_tok = mixed_fit
        return CostModel(
            prefill_per_token=p_slope,
            prefill_overhead=p_int,
            decode_per_token=d_slope,
            decode_overhead=d_int,
            decode_dispatch=decode_dispatch,
            mixed_overhead=m_oh,
            mixed_decode_per_row=m_row,
            mixed_prefill_per_token=m_tok,
            level_caps=tuple(level_caps),
        )


# Paper Table III constants, importable by name.
PAPER_COST_MODEL = CostModel()
