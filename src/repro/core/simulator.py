"""Event-driven PD-Competition simulator.

Reproduces the paper's experiment semantics (§III-A, Fig. 2):

  * The node runs exactly one stage at a time — a prefill stage or a decode
    round — alternating under the iteration policy's control.
  * A prefill stage admits ≤ 1 new request per idle client (Eq. 16), total
    input tokens ≤ the largest level capacity (Eq. 6); its duration is the
    measured linear model on the *actual* token count (the levels quantize
    the decision model, not the physics — see DESIGN.md §2).
  * A decode round gives every active client one token; duration
    T^d_oh + T^d · n_active.
  * A request's decode may be preempted by prefill stages (continuous
    batching); a client processes one request at a time until completion.

The simulator consumes the same policy objects as the real engine
(``repro.serving.engine``), so scheduler behaviour validated here transfers.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

from .cost_model import CostModel
from .iteration import (
    CandidateBatch,
    IterationPolicy,
    LagrangianPolicy,
    PrefillFirstPolicy,
    SystemSnapshot,
)
from .offline import round_robin_assign, solve_offline
from .online import (
    GlobalQueueScheduler,
    RequestScheduler,
    SortingPreemptiveScheduler,
    StaticBacklogScheduler,
    build_clients,
)
from .types import (
    ClientState,
    Phase,
    Request,
    ScheduleTrace,
    StageKind,
    StageRecord,
)


@dataclass
class SimConfig:
    n_clients: int
    cost_model: CostModel
    max_stages: int = 2_000_000     # runaway guard
    record_decisions: bool = True


class Simulator:
    """Simulates one serve run of a request set under a scheduling config."""

    def __init__(
        self,
        requests: Sequence[Request],
        config: SimConfig,
        request_scheduler: RequestScheduler,
        iteration_policy: IterationPolicy,
        clients: Optional[List[ClientState]] = None,
        policy_name: str = "",
    ):
        self.requests = list(requests)
        self.cfg = config
        self.sched = request_scheduler
        self.policy = iteration_policy
        self.clients = clients or [ClientState(cid=j) for j in range(config.n_clients)]
        self.policy_name = policy_name or iteration_policy.name

    # ------------------------------------------------------------------ #
    def run(self) -> ScheduleTrace:
        cm = self.cfg.cost_model
        trace = ScheduleTrace(
            num_clients=self.cfg.n_clients,
            requests=self.requests,
            policy_name=self.policy_name,
        )
        for r in self.requests:
            r.reset()
        t = 0.0
        bin_index = -1  # incremented on first prefill stage

        for _ in range(self.cfg.max_stages):
            active = [c for c in self.clients if c.current is not None]
            idle = [c for c in self.clients if c.current is None]
            done = not active and not self.sched.has_pending()
            if done:
                break

            candidate_pairs = self.sched.propose_batch(
                idle, cm.max_level.cap_tokens
            )
            candidate = CandidateBatch(
                requests=[r for _, r in candidate_pairs],
                client_ids=[c.cid for c, _ in candidate_pairs],
            )
            snap = SystemSnapshot(
                n_clients=self.cfg.n_clients,
                n_active=len(active),
                n_idle=len(idle),
                active_remaining_est=sum(
                    max(0, (c.current.n_decode_est or 0) - c.current.decoded)
                    for c in active
                    if c.current is not None
                ),
                pending_requests=self.sched.pending_count(),
                candidate=candidate,
                now=t,
            )
            t0 = time.perf_counter()
            do_prefill = self.policy(snap, cm)
            if self.cfg.record_decisions:
                trace.decision_times_ms.append((time.perf_counter() - t0) * 1e3)

            if do_prefill and candidate:
                bin_index += 1
                t = self._run_prefill(trace, t, bin_index, candidate_pairs, cm)
            elif active:
                t = self._run_decode_round(trace, t, max(bin_index, 0), active, cm)
            else:
                # No decodes and the policy refused a non-empty candidate —
                # force progress (progress guard also lives in the policy).
                if candidate:
                    bin_index += 1
                    t = self._run_prefill(trace, t, bin_index, candidate_pairs, cm)
                else:
                    raise RuntimeError(
                        "scheduler deadlock: pending requests but no candidate"
                    )
        else:
            raise RuntimeError("max_stages exceeded — scheduler not terminating")

        trace.validate()
        return trace

    # ------------------------------------------------------------------ #
    def _run_prefill(self, trace, t, bin_index, pairs, cm: CostModel) -> float:
        total_tokens = sum(r.n_prefill for _, r in pairs)
        duration = cm.prefill_time(total_tokens)
        level = cm.level_for(min(total_tokens, cm.max_level.cap_tokens)).index
        self.sched.commit_batch(pairs)
        busy = {}
        for client, req in pairs:
            req.client = client.cid
            req.prefill_bin = bin_index
            req.t_prefill_start = t
            req.t_prefill_end = t + duration
            client.current = req
            client.busy_time += duration
            busy[client.cid] = req.rid
        trace.stages.append(
            StageRecord(
                kind=StageKind.PREFILL,
                t_start=t,
                t_end=t + duration,
                bin_index=bin_index,
                busy=busy,
                tokens=total_tokens,
                level=level,
            )
        )
        return t + duration

    def _run_decode_round(self, trace, t, bin_index, active, cm: CostModel) -> float:
        duration = cm.decode_round_time(len(active))
        busy = {}
        for client in active:
            req = client.current
            req.decoded += 1
            client.busy_time += duration
            busy[client.cid] = req.rid
            if req.decoded >= req.n_decode:
                req.t_done = t + duration
                client.current = None
        trace.stages.append(
            StageRecord(
                kind=StageKind.DECODE,
                t_start=t,
                t_end=t + duration,
                bin_index=bin_index,
                busy=busy,
                tokens=len(active),
                rounds=1,
            )
        )
        return t + duration


# --------------------------------------------------------------------------- #
# The four paper configurations (Figs. 6–9) + beyond-paper variants           #
# --------------------------------------------------------------------------- #
def simulate(
    requests: Sequence[Request],
    n_clients: int,
    cost_model: CostModel,
    mode: str = "baseline",
    offline_exact: bool = False,
    iteration_policy: Optional[IterationPolicy] = None,
    oracle_estimates: bool = False,
) -> ScheduleTrace:
    """Run one of the named configurations.

    mode:
      * ``baseline``      — global FCFS queue, prefill-first: vLLM's default
                            scheduler, the paper's baseline (Fig. 6).
      * ``offline``       — bin-packed backlogs, no stealing, prefill-first
                            (Fig. 7).
      * ``online``        — FCFS round-robin backlogs + Algorithm 1 stealing
                            + Lagrangian iteration rule (Fig. 8).
      * ``hybrid``        — bin-packed backlogs + Algorithm 1 + Lagrangian
                            (Fig. 9).
      * ``static_rr``     — static round-robin backlogs, no stealing
                            (ablation: pre-assigned unbalanced clients).
    ``iteration_policy`` overrides the mode's default iteration rule (used by
    the beyond-paper studies). ``oracle_estimates=True`` gives the planner
    true decode lengths (the paper's offline/RLHF scenario, where outputs are
    measured or well-predicted); default keeps whatever estimates the
    workload carries. Requests are copied, so repeated calls are independent.
    """
    requests = [
        Request(
            rid=r.rid,
            n_prefill=r.n_prefill,
            n_decode=r.n_decode,
            n_decode_est=(r.n_decode if oracle_estimates else r.n_decode_est),
            arrival=r.arrival,
        )
        for r in requests
    ]
    cfg = SimConfig(n_clients=n_clients, cost_model=cost_model)

    if mode == "baseline":
        clients = [ClientState(cid=j) for j in range(n_clients)]
        sched: RequestScheduler = GlobalQueueScheduler(requests)
        policy = iteration_policy or PrefillFirstPolicy()
    elif mode == "static_rr":
        assignment = round_robin_assign(requests, n_clients)
        clients = build_clients(n_clients, requests, assignment)
        sched = StaticBacklogScheduler(clients)
        policy = iteration_policy or PrefillFirstPolicy()
    elif mode == "offline":
        result = solve_offline(requests, n_clients, cost_model, exact=offline_exact)
        clients = build_clients(n_clients, requests, result.assignment)
        sched = StaticBacklogScheduler(clients)
        policy = iteration_policy or PrefillFirstPolicy()
    elif mode == "online":
        assignment = round_robin_assign(requests, n_clients)
        clients = build_clients(n_clients, requests, assignment)
        sched = SortingPreemptiveScheduler(clients)
        policy = iteration_policy or LagrangianPolicy()
    elif mode == "hybrid":
        result = solve_offline(requests, n_clients, cost_model, exact=offline_exact)
        clients = build_clients(n_clients, requests, result.assignment)
        sched = SortingPreemptiveScheduler(clients)
        policy = iteration_policy or LagrangianPolicy()
    elif mode == "global_fcfs":
        clients = [ClientState(cid=j) for j in range(n_clients)]
        sched = GlobalQueueScheduler(requests)
        policy = iteration_policy or PrefillFirstPolicy()
    else:
        raise ValueError(f"unknown mode {mode!r}")

    sim = Simulator(
        requests,
        cfg,
        sched,
        policy,
        clients=clients,
        policy_name=f"{mode}/{policy.name}",
    )
    return sim.run()
