"""Offline requests scheduling — Minimizing Makespan Bin Packing (Eqs. 26–30)
and the theoretical lower bound (Eqs. 31–32).

The offline model balances the estimated decode completion time T_i of the
given requests across J clients:

    min  max_j t_j
    s.t. Σ_j x_ij = 1            ∀ i
         Σ_i x_ij T_i ≤ t_j      ∀ j

This is the classic P||Cmax (multiprocessor scheduling). We provide:

  * ``lpt_assign``       — Longest-Processing-Time-first, 4/3-approximate, O(I log I).
  * ``local_search``     — move/swap refinement of any assignment.
  * ``milp_assign``      — exact (scipy HiGHS) with LPT warm-bound; the
                           paper-scale instance (1319 × 200) solves via LPT +
                           local search in milliseconds and is provably near
                           the LP bound; exact MILP is for small instances.
  * ``solve_offline``    — the composition used by the framework.
  * ``theoretical_lower_bound`` — T_LB = t^p* + t^d*  (Eqs. 31–32).
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cost_model import CostModel
from .types import Request


@dataclass
class OfflineResult:
    """Assignment x_{ij} (as request-order lists per client) + diagnostics."""

    assignment: List[List[int]]          # client -> list of request ids
    loads: List[float]                   # t_j per client (estimated)
    makespan_est: float                  # max_j t_j
    lp_lower_bound: float                # max(mean load, max item)
    solver: str
    solve_seconds: float

    @property
    def gap(self) -> float:
        """Relative gap between achieved makespan and the LP lower bound.

        A degenerate instance can carry a zero lower bound (e.g. an empty
        request list per client); reporting 0.0 there would read as a
        *perfect* solution even when the achieved makespan is positive, so
        a positive makespan over a zero bound is an infinite gap, and only
        zero-over-zero is a true 0.0.
        """
        if self.lp_lower_bound <= 0:
            return 0.0 if self.makespan_est <= 0 else float("inf")
        return (self.makespan_est - self.lp_lower_bound) / self.lp_lower_bound


def request_weights(
    requests: Sequence[Request],
    cost_model: CostModel,
    n_clients: int,
    include_prefill: bool = False,
    cache_aware: bool = True,
) -> np.ndarray:
    """T_i: estimated decode completion time per request (offline model §IV-B).

    Offline planning uses the *estimated* decode length (n_decode_est); true
    lengths stay unknown until execution, as in the paper. (The
    heterogeneous solver prices a different, prefill-inclusive quantity —
    see ``core.hetero.replica_request_weight``.)

    ``include_prefill`` adds each request's prefill service time to its
    weight — required when prompt lengths (and therefore prefill cost)
    vary enough to dominate the packing. With ``cache_aware`` (the
    default) the prefill term prices the request's *uncached* prompt
    length (``Request.cached_prefill`` as probed against the warm fleet
    state): a cache hit makes a nominally huge prompt nearly free, and a
    packer that prices the nominal length balances work that will never
    run. ``cache_aware=False`` is the hard-gated cache-blind ablation."""
    out = []
    for r in requests:
        w = cost_model.estimated_decode_completion(
            r.n_decode_est or r.n_decode, n_clients
        )
        if include_prefill:
            p = r.uncached_prefill if cache_aware else r.n_prefill
            w += cost_model.prefill_time(p)
        out.append(w)
    return np.asarray(out, dtype=np.float64)


# internal alias kept for the pre-heterogeneous call sites below
_weights = request_weights


# --------------------------------------------------------------------------- #
# Heuristics                                                                  #
# --------------------------------------------------------------------------- #
def lpt_assign(weights: np.ndarray, n_clients: int) -> List[List[int]]:
    """Longest Processing Time first onto the least-loaded client (min-heap)."""
    order = np.argsort(-weights, kind="stable")
    heap: List[Tuple[float, int]] = [(0.0, j) for j in range(n_clients)]
    heapq.heapify(heap)
    assignment: List[List[int]] = [[] for _ in range(n_clients)]
    for i in order:
        load, j = heapq.heappop(heap)
        assignment[j].append(int(i))
        heapq.heappush(heap, (load + float(weights[i]), j))
    return assignment


def _loads(assignment: List[List[int]], weights: np.ndarray) -> np.ndarray:
    return np.asarray(
        [sum(float(weights[i]) for i in client) for client in assignment],
        dtype=np.float64,
    )


def local_search(
    assignment: List[List[int]],
    weights: np.ndarray,
    max_rounds: int = 50,
) -> List[List[int]]:
    """Move/swap local search on the makespan.

    Repeatedly takes the max-loaded client and tries (a) moving one of its
    items to the min-loaded client, (b) swapping an item pair with the
    min-loaded client, accepting strict makespan-or-tie-breaking improvements.
    """
    assignment = [list(c) for c in assignment]
    loads = _loads(assignment, weights)
    for _ in range(max_rounds):
        j_max = int(np.argmax(loads))
        j_min = int(np.argmin(loads))
        if j_max == j_min:
            break
        improved = False
        # (a) single-item move
        best_delta = 0.0
        best_item = None
        for i in assignment[j_max]:
            w = float(weights[i])
            new_max = max(loads[j_max] - w, loads[j_min] + w)
            delta = loads[j_max] - new_max
            if delta > best_delta + 1e-12:
                best_delta, best_item = delta, i
        if best_item is not None:
            assignment[j_max].remove(best_item)
            assignment[j_min].append(best_item)
            loads[j_max] -= weights[best_item]
            loads[j_min] += weights[best_item]
            improved = True
        else:
            # (b) pairwise swap
            best = None
            for a in assignment[j_max]:
                for b in assignment[j_min]:
                    wa, wb = float(weights[a]), float(weights[b])
                    if wa <= wb:
                        continue
                    new_max = max(loads[j_max] - wa + wb, loads[j_min] + wa - wb)
                    delta = loads[j_max] - new_max
                    if best is None or delta > best[0] + 1e-12:
                        if delta > 1e-12:
                            best = (delta, a, b)
            if best is not None:
                _, a, b = best
                assignment[j_max].remove(a)
                assignment[j_min].remove(b)
                assignment[j_max].append(b)
                assignment[j_min].append(a)
                loads[j_max] += weights[b] - weights[a]
                loads[j_min] += weights[a] - weights[b]
                improved = True
        if not improved:
            break
    return assignment


# --------------------------------------------------------------------------- #
# Exact MILP (scipy HiGHS) — the paper solves this model with SCIP            #
# --------------------------------------------------------------------------- #
def milp_assign(
    weights: np.ndarray,
    n_clients: int,
    time_limit_s: float = 60.0,
    warm_makespan: Optional[float] = None,
) -> Optional[List[List[int]]]:
    """Exact P||Cmax via MILP (Eqs. 26–30). Returns None if solver fails.

    Variables: x_{ij} ∈ {0,1} (I*J), t_max ∈ R+.
    min t_max  s.t.  Σ_j x_ij = 1;  Σ_i w_i x_ij - t_max ≤ 0.
    """
    from scipy.optimize import LinearConstraint, Bounds, milp
    import scipy.sparse as sp

    n_i = len(weights)
    n_x = n_i * n_clients
    n_var = n_x + 1  # + t_max

    c = np.zeros(n_var)
    c[-1] = 1.0

    # Σ_j x_ij = 1  for each i
    rows, cols, vals = [], [], []
    for i in range(n_i):
        for j in range(n_clients):
            rows.append(i)
            cols.append(i * n_clients + j)
            vals.append(1.0)
    a_eq = sp.csr_matrix((vals, (rows, cols)), shape=(n_i, n_var))
    eq = LinearConstraint(a_eq, lb=np.ones(n_i), ub=np.ones(n_i))

    # Σ_i w_i x_ij - t_max ≤ 0  for each j
    rows, cols, vals = [], [], []
    for j in range(n_clients):
        for i in range(n_i):
            rows.append(j)
            cols.append(i * n_clients + j)
            vals.append(float(weights[i]))
        rows.append(j)
        cols.append(n_x)
        vals.append(-1.0)
    a_ub = sp.csr_matrix((vals, (rows, cols)), shape=(n_clients, n_var))
    ub = LinearConstraint(a_ub, lb=-np.inf * np.ones(n_clients), ub=np.zeros(n_clients))

    integrality = np.ones(n_var)
    integrality[-1] = 0.0
    ub_t = warm_makespan if warm_makespan is not None else float(np.sum(weights))
    bounds = Bounds(
        lb=np.zeros(n_var),
        ub=np.concatenate([np.ones(n_x), [ub_t]]),
    )
    res = milp(
        c=c,
        constraints=[eq, ub],
        integrality=integrality,
        bounds=bounds,
        options={"time_limit": time_limit_s, "presolve": True},
    )
    if res.x is None:
        return None
    x = np.asarray(res.x[:n_x]).reshape(n_i, n_clients)
    assignment: List[List[int]] = [[] for _ in range(n_clients)]
    for i in range(n_i):
        j = int(np.argmax(x[i]))
        assignment[j].append(i)
    return assignment


# --------------------------------------------------------------------------- #
# Composition                                                                 #
# --------------------------------------------------------------------------- #
def solve_offline(
    requests: Sequence[Request],
    n_clients: int,
    cost_model: CostModel,
    exact: bool = False,
    exact_time_limit_s: float = 60.0,
    local_search_rounds: int = 200,
    include_prefill: bool = False,
    cache_aware: bool = True,
) -> OfflineResult:
    """Solve the offline request-assignment model.

    Default path: LPT + local search (paper-scale in milliseconds). With
    ``exact=True`` also runs the MILP (keeps whichever is better) — this is
    the SCIP path in the paper, practical only at small scale.
    ``include_prefill`` / ``cache_aware`` select the prefill-inclusive,
    prefix-cache-aware pricing (see ``request_weights``).
    """
    if n_clients <= 0:
        raise ValueError("n_clients must be positive")
    t0 = time.perf_counter()
    weights = _weights(
        requests, cost_model, n_clients,
        include_prefill=include_prefill, cache_aware=cache_aware,
    )
    rid_of = [r.rid for r in requests]

    assignment = lpt_assign(weights, n_clients)
    assignment = local_search(assignment, weights, max_rounds=local_search_rounds)
    solver = "lpt+local_search"

    loads = _loads(assignment, weights)
    if exact:
        exact_asn = milp_assign(
            weights, n_clients, time_limit_s=exact_time_limit_s,
            warm_makespan=float(np.max(loads)),
        )
        if exact_asn is not None:
            exact_loads = _loads(exact_asn, weights)
            if float(np.max(exact_loads)) < float(np.max(loads)) - 1e-12:
                assignment, loads = exact_asn, exact_loads
                solver = "milp(highs)"
            else:
                solver = "lpt+local_search(=milp)"

    lp_lb = max(float(np.sum(weights)) / n_clients, float(np.max(weights)) if len(weights) else 0.0)
    # Map positional indices back to request ids, ordering each client's
    # backlog longest-first (Algorithm 1's sort by N_i^p + N_i^d).
    by_pos = {i: requests[i] for i in range(len(requests))}
    mapped: List[List[int]] = []
    for client in assignment:
        ordered = sorted(client, key=lambda i: -by_pos[i].est_total_tokens)
        mapped.append([rid_of[i] for i in ordered])
    return OfflineResult(
        assignment=mapped,
        loads=[float(x) for x in loads],
        makespan_est=float(np.max(loads)) if len(loads) else 0.0,
        lp_lower_bound=lp_lb,
        solver=solver,
        solve_seconds=time.perf_counter() - t0,
    )


def evaluate_assignment(
    requests: Sequence[Request],
    assignment: List[List[int]],
    n_clients: int,
    cost_model: CostModel,
    solver: str = "external",
) -> OfflineResult:
    """Wrap an externally-produced assignment (client → rid lists, e.g.
    ``round_robin_assign``) in an ``OfflineResult`` with the same load /
    makespan / LP-bound diagnostics ``solve_offline`` reports — so baseline
    ablations and the solver path are compared on identical terms."""
    if len(assignment) != n_clients:
        raise ValueError("assignment length != n_clients")
    t0 = time.perf_counter()
    weights = _weights(requests, cost_model, n_clients)
    pos_of = {r.rid: i for i, r in enumerate(requests)}
    loads = [
        sum(float(weights[pos_of[rid]]) for rid in client)
        for client in assignment
    ]
    lp_lb = max(
        float(np.sum(weights)) / n_clients,
        float(np.max(weights)) if len(weights) else 0.0,
    )
    return OfflineResult(
        assignment=[list(c) for c in assignment],
        loads=loads,
        makespan_est=float(max(loads)) if loads else 0.0,
        lp_lower_bound=lp_lb,
        solver=solver,
        solve_seconds=time.perf_counter() - t0,
    )


def split_requests(
    requests: Sequence[Request], assignment: List[List[int]]
) -> List[List[Request]]:
    """Materialize an assignment (client → rid list) as per-client Request
    lists, preserving the assignment's per-client order. Used by the fleet
    to turn a replica-level ``solve_offline``/``round_robin_assign`` result
    into per-replica backlogs."""
    by_rid: Dict[int, Request] = {r.rid: r for r in requests}
    out: List[List[Request]] = []
    seen: set = set()
    for rids in assignment:
        part = []
        for rid in rids:
            if rid in seen:
                raise ValueError(f"request {rid} assigned twice")
            seen.add(rid)
            part.append(by_rid[rid])
        out.append(part)
    if len(seen) != len(requests):
        missing = sorted(set(by_rid) - seen)
        raise ValueError(f"requests not assigned: {missing[:5]}...")
    return out


def round_robin_assign(requests: Sequence[Request], n_clients: int) -> List[List[int]]:
    """FCFS round-robin — the unbalanced baseline assignment (Fig. 6)."""
    assignment: List[List[int]] = [[] for _ in range(n_clients)]
    for pos, r in enumerate(requests):
        assignment[pos % n_clients].append(r.rid)
    return assignment


# --------------------------------------------------------------------------- #
# Theoretical lower bound (Eqs. 31–32)                                        #
# --------------------------------------------------------------------------- #
@dataclass
class LowerBound:
    t_prefill_star: float
    t_decode_star: float

    @property
    def total(self) -> float:
        return self.t_prefill_star + self.t_decode_star


def theoretical_lower_bound(
    requests: Sequence[Request],
    n_clients: int,
    cost_model: CostModel,
    use_true_lengths: bool = True,
) -> LowerBound:
    """T_LB = t^p* + t^d*.

    t^p* = T_L^p ⌈Σ_i N_i^p / N_L^cap⌉     (prefill fully packed at level L)
    t^d* = optimal decode makespan. Decode runs in lockstep rounds of ≤ J
           tokens; a round with n active clients costs T_oh + T_tok·n, so
           per-token system time is minimized at n = J. Hence at least
           ⌈Σ_i N_i^d / J⌉ rounds are needed, none cheaper (per token) than a
           full round; and no request finishes in fewer than N_i^d rounds,
           each at least the single-client round time. t^d* is the max of the
           two bounds — the paper's P||Cmax construction (Eqs. 26–30).
    """
    lvl = cost_model.max_level
    total_prefill_tokens = sum(r.n_prefill for r in requests)
    n_stages = int(np.ceil(total_prefill_tokens / lvl.cap_tokens))
    t_p_star = lvl.duration_s * n_stages

    def dlen(r: Request) -> int:
        return r.n_decode if use_true_lengths else int(r.n_decode_est or r.n_decode)

    lens = np.asarray([dlen(r) for r in requests], dtype=np.float64)
    if len(lens) == 0:
        return LowerBound(t_p_star, 0.0)
    packed_rounds = float(np.ceil(np.sum(lens) / n_clients))
    t_d_star = max(
        packed_rounds * cost_model.decode_round_time(n_clients),
        float(np.max(lens)) * cost_model.decode_round_time(1),
    )
    return LowerBound(t_prefill_star=t_p_star, t_decode_star=t_d_star)
