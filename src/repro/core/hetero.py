"""Heterogeneous fleet scheduling — R||Cmax: the paper's offline layer
generalized to replicas that run at different speeds.

The paper's offline model (Eqs. 26–30) and lower bound (Eqs. 31–32) assume
identical machines: one shared ``CostModel`` prices every client, which is
P||Cmax. Real fleets mix accelerator generations, so this module lifts the
same three pieces to *unrelated* machines, where request ``i`` costs
``T[i, j]`` seconds on replica ``j`` — each entry priced through that
replica's own ``CostModel`` (seeded from a per-replica prior, refit live by
that replica's ``OnlineProfiler``):

  * ``hetero_lpt_assign``   — speed-scaled LPT seed: jobs descend by their
                              best-machine size, each lands on the replica
                              minimizing its *completion time* there (load +
                              T[i, j]), not the emptiest queue.
  * ``hetero_local_search`` — move/swap refinement where every candidate is
                              re-priced through the destination replica's
                              column of the weight matrix.
  * ``hetero_lp_lower_bound`` — the assignment-level R||Cmax floor:
                              max(LP relaxation, max_i min_j T[i, j]),
                              reducing to P||Cmax's max(mean load, max item)
                              when all columns are identical.
  * ``hetero_theoretical_lower_bound`` — the wall-clock fleet floor
                              (Eqs. 31–32 generalized): stage/round terms
                              priced at the fleet's harmonic-mean stage
                              time, single-request term at the fastest
                              replica. Recovers ``theoretical_lower_bound``
                              at n_clients = replicas × slots *exactly* when
                              every replica's cost model is identical.

Execution-side plumbing (per-replica profilers, ``speed_factor`` virtual
time, speed-aware dispatch and stealing) lives in ``serving.fleet``; this
module is pure scheduling math shared with tests and benchmarks.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from .cost_model import CostModel
from .offline import LowerBound, OfflineResult, theoretical_lower_bound
from .types import Request


@dataclass(frozen=True)
class ReplicaSpec:
    """Static description of one replica in a heterogeneous fleet.

    ``speed_factor`` is relative speed (1.0 = the fleet's baseline; 0.5 =
    half as fast, stage durations double). It does double duty: it seeds the
    replica's cost-model prior (``resolve_cost_model``) and it scales the
    engine's virtual-time stage clock so mixed-generation fleets are
    emulatable — and deterministically testable — on one CPU host. An
    explicit ``cost_model`` overrides the scaled prior (e.g. a replica whose
    prefill/decode ratio differs, not just its clock rate).
    """

    speed_factor: float = 1.0
    cost_model: Optional[CostModel] = None
    name: str = ""

    def __post_init__(self) -> None:
        if self.speed_factor <= 0:
            raise ValueError("speed_factor must be positive")

    def resolve_cost_model(self, base: CostModel) -> CostModel:
        """The replica's cost-model prior: the explicit model if given,
        otherwise the fleet's base model scaled by this replica's speed."""
        if self.cost_model is not None:
            return self.cost_model
        return base.scaled(self.speed_factor)


def replica_request_weight(
    req: Request,
    cost_model: CostModel,
    slots_per_replica: int,
    remaining_decode: Optional[int] = None,
    cached_prefill: Optional[int] = None,
) -> float:
    """Request ``req``'s estimated service time on one replica: prefill
    plus client wall-clock decode completion at that replica's slot count,
    priced through the replica's own cost model. THE per-request pricing
    rule of the heterogeneous layer — the offline weight matrix, the
    ``least_load`` dispatch load, and the steal gate all call this one
    function, so the solve and the online layer can never silently
    diverge. ``remaining_decode`` overrides the decode estimate for
    partially-served requests (dispatch load accounting).

    ``cached_prefill`` is how many of the request's prompt tokens THIS
    replica's prefix cache would supply (warm-state probe): the prefill
    term prices only the uncached remainder, so a replica that already
    holds a request's shared prefix genuinely bids lower than a cold one.
    Defaults to ``req.cached_prefill`` (0 for cache-less fleets — the
    historical pricing, unchanged)."""
    decode = (
        int(req.n_decode_est or req.n_decode)
        if remaining_decode is None else max(remaining_decode, 0)
    )
    cached = req.cached_prefill if cached_prefill is None else cached_prefill
    uncached = max(req.n_prefill - max(cached, 0), 0)
    return cost_model.prefill_time(uncached) + (
        cost_model.estimated_decode_completion(decode, slots_per_replica)
    )


def replica_resume_weight(
    req: Request,
    cost_model: CostModel,
    slots_per_replica: int,
    remaining_decode: int,
) -> float:
    """Service time of a page-copied (live-migrated) in-flight request on a
    replica: decode-only. The import lands the request's KV pages directly
    into the destination pool, so unlike ``replica_request_weight`` no
    prefill is ever re-paid — which is exactly why moving a running
    straggler can price in where re-queueing it could not. The running-slot
    steal gate and the drain placement both price through this rule."""
    return cost_model.estimated_decode_completion(
        max(remaining_decode, 0), slots_per_replica
    )


def hetero_weights(
    requests: Sequence[Request],
    cost_models: Sequence[CostModel],
    slots_per_replica: int,
    replica_penalties: Optional[Sequence[float]] = None,
    cached_tokens: Optional[np.ndarray] = None,
) -> np.ndarray:
    """The R||Cmax weight matrix ``T[i, j]``: request ``i``'s estimated
    service time on replica ``j`` (``replica_request_weight`` evaluated
    per replica cost model — the same pricing ``least_load`` dispatch
    uses). ``replica_penalties`` multiplies whole columns (≥ 1.0 each):
    the health layer prices SUSPECT replicas out of the offline solve by
    inflating their columns, rather than deleting them — the solver's
    shape stays R-wide and a penalized replica still takes work if every
    alternative is worse.

    ``cached_tokens`` — an ``(n_requests, n_replicas)`` matrix of prompt
    tokens each replica's warm prefix cache would supply — makes the
    prefill term per-(request, replica): a replica already holding a
    request's shared prefix bids its uncached remainder only, so cache
    affinity flows into the R||Cmax solve instead of being a hot-path
    accident. None (the default) prices ``Request.cached_prefill``
    uniformly — 0 for cache-less fleets, the historical matrix."""
    n_i, n_j = len(requests), len(cost_models)
    if replica_penalties is not None and len(replica_penalties) != n_j:
        raise ValueError(
            f"{len(replica_penalties)} penalties for {n_j} replicas"
        )
    if cached_tokens is not None:
        cached_tokens = np.asarray(cached_tokens)
        if cached_tokens.shape != (n_i, n_j):
            raise ValueError(
                f"cached_tokens shape {cached_tokens.shape} != ({n_i}, {n_j})"
            )
    t = np.zeros((n_i, n_j), dtype=np.float64)
    for j, cm in enumerate(cost_models):
        pen = 1.0 if replica_penalties is None else float(replica_penalties[j])
        if pen < 1.0:
            raise ValueError("replica penalties must be >= 1.0")
        for i, r in enumerate(requests):
            t[i, j] = pen * replica_request_weight(
                r, cm, slots_per_replica,
                cached_prefill=(
                    None if cached_tokens is None
                    else int(cached_tokens[i, j])
                ),
            )
    return t


# --------------------------------------------------------------------------- #
# Heuristics                                                                  #
# --------------------------------------------------------------------------- #
# A "machine" in this R||Cmax instance is a whole REPLICA: ``slots`` clients
# decoding in parallel. Its estimated completion ("span") is therefore NOT
# the sum of its items' client-wall-times but
#
#     span_j = max( Σ_i T[i, j] / slots ,  max_i T[i, j] )
#
# — the average-client-load floor (work spreads over the slots) and the
# single-item floor (one request cannot split across clients; a long decode
# on a slow replica straggles its client for the full item weight no matter
# how idle the neighbors are). Summed loads alone would happily trade one
# huge item for several small ones and park a straggler on the slow replica.
def _replica_spans(
    assignment: List[List[int]], weights: np.ndarray, slots: int
) -> np.ndarray:
    spans = np.zeros(weights.shape[1], dtype=np.float64)
    for j, items in enumerate(assignment):
        if not items:
            continue
        w = [float(weights[i, j]) for i in items]
        spans[j] = max(sum(w) / slots, max(w))
    return spans


def hetero_lpt_assign(weights: np.ndarray, slots: int) -> List[List[int]]:
    """Speed-scaled LPT: jobs ordered by descending best-machine size
    (min_j T[i, j]), each assigned to the replica whose estimated span
    grows the least by taking it. Reduces to plain LPT when all columns
    are identical."""
    n_i, n_j = weights.shape
    order = np.argsort(-weights.min(axis=1), kind="stable")
    sums = np.zeros(n_j, dtype=np.float64)
    maxes = np.zeros(n_j, dtype=np.float64)
    assignment: List[List[int]] = [[] for _ in range(n_j)]
    for i in order:
        new_spans = np.maximum(
            (sums + weights[i]) / slots, np.maximum(maxes, weights[i])
        )
        j = int(np.argmin(new_spans))
        assignment[j].append(int(i))
        sums[j] += weights[i, j]
        maxes[j] = max(maxes[j], float(weights[i, j]))
    return assignment


def hetero_local_search(
    assignment: List[List[int]],
    weights: np.ndarray,
    slots: int,
    max_rounds: int = 200,
) -> List[List[int]]:
    """Move/swap local search on the R||Cmax makespan (max replica span).

    Unlike the P||Cmax version, a candidate move changes the item's weight:
    moving ``i`` from the max-span replica ``a`` to ``b`` removes
    ``T[i, a]`` and adds ``T[i, b]`` — every candidate is re-priced through
    the *destination* replica's cost model. Each round takes the best strict
    makespan improvement among all single-item moves off the max-span
    replica, falling back to the best pairwise swap with any other replica.
    """
    assignment = [list(c) for c in assignment]
    n_j = weights.shape[1]

    def span_of(items: List[int], j: int) -> float:
        if not items:
            return 0.0
        w = [float(weights[i, j]) for i in items]
        return max(sum(w) / slots, max(w))

    for _ in range(max_rounds):
        spans = _replica_spans(assignment, weights, slots)
        a = int(np.argmax(spans))

        def makespan_excluding(*excl: int) -> float:
            rest = [spans[j] for j in range(n_j) if j not in excl]
            return max(rest) if rest else 0.0

        best_move = None  # (new_makespan, i, dest)
        for i in assignment[a]:
            rem_a = [x for x in assignment[a] if x != i]
            for b in range(n_j):
                if b == a:
                    continue
                new_mk = max(
                    span_of(rem_a, a),
                    span_of(assignment[b] + [i], b),
                    makespan_excluding(a, b),
                )
                if new_mk < spans[a] - 1e-12 and (
                    best_move is None or new_mk < best_move[0] - 1e-12
                ):
                    best_move = (new_mk, i, b)
        if best_move is not None:
            _, i, b = best_move
            assignment[a].remove(i)
            assignment[b].append(i)
            continue
        best_swap = None  # (new_makespan, x, b, y)
        for b in range(n_j):
            if b == a:
                continue
            for x in assignment[a]:
                rem_a = [i for i in assignment[a] if i != x]
                for y in assignment[b]:
                    rem_b = [i for i in assignment[b] if i != y]
                    new_mk = max(
                        span_of(rem_a + [y], a),
                        span_of(rem_b + [x], b),
                        makespan_excluding(a, b),
                    )
                    if new_mk < spans[a] - 1e-12 and (
                        best_swap is None or new_mk < best_swap[0] - 1e-12
                    ):
                        best_swap = (new_mk, x, b, y)
        if best_swap is None:
            break
        _, x, b, y = best_swap
        assignment[a].remove(x)
        assignment[b].remove(y)
        assignment[a].append(y)
        assignment[b].append(x)
    return assignment


# --------------------------------------------------------------------------- #
# Lower bounds                                                                #
# --------------------------------------------------------------------------- #
def hetero_lp_lower_bound(weights: np.ndarray, slots: int = 1) -> float:
    """Assignment-level R||Cmax lower bound, in replica-span units (each
    machine is a replica of ``slots`` parallel clients — see
    ``_replica_spans``).

    max of three valid floors:

      * LP relaxation of the assignment model over per-slot loads
        (fractional x_{ij} on weights T/slots, scipy HiGHS; skipped
        silently if the solver is unavailable or fails);
      * ``max_i min_j T[i, j]`` — every job occupies one client somewhere,
        at best on its fastest machine (the item-integrality term both
        relaxations miss);
      * ``Σ_i min_j T[i, j] / (R·slots)`` — work conservation at
        best-machine pricing (the closed-form stand-in for the LP).

    With identical columns (homogeneous fleet) this reduces to P||Cmax's
    ``max(mean per-client load, max item)`` over the flat pool of R·slots
    clients — the same form ``solve_offline`` reports as its
    ``lp_lower_bound``.
    """
    if weights.size == 0:
        return 0.0
    n_i, n_j = weights.shape
    best = weights.min(axis=1)
    bound = max(float(best.max()), float(best.sum()) / (n_j * slots))
    lp = _assignment_lp(weights / slots)
    if lp is not None:
        bound = max(bound, lp)
    return bound


def _assignment_lp(weights: np.ndarray) -> Optional[float]:
    """LP relaxation of min-makespan assignment: min C s.t. Σ_j x_ij = 1,
    Σ_i T_ij x_ij ≤ C, x ∈ [0, 1]. Returns None when scipy is unavailable
    or the solve fails (callers fall back to the closed-form floors)."""
    try:
        import scipy.sparse as sp
        from scipy.optimize import linprog
    except Exception:  # noqa: BLE001 — scipy is optional here
        return None
    n_i, n_j = weights.shape
    n_x = n_i * n_j
    c = np.zeros(n_x + 1)
    c[-1] = 1.0
    rows, cols, vals = [], [], []
    for i in range(n_i):
        for j in range(n_j):
            rows.append(i)
            cols.append(i * n_j + j)
            vals.append(1.0)
    a_eq = sp.csr_matrix((vals, (rows, cols)), shape=(n_i, n_x + 1))
    rows, cols, vals = [], [], []
    for j in range(n_j):
        for i in range(n_i):
            rows.append(j)
            cols.append(i * n_j + j)
            vals.append(float(weights[i, j]))
        rows.append(j)
        cols.append(n_x)
        vals.append(-1.0)
    a_ub = sp.csr_matrix((vals, (rows, cols)), shape=(n_j, n_x + 1))
    try:
        res = linprog(
            c,
            A_eq=a_eq,
            b_eq=np.ones(n_i),
            A_ub=a_ub,
            b_ub=np.zeros(n_j),
            bounds=[(0.0, 1.0)] * n_x + [(0.0, None)],
            method="highs",
        )
    except Exception:  # noqa: BLE001
        return None
    if not res.success:
        return None
    return float(res.x[-1])


def _harmonic_mean(values: Sequence[float]) -> float:
    """Harmonic mean with an exact short-circuit for equal inputs, so the
    homogeneous reduction of the fleet bound is bit-identical to the
    P||Cmax formula rather than equal-up-to-rounding."""
    vals = [float(v) for v in values]
    if not vals:
        return 0.0
    if min(vals) == max(vals):
        return vals[0]
    if min(vals) <= 0:
        return 0.0
    return len(vals) / sum(1.0 / v for v in vals)


def hetero_theoretical_lower_bound(
    requests: Sequence[Request],
    cost_models: Sequence[CostModel],
    slots_per_replica: int,
    use_true_lengths: bool = True,
) -> LowerBound:
    """Wall-clock fleet floor: Eqs. 31–32 generalized to per-replica speeds.

    The paper's flat-pool construction prices ``ceil(ΣN^p / cap)`` prefill
    stages at the level-L duration and ``ceil(ΣN^d / J)`` packed decode
    rounds at the full-batch round time. With replicas of differing speed
    the fleet's aggregate stage-production rate is the sum of per-replica
    rates, so each stage/round term is priced at the *harmonic mean* of the
    per-replica stage times (R machines at harmonic-mean time T̄ produce
    stages exactly as fast as the actual mixed fleet); the
    longest-single-request term runs at best on the *fastest* replica
    (min_j single-client round time). With identical cost models every
    harmonic mean collapses to the shared value and the result equals
    ``theoretical_lower_bound(requests, R × slots, cm)`` exactly — the
    P||Cmax bound is the homogeneous special case, unit-tested as such.

    Like the paper's bound, this is the flat-pool idealization (perfect
    packing, no prefill/decode interleaving conflicts): a floor up to
    cost-model fit error, which ``benchmarks/hetero_fleet.py`` validates
    against measured per-replica models.
    """
    if not cost_models:
        raise ValueError("need at least one replica cost model")
    if all(cm == cost_models[0] for cm in cost_models[1:]):
        # exact homogeneous reduction — delegate to the paper's formula
        return theoretical_lower_bound(
            requests,
            len(cost_models) * slots_per_replica,
            cost_models[0],
            use_true_lengths=use_true_lengths,
        )
    n_rep = len(cost_models)
    j_total = n_rep * slots_per_replica
    cap = max(cm.max_level.cap_tokens for cm in cost_models)
    total_prefill = sum(r.n_prefill for r in requests)
    n_stages = int(np.ceil(total_prefill / cap))
    t_p_star = n_stages * _harmonic_mean(
        [cm.max_level.duration_s for cm in cost_models]
    )

    def dlen(r: Request) -> int:
        return r.n_decode if use_true_lengths else int(r.n_decode_est or r.n_decode)

    lens = np.asarray([dlen(r) for r in requests], dtype=np.float64)
    if len(lens) == 0:
        return LowerBound(t_p_star, 0.0)
    packed_rounds = float(np.ceil(np.sum(lens) / j_total))
    round_hm = _harmonic_mean([cm.decode_round_time(j_total) for cm in cost_models])
    fastest_single = min(cm.decode_round_time(1) for cm in cost_models)
    t_d_star = max(
        packed_rounds * round_hm,
        float(np.max(lens)) * fastest_single,
    )
    return LowerBound(t_prefill_star=t_p_star, t_decode_star=t_d_star)


# --------------------------------------------------------------------------- #
# Composition                                                                 #
# --------------------------------------------------------------------------- #
def _mapped_result(
    requests: Sequence[Request],
    assignment: List[List[int]],
    weights: np.ndarray,
    slots: int,
    solver: str,
    t0: float,
) -> OfflineResult:
    spans = _replica_spans(assignment, weights, slots)
    rid_of = [r.rid for r in requests]
    mapped: List[List[int]] = []
    for client in assignment:
        # longest-first per replica (Algorithm 1's sort by N^p + N^d)
        ordered = sorted(client, key=lambda i: -requests[i].est_total_tokens)
        mapped.append([rid_of[i] for i in ordered])
    return OfflineResult(
        assignment=mapped,
        loads=[float(x) for x in spans],
        makespan_est=float(np.max(spans)) if len(spans) else 0.0,
        lp_lower_bound=hetero_lp_lower_bound(weights, slots),
        solver=solver,
        solve_seconds=time.perf_counter() - t0,
    )


def solve_hetero(
    requests: Sequence[Request],
    cost_models: Sequence[CostModel],
    slots_per_replica: int,
    local_search_rounds: int = 200,
    replica_penalties: Optional[Sequence[float]] = None,
) -> OfflineResult:
    """Solve the R||Cmax offline assignment: speed-scaled LPT seed + local
    search re-priced through each replica's own cost model. Returns the same
    ``OfflineResult`` shape as ``solve_offline`` (per-replica rid lists
    ordered longest-first, loads, makespan estimate, LP lower bound), so the
    fleet layer treats both solvers identically. ``replica_penalties``
    inflates whole columns of the weight matrix (see ``hetero_weights``) —
    how SUSPECT replicas are priced out of a solve without changing its
    shape."""
    if not cost_models:
        raise ValueError("need at least one replica cost model")
    t0 = time.perf_counter()
    weights = hetero_weights(
        requests, cost_models, slots_per_replica,
        replica_penalties=replica_penalties,
    )
    assignment = hetero_lpt_assign(weights, slots_per_replica)
    assignment = hetero_local_search(
        assignment, weights, slots_per_replica, max_rounds=local_search_rounds
    )
    return _mapped_result(
        requests, assignment, weights, slots_per_replica,
        "hetero-lpt+local_search", t0,
    )


def evaluate_hetero_assignment(
    requests: Sequence[Request],
    assignment: List[List[int]],
    cost_models: Sequence[CostModel],
    slots_per_replica: int,
    solver: str = "external",
) -> OfflineResult:
    """Price an externally-produced assignment (replica → rid lists, e.g. a
    speed-blind ``solve_offline`` partition or ``round_robin_assign``) on
    the heterogeneous weight matrix — so speed-blind baselines and the
    R||Cmax solver are compared on identical terms."""
    if len(assignment) != len(cost_models):
        raise ValueError("assignment length != number of replicas")
    t0 = time.perf_counter()
    weights = hetero_weights(requests, cost_models, slots_per_replica)
    pos_of = {r.rid: i for i, r in enumerate(requests)}
    positional = [[pos_of[rid] for rid in client] for client in assignment]
    return _mapped_result(
        requests, positional, weights, slots_per_replica, solver, t0
    )
