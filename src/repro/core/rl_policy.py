"""RL iteration scheduler — the paper's future-work #2, implemented.

"a simple reinforcement learning model could be trained to assist the
scheduler in making decisions dynamically" (§VI). The state variables the
paper names (prefill waiting, decoding clients, expected decode/prefill
time) are cheap to derive — we discretize them into a small Q-table and
train with tabular Q-learning directly inside the simulator.

State: (idle-fraction bucket, candidate C_d/C_p ratio bucket,
        pending-pressure bucket); actions: {decode, prefill}.
Reward: −(stage duration) · (fraction of clients NOT doing useful work) —
i.e., the idle client-time each decision buys, which telescopes to the
trace's total idle area (= (1−utilization)·J·makespan), so minimizing it is
exactly maximizing the paper's objective.

Training runs in the event-driven simulator (thousands of decisions per
second), so a policy trains in seconds; see EXPERIMENTS.md §Beyond-paper.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from .cost_model import CostModel
from .iteration import IterationPolicy, SystemSnapshot

N_IDLE_BUCKETS = 6
N_RATIO_BUCKETS = 6
N_PRESSURE_BUCKETS = 3


def _state(snap: SystemSnapshot, cm: CostModel) -> Tuple[int, int, int]:
    idle_frac = snap.n_idle / max(snap.n_clients, 1)
    idle_b = min(int(idle_frac * N_IDLE_BUCKETS), N_IDLE_BUCKETS - 1)
    cand = snap.candidate
    if cand:
        c_p = cm.quantized_prefill_time(
            min(cand.total_prefill_tokens, cm.max_level.cap_tokens)
        )
        c_d = cm.decode_per_token * cand.total_decode_est
        ratio = c_d / max(c_p, 1e-9)
    else:
        ratio = 0.0
    ratio_b = min(int(ratio / 0.5), N_RATIO_BUCKETS - 1)  # 0.5-wide buckets
    press = snap.pending_requests / max(snap.n_idle, 1)
    press_b = 0 if press <= 1 else (1 if press <= 4 else 2)
    return idle_b, ratio_b, press_b


@dataclass
class RLPolicy(IterationPolicy):
    """Tabular Q-policy over the paper's suggested state variables."""

    q: np.ndarray = field(
        default_factory=lambda: np.zeros(
            (N_IDLE_BUCKETS, N_RATIO_BUCKETS, N_PRESSURE_BUCKETS, 2), np.float64
        )
    )
    epsilon: float = 0.0
    rng: np.random.Generator = field(default_factory=lambda: np.random.default_rng(0))
    name: str = "rl"

    # training hooks (filled by the trainer between decisions)
    _last: Optional[Tuple[Tuple[int, int, int], int]] = None

    def decide_prefill(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        # Structural progress guards (not learnable): drain-phase admission
        # and capacity saturation (see BalancedLagrangianPolicy) — the RL
        # policy learns only the genuine wait-vs-fire trade-off region.
        cand = snap.candidate
        if snap.pending_requests <= snap.n_idle:
            return True
        if snap.n_idle > len(cand.requests) and snap.pending_requests > len(cand.requests):
            return True
        if cand.total_prefill_tokens >= cost_model.max_level.cap_tokens:
            return True
        s = _state(snap, cost_model)
        if self.epsilon > 0 and self.rng.random() < self.epsilon:
            a = int(self.rng.integers(0, 2))
        else:
            a = int(np.argmax(self.q[s]))
        self._last = (s, a)
        return bool(a)


def train_rl_policy(
    make_requests,
    n_clients: int,
    cost_model: CostModel,
    episodes: int = 60,
    alpha: float = 0.2,
    gamma: float = 0.98,
    seed: int = 0,
) -> RLPolicy:
    """Q-learning in the simulator. ``make_requests(episode)`` supplies a
    fresh workload per episode (same distribution as evaluation)."""
    from .online import SortingPreemptiveScheduler, build_clients
    from .offline import solve_offline
    from .simulator import SimConfig, Simulator

    policy = RLPolicy(rng=np.random.default_rng(seed))

    class TrainingPolicy(IterationPolicy):
        name = "rl-training"

        def __init__(self):
            self.prev_sa = None

        def __call__(self, snap: SystemSnapshot, cm: CostModel) -> bool:
            # reward for the PREVIOUS decision materializes as the idle
            # client-time since then; approximate by the idle area of the
            # stage the previous action produced.
            s = _state(snap, cm)
            cand = snap.candidate
            guard = (
                bool(cand)
                and (
                    snap.pending_requests <= snap.n_idle
                    or (snap.n_idle > len(cand.requests)
                        and snap.pending_requests > len(cand.requests))
                    or cand.total_prefill_tokens >= cm.max_level.cap_tokens
                )
            )
            if not cand:
                a = 0
            elif snap.n_active == 0 or guard:
                a = 1
            else:
                if policy.rng.random() < policy.epsilon:
                    a = int(policy.rng.integers(0, 2))
                else:
                    a = int(np.argmax(policy.q[s]))
            if self.prev_sa is not None:
                ps, pa, pt, pidle = self.prev_sa
                dt = snap.now - pt
                reward = -dt * (pidle / max(snap.n_clients, 1))
                target = reward + gamma * np.max(policy.q[s])
                policy.q[ps + (pa,)] += alpha * (target - policy.q[ps + (pa,)])
            self.prev_sa = (s, a, snap.now, snap.n_idle)
            return bool(a)

    for ep in range(episodes):
        policy.epsilon = max(0.02, 0.4 * (1 - ep / max(episodes - 1, 1)))
        reqs = make_requests(ep)
        res = solve_offline(reqs, n_clients, cost_model)
        clients = build_clients(n_clients, reqs, res.assignment)
        sched = SortingPreemptiveScheduler(clients)
        sim = Simulator(
            reqs,
            SimConfig(n_clients=n_clients, cost_model=cost_model,
                      record_decisions=False),
            sched,
            TrainingPolicy(),
            clients=clients,
            policy_name="rl-train",
        )
        sim.run()
    policy.epsilon = 0.0
    return policy
