"""Online iteration scheduling — when to preempt decode and insert prefill.

The decision runs at every decode-round boundary (the paper's ~50 ms cadence)
and must return within the real-time budget (<10 ms; measured <5 ms, see
``benchmarks``). Policies:

  * ``PrefillFirstPolicy``   — the vLLM-style baseline: insert a prefill stage
    whenever any client is idle and a request is waiting.
  * ``LagrangianPolicy``     — the paper's rule (Eqs. 41–43): compare the
    marginal makespan cost of a prefill stage, C_p = T_l^p (the *level*
    duration of the candidate batch — levels quantize the decision exactly as
    y_{k,l} does in the MIP), against the waited decode value it unlocks,
    C_d = T^d Σ_{i∈batch} N_i^d. Prefill iff C_p < C_d.
  * Beyond-paper policies (§EXPERIMENTS.md §Beyond-paper):
      - ``UtilizationWeightedPolicy`` — weighs the prefill stall by the number
        of clients it stalls vs the idleness it cures.
      - ``DynamicBatchPolicy`` — the paper's future-work #3: caps concurrent
        clients dynamically from the memory/throughput trade-off.

All policies are pure functions of a small ``SystemSnapshot``, so the same
code runs in the simulator and in the real engine's dispatch loop.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

from .cost_model import CostModel
from .types import Request


@dataclass
class CandidateBatch:
    """Prefill batch the request scheduler proposes for the idle clients.

    ``chunk_tokens`` is set by the chunked-prefill engine: the tokens the
    *next stage* would actually process (one chunk per request), which may be
    far fewer than the batch's full prompts. Policies must price the stage
    they are deciding on, so cost comparisons use
    ``effective_prefill_tokens`` — with whole-prompt prefill the two are
    identical, with chunking the marginal stage is one chunk round (this is
    what lets a Lagrangian-style rule interleave prefill work without
    stalling decode for a whole prompt; HyGen §4)."""

    requests: List[Request]
    client_ids: List[int]
    chunk_tokens: Optional[int] = None
    # Prompt tokens across the batch the prefix cache will supply (the
    # engine probes its index when building the candidate; 0 with no cache
    # or under the cache-blind pricing ablation). Cached tokens are never
    # computed, so policies pricing outstanding prefill work must charge
    # ``uncached_prefill_tokens``, not the nominal prompt lengths.
    cached_tokens: int = 0

    @property
    def total_prefill_tokens(self) -> int:
        return sum(r.n_prefill for r in self.requests)

    @property
    def uncached_prefill_tokens(self) -> int:
        """Outstanding prefill tokens that actually need compute — nominal
        prompt lengths minus what the prefix cache covers."""
        return max(self.total_prefill_tokens - self.cached_tokens, 0)

    @property
    def effective_prefill_tokens(self) -> int:
        """Tokens the next prefill stage would run: one chunk round when the
        engine chunks, the full prompts otherwise."""
        if self.chunk_tokens is not None:
            return self.chunk_tokens
        return self.total_prefill_tokens

    @property
    def total_decode_est(self) -> int:
        return sum(int(r.n_decode_est or r.n_decode) for r in self.requests)

    def __bool__(self) -> bool:
        return bool(self.requests)


@dataclass
class SystemSnapshot:
    """Everything an iteration policy may look at (cheap scalars only)."""

    n_clients: int
    n_active: int                     # clients currently decoding
    n_idle: int
    active_remaining_est: int         # Σ estimated remaining decode tokens (active)
    pending_requests: int             # requests not yet prefilled (global)
    candidate: CandidateBatch         # what a prefill stage would run *now*
    now: float                        # current sim/wall time (seconds)


@dataclass(frozen=True)
class Decision:
    """One iteration-level scheduling decision.

    ``prefill`` chooses the stage kind (the paper's binary choice);
    ``horizon`` is how many decode iterations to commit to one fused
    on-device dispatch when ``prefill`` is False. Horizon 1 reproduces the
    per-token baseline (one host sync per decoded token).

    ``chunk_tokens`` carries the *mixed-step* split: how many prefill-chunk
    tokens to co-schedule inside the next decode round (one unified
    dispatch — prefill piggybacks on decode instead of preempting it). It
    is only set when the engine offers a mixed budget; > 0 means "run a
    mixed round with this share", 0 falls through to pure (fused) decode.
    The binary ``prefill`` choice is the degenerate case: share = whole
    budget when there is nothing to decode, share = 0 when there is nothing
    to prefill."""

    prefill: bool
    horizon: int = 1
    chunk_tokens: int = 0


class IterationPolicy:
    name = "base"
    # SLO-urgency coupling for the mixed-step share (see ``_slo_urgency``).
    # Instances may set False for the SLO-blind ablation — requests still
    # *carry* their SLOs for goodput accounting; the scheduler just stops
    # looking at them.
    slo_urgency: bool = True

    def decide_prefill(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        """True → insert a prefill stage now; False → run a decode round."""
        raise NotImplementedError

    def _slo_urgency(self, snap: SystemSnapshot) -> float:
        """How close the candidate's most-pressed request is to blowing its
        TTFT deadline: max over candidates of elapsed / ttft_slo (0.0 with
        no deadlines in view). Crossing 1.0 means a deadline already passed.
        The share pricing multiplies its admission-pressure weight w by
        (1 + urgency), so a request nearing its deadline outbids the decode
        latency it inflates — the graceful-degradation half of overload
        control (the other half, offline admission throttling, lives in
        ``serving.overload``)."""
        if not self.slo_urgency:
            return 0.0
        u = 0.0
        for r in snap.candidate.requests:
            if r.ttft_slo_s is not None and r.ttft_slo_s > 0:
                u = max(u, (snap.now - r.arrival) / r.ttft_slo_s)
        return max(0.0, u)

    def decode_horizon(
        self, snap: SystemSnapshot, cost_model: CostModel, k_max: int = 1
    ) -> int:
        """Decode iterations to fuse into the next dispatch (1 ≤ K ≤ k_max).

        Default: the Lagrangian-style marginal rule shared by every policy.
        Fusing one more iteration saves amortized dispatch cost
        d/dK [C_dispatch·(1−1/K)] = C_dispatch/K², but commits the engine one
        round longer before it can reconsider — if prefill-ready work exists
        (or can appear when a slot frees mid-horizon), that delay costs an
        expected  w·t_round/2  of stalled prefill, with w the admission
        pressure (pending work per client slot). Equating the marginals
        prices the horizon in closed form:

            K* = sqrt(2·C_dispatch / (w·t_round))

        With no pending work there is nothing to preempt for (w→0, K*→∞) and
        the horizon saturates at ``k_max``; under heavy admission pressure
        K*→1 recovers the paper's per-iteration granularity.
        """
        if k_max <= 1:
            return 1
        # Prefill-ready work = queued requests OR an already-materialized
        # candidate (e.g. a long prompt's remaining chunks after the queue
        # drained) — either one makes delaying the next decision costly.
        waiters = max(snap.pending_requests, len(snap.candidate.requests))
        if waiters <= 0:
            return k_max
        w = min(1.0, waiters / max(snap.n_clients, 1))
        t_round = cost_model.decode_round_time(max(snap.n_active, 1))
        if t_round <= 0 or cost_model.decode_dispatch <= 0:
            return 1
        k_star = (2.0 * cost_model.decode_dispatch / (w * t_round)) ** 0.5
        return max(1, min(k_max, int(k_star)))

    def prefill_share(
        self,
        snap: SystemSnapshot,
        cost_model: CostModel,
        budget: int,
        explain: Optional[dict] = None,
    ) -> int:
        """Prefill-chunk tokens to co-schedule into the next *mixed* round
        (0 ≤ share ≤ budget) — the Lagrangian turned from a binary stage
        switch into a continuous knob.

        In a mixed batch nothing stalls: co-scheduling n prefill tokens
        merely inflates the round by t_p·n (every active decoder waits that
        much longer for its next token), while the waiting prompts' time to
        first token shrinks as their P outstanding tokens flow at n per
        round. The marginal decode-latency cost of the n-th chunk token is
        flat, n_active·t_p; the marginal queueing gain is diminishing,
        w·P·t_0/n² (finishing P tokens takes P·t_0/n + P·t_p seconds of
        round overhead, weighted by the admission pressure w = waiters per
        slot). Equating the marginals prices the share in closed form:

            n* = sqrt(w · P · t_0 / (n_active · t_p))

        with t_0 the pure-decode round time and t_p the cost model's fitted
        per-prefill-token inflation. With no active decoders there is no
        latency to protect (n*→∞ — take the whole budget); under heavy
        decode load with a trickle of prefill work n*→0 and the engine runs
        pure fused decode. The paper's binary choice survives as the two
        saturated ends of this knob.
        """
        def _out(share: int, rule: str, **priced) -> int:
            # audit-log hook: when the engine passes an ``explain`` dict the
            # priced inputs and chosen share are recorded alongside it
            if explain is not None:
                explain.update(
                    rule=rule, budget=budget, share=share,
                    n_active=snap.n_active, pending=snap.pending_requests,
                    candidate=len(snap.candidate.requests), **priced,
                )
            return share

        if budget <= 0:
            return _out(0, "no_budget")
        if snap.n_active == 0:
            # nothing decoding — nothing to inflate
            return _out(budget, "no_active_decoders")
        waiters = max(snap.pending_requests, len(snap.candidate.requests))
        if waiters <= 0:
            return _out(0, "no_waiters")
        w = min(1.0, waiters / max(snap.n_clients, 1))
        # SLO-urgency: a candidate nearing its TTFT deadline raises the
        # admission-pressure weight past its nominal [0, 1] cap, so the
        # priced share grows ~sqrt(1 + urgency) and the deadline outbids
        # the decode latency it inflates.
        urgency = self._slo_urgency(snap)
        w = w * (1.0 + urgency)
        t0 = cost_model.mixed_round_time(snap.n_active, 0)
        tp = cost_model.mixed_prefill_token_time
        if tp <= 0:
            # a noisy fit can clamp the mixed slope to exactly 0 — fall
            # back to the stage-level slope rather than pricing chunk
            # tokens as free (which would take the max share every round)
            tp = cost_model.prefill_per_token
        if t0 <= 0:
            t0 = cost_model.decode_round_time(snap.n_active)
        # P = outstanding prefill tokens that will actually flow through
        # mixed rounds: cache-adopted tokens never run, so pricing them
        # would buy decode-latency inflation for work that does not exist
        p_out = max(
            snap.candidate.uncached_prefill_tokens,
            snap.candidate.effective_prefill_tokens,
        )
        if t0 <= 0 or tp <= 0:
            return _out(budget, "degenerate_fit", w=w, t0=t0, tp=tp)
        n_star = (w * p_out * t0 / (snap.n_active * tp)) ** 0.5
        return _out(
            min(budget, int(n_star)), "lagrangian_share",
            w=w, urgency=urgency, p_out=p_out, t0=t0, tp=tp, n_star=n_star,
        )

    def decide(
        self,
        snap: SystemSnapshot,
        cost_model: CostModel,
        k_max: int = 1,
        mixed_budget: Optional[int] = None,
        explain: Optional[dict] = None,
    ) -> Decision:
        """Stage choice plus the decode horizon to run if decoding.

        ``mixed_budget`` switches to mixed-step semantics: instead of the
        binary prefill-vs-decode choice the policy prices the prefill-token
        share of one unified dispatch (``chunk_tokens``); 0 falls back to a
        pure fused-decode stage at the priced horizon. ``explain``, when a
        dict, is filled with the share evaluation's priced inputs (the
        engine forwards it to the observability audit log)."""
        if mixed_budget is not None:
            share = min(
                self.prefill_share(snap, cost_model, mixed_budget, explain),
                mixed_budget,
            )
            if share > 0:
                return Decision(prefill=False, horizon=1, chunk_tokens=share)
            return Decision(
                prefill=False,
                horizon=self.decode_horizon(snap, cost_model, k_max),
            )
        if self(snap, cost_model):
            return Decision(prefill=True)
        return Decision(
            prefill=False, horizon=self.decode_horizon(snap, cost_model, k_max)
        )

    def __call__(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        # Progress guarantees, shared by all policies:
        if not snap.candidate:
            return False                      # nothing to prefill
        if snap.n_active == 0:
            return True                       # nothing to decode — must prefill
        return self.decide_prefill(snap, cost_model)


class PrefillFirstPolicy(IterationPolicy):
    """Baseline: prefill whenever possible (FCFS prefill-first, §I)."""

    name = "prefill_first"

    def decide_prefill(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        return True

    def prefill_share(
        self,
        snap: SystemSnapshot,
        cost_model: CostModel,
        budget: int,
        explain: Optional[dict] = None,
    ) -> int:
        # mixed-step analogue of "prefill whenever possible": take the
        # whole chunk budget every round, regardless of latency inflation
        share = max(budget, 0)
        if explain is not None:
            explain.update(rule="prefill_first", budget=budget, share=share)
        return share


class DecodeFirstPolicy(IterationPolicy):
    """Anti-baseline for ablations: only prefill when forced."""

    name = "decode_first"

    def decide_prefill(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        return False

    def prefill_share(
        self,
        snap: SystemSnapshot,
        cost_model: CostModel,
        budget: int,
        explain: Optional[dict] = None,
    ) -> int:
        # only co-schedule prefill when there is nothing to decode at all
        share = max(budget, 0) if snap.n_active == 0 else 0
        if explain is not None:
            explain.update(
                rule="decode_first", budget=budget, share=share,
                n_active=snap.n_active,
            )
        return share


class LagrangianPolicy(IterationPolicy):
    """The paper's heuristic (Eqs. 41–43).

    C_p = T_l^p for the smallest level fitting the candidate batch (Eq. 42 —
    the marginal makespan cost of opening prefill stage k at level l).
    C_d = T^d Σ_i N_i^d over the candidate's requests (Eq. 43 — the decode
    time the batch will contribute; inserting the prefill *now* unlocks it).

    If C_p ≥ C_d: continue decoding and accumulate more waiters (the stage
    overhead isn't amortized yet); else execute the prefill stage.

    Progress refinement: when no further waiters can arrive (pending ≤ idle
    slots — the drain phase of an offline batch), waiting is pointless and
    the candidate is admitted immediately. Without this the rule strands the
    last sub-threshold request until all decodes finish, serializing its
    entire decode onto the makespan.
    """

    name = "lagrangian"

    def decide_prefill(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        if snap.pending_requests <= snap.n_idle:
            return True  # drain phase: no future waiters to amortize with
        batch_tokens = snap.candidate.effective_prefill_tokens
        if batch_tokens >= cost_model.max_level.cap_tokens:
            return True  # batch already fills the largest level
        c_p = cost_model.quantized_prefill_time(batch_tokens)
        c_d = cost_model.decode_per_token * snap.candidate.total_decode_est
        return c_p < c_d


class BalancedLagrangianPolicy(IterationPolicy):
    """Beyond-paper fix of the Lagrangian rule's starvation mode.

    The paper's rule compares C_p (level duration) to C_d (decode work of the
    *candidate batch*). The candidate is capacity-capped at N_L^cap tokens,
    so C_d ≤ T^d · N_L^cap · (N̄_d / N̄_p): for prompt-heavy workloads
    (N_d/N_p below T_L^p / (T^d·N_L^cap) ≈ 0.64 at the paper's constants)
    C_d can NEVER exceed C_p and the system starves — refills only happen
    through the n_active==0 guard, and utilization collapses (measured 39.9%
    vs 64.9% prefill-first on a long-prompt workload; EXPERIMENTS.md
    §Beyond-paper).

    Fix: when the candidate is *capacity-saturated* (more waiters exist than
    the batch can take), waiting cannot grow the batch — fire immediately.
    On decode-heavy workloads (GSM8K) the guard never triggers and behaviour
    is identical to the paper's rule.
    """

    name = "balanced_lagrangian"

    def decide_prefill(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        if snap.pending_requests <= snap.n_idle:
            return True
        cand = snap.candidate
        # capacity saturation: idle clients + pending work exist beyond the
        # batch → the batch cannot grow by waiting
        if snap.n_idle > len(cand.requests) and snap.pending_requests > len(cand.requests):
            return True
        batch_tokens = cand.effective_prefill_tokens
        if batch_tokens >= cost_model.max_level.cap_tokens:
            return True
        c_p = cost_model.quantized_prefill_time(batch_tokens)
        c_d = cost_model.decode_per_token * cand.total_decode_est
        return c_p < c_d


class AmortizedPolicy(IterationPolicy):
    """Beyond-paper: fire at the analytically-optimal batch size k*.

    Deferring a prefill by one decode round wastes k · t_r of idle
    client-time (k waiters idle for the round) but saves stage overhead by
    batching more waiters. With completion rate λ per round, gathering k
    waiters costs ≈ k²·t_r/(2λ) of idle time while merging saves
    (k−1)·T_oh·n_active of stall; balancing marginals gives

        k* = sqrt(2 · λ · n_active · T_oh / t_r)

    (≈9 at the paper's constants vs the Lagrangian's ≈2). Inherits the
    saturation and drain guards.
    """

    name = "amortized"

    def decide_prefill(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        if snap.pending_requests <= snap.n_idle:
            return True
        cand = snap.candidate
        if snap.n_idle > len(cand.requests) and snap.pending_requests > len(cand.requests):
            return True
        if cand.effective_prefill_tokens >= cost_model.max_level.cap_tokens:
            return True
        t_r = cost_model.decode_round_time(max(snap.n_active, 1))
        # completion rate: active clients finishing per round
        mean_remaining = snap.active_remaining_est / max(snap.n_active, 1)
        lam = snap.n_active / max(mean_remaining, 1.0)
        k_star = (2.0 * lam * snap.n_active * cost_model.prefill_overhead / t_r) ** 0.5
        return len(cand.requests) >= max(1.0, k_star)


class UtilizationWeightedPolicy(IterationPolicy):
    """Beyond-paper: weigh stall and idleness by the clients they touch.

    Inserting a prefill of duration C_p stalls the n_active decoders:
    wasted client-time = n_active * C_p. NOT inserting leaves the candidate's
    n_cand clients idle for at least one more decode round t_r, and (if we
    never insert) forfeits C_d of useful decode: waste ≈ n_cand * t_r
    accumulating each round. Prefill when the per-round idle waste exceeds
    the amortized stall:

        n_cand * t_r  ≥  n_active * C_p / max(1, E[rounds between prefills])

    We approximate the amortization horizon by the candidate's mean decode
    length (a batch admitted now keeps its clients busy that long).
    """

    name = "utilization_weighted"

    def decide_prefill(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        cand = snap.candidate
        batch_tokens = cand.effective_prefill_tokens
        if batch_tokens >= cost_model.max_level.cap_tokens:
            return True
        c_p = cost_model.quantized_prefill_time(batch_tokens)
        t_r = cost_model.decode_round_time(snap.n_active)
        n_cand = len(cand.requests)
        mean_decode = cand.total_decode_est / max(1, n_cand)
        horizon_rounds = max(1.0, mean_decode)
        idle_waste_per_round = n_cand * t_r
        stall_amortized = snap.n_active * c_p / horizon_rounds
        return idle_waste_per_round >= stall_amortized


class DynamicBatchPolicy(IterationPolicy):
    """Beyond-paper (paper §VI future work #3): dynamic client count.

    Wraps an inner policy but refuses to admit new requests once the active
    count reaches a dynamically-chosen cap. The cap maximizes decode
    throughput per round: tokens/s = n / (T_oh + T_tok * n) is increasing in
    n, so the cap is only binding when the *tail* is near — then admitting
    more requests prolongs the tail; we cap admission so the last requests
    finish together (see EXPERIMENTS.md §Beyond-paper).
    """

    name = "dynamic_batch"

    def __init__(self, inner: Optional[IterationPolicy] = None):
        self.inner = inner or LagrangianPolicy()
        self.name = f"dynamic_batch({self.inner.name})"

    def decide_prefill(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        # Tail detection: fewer pending requests than idle slots means the
        # run is draining; admit immediately to keep the tail short.
        if snap.pending_requests <= snap.n_idle:
            return True
        return self.inner.decide_prefill(snap, cost_model)

    def prefill_share(
        self,
        snap: SystemSnapshot,
        cost_model: CostModel,
        budget: int,
        explain: Optional[dict] = None,
    ) -> int:
        if snap.pending_requests <= snap.n_idle:
            share = max(budget, 0)         # drain phase: admit immediately
            if explain is not None:
                explain.update(rule="drain_phase", budget=budget, share=share)
            return share
        return self.inner.prefill_share(snap, cost_model, budget, explain)


class TimedPolicy(IterationPolicy):
    """Decorator measuring per-decision wall time (the <5 ms claim)."""

    def __init__(self, inner: IterationPolicy):
        self.inner = inner
        self.name = inner.name
        self.decision_times_ms: List[float] = []

    def __call__(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        t0 = time.perf_counter()
        out = self.inner(snap, cost_model)
        self.decision_times_ms.append((time.perf_counter() - t0) * 1e3)
        return out

    def decide(
        self,
        snap: SystemSnapshot,
        cost_model: CostModel,
        k_max: int = 1,
        mixed_budget: Optional[int] = None,
        explain: Optional[dict] = None,
    ) -> Decision:
        # time the full engine-facing decision: under mixed-step scheduling
        # the binary __call__ path never runs, so without this override a
        # mixed serve would record no decision times at all
        t0 = time.perf_counter()
        out = self.inner.decide(snap, cost_model, k_max, mixed_budget, explain)
        self.decision_times_ms.append((time.perf_counter() - t0) * 1e3)
        return out

    def decide_prefill(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        return self.inner.decide_prefill(snap, cost_model)

    def decode_horizon(
        self, snap: SystemSnapshot, cost_model: CostModel, k_max: int = 1
    ) -> int:
        return self.inner.decode_horizon(snap, cost_model, k_max)

    def prefill_share(
        self,
        snap: SystemSnapshot,
        cost_model: CostModel,
        budget: int,
        explain: Optional[dict] = None,
    ) -> int:
        return self.inner.prefill_share(snap, cost_model, budget, explain)


POLICIES = {
    "prefill_first": PrefillFirstPolicy,
    "decode_first": DecodeFirstPolicy,
    "lagrangian": LagrangianPolicy,
    "balanced_lagrangian": BalancedLagrangianPolicy,
    "amortized": AmortizedPolicy,
    "utilization_weighted": UtilizationWeightedPolicy,
    "dynamic_batch": DynamicBatchPolicy,
}


def make_policy(name: str) -> IterationPolicy:
    if name not in POLICIES:
        raise KeyError(f"unknown policy {name!r}; have {sorted(POLICIES)}")
    return POLICIES[name]()
