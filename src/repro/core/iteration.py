"""Online iteration scheduling — when to preempt decode and insert prefill.

The decision runs at every decode-round boundary (the paper's ~50 ms cadence)
and must return within the real-time budget (<10 ms; measured <5 ms, see
``benchmarks``). Policies:

  * ``PrefillFirstPolicy``   — the vLLM-style baseline: insert a prefill stage
    whenever any client is idle and a request is waiting.
  * ``LagrangianPolicy``     — the paper's rule (Eqs. 41–43): compare the
    marginal makespan cost of a prefill stage, C_p = T_l^p (the *level*
    duration of the candidate batch — levels quantize the decision exactly as
    y_{k,l} does in the MIP), against the waited decode value it unlocks,
    C_d = T^d Σ_{i∈batch} N_i^d. Prefill iff C_p < C_d.
  * Beyond-paper policies (§EXPERIMENTS.md §Beyond-paper):
      - ``UtilizationWeightedPolicy`` — weighs the prefill stall by the number
        of clients it stalls vs the idleness it cures.
      - ``DynamicBatchPolicy`` — the paper's future-work #3: caps concurrent
        clients dynamically from the memory/throughput trade-off.

All policies are pure functions of a small ``SystemSnapshot``, so the same
code runs in the simulator and in the real engine's dispatch loop.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

from .cost_model import CostModel
from .types import Request


@dataclass
class CandidateBatch:
    """Prefill batch the request scheduler proposes for the idle clients.

    ``chunk_tokens`` is set by the chunked-prefill engine: the tokens the
    *next stage* would actually process (one chunk per request), which may be
    far fewer than the batch's full prompts. Policies must price the stage
    they are deciding on, so cost comparisons use
    ``effective_prefill_tokens`` — with whole-prompt prefill the two are
    identical, with chunking the marginal stage is one chunk round (this is
    what lets a Lagrangian-style rule interleave prefill work without
    stalling decode for a whole prompt; HyGen §4)."""

    requests: List[Request]
    client_ids: List[int]
    chunk_tokens: Optional[int] = None

    @property
    def total_prefill_tokens(self) -> int:
        return sum(r.n_prefill for r in self.requests)

    @property
    def effective_prefill_tokens(self) -> int:
        """Tokens the next prefill stage would run: one chunk round when the
        engine chunks, the full prompts otherwise."""
        if self.chunk_tokens is not None:
            return self.chunk_tokens
        return self.total_prefill_tokens

    @property
    def total_decode_est(self) -> int:
        return sum(int(r.n_decode_est or r.n_decode) for r in self.requests)

    def __bool__(self) -> bool:
        return bool(self.requests)


@dataclass
class SystemSnapshot:
    """Everything an iteration policy may look at (cheap scalars only)."""

    n_clients: int
    n_active: int                     # clients currently decoding
    n_idle: int
    active_remaining_est: int         # Σ estimated remaining decode tokens (active)
    pending_requests: int             # requests not yet prefilled (global)
    candidate: CandidateBatch         # what a prefill stage would run *now*
    now: float                        # current sim/wall time (seconds)


@dataclass(frozen=True)
class Decision:
    """One iteration-level scheduling decision.

    ``prefill`` chooses the stage kind (the paper's binary choice);
    ``horizon`` is how many decode iterations to commit to one fused
    on-device dispatch when ``prefill`` is False. Horizon 1 reproduces the
    per-token baseline (one host sync per decoded token)."""

    prefill: bool
    horizon: int = 1


class IterationPolicy:
    name = "base"

    def decide_prefill(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        """True → insert a prefill stage now; False → run a decode round."""
        raise NotImplementedError

    def decode_horizon(
        self, snap: SystemSnapshot, cost_model: CostModel, k_max: int = 1
    ) -> int:
        """Decode iterations to fuse into the next dispatch (1 ≤ K ≤ k_max).

        Default: the Lagrangian-style marginal rule shared by every policy.
        Fusing one more iteration saves amortized dispatch cost
        d/dK [C_dispatch·(1−1/K)] = C_dispatch/K², but commits the engine one
        round longer before it can reconsider — if prefill-ready work exists
        (or can appear when a slot frees mid-horizon), that delay costs an
        expected  w·t_round/2  of stalled prefill, with w the admission
        pressure (pending work per client slot). Equating the marginals
        prices the horizon in closed form:

            K* = sqrt(2·C_dispatch / (w·t_round))

        With no pending work there is nothing to preempt for (w→0, K*→∞) and
        the horizon saturates at ``k_max``; under heavy admission pressure
        K*→1 recovers the paper's per-iteration granularity.
        """
        if k_max <= 1:
            return 1
        # Prefill-ready work = queued requests OR an already-materialized
        # candidate (e.g. a long prompt's remaining chunks after the queue
        # drained) — either one makes delaying the next decision costly.
        waiters = max(snap.pending_requests, len(snap.candidate.requests))
        if waiters <= 0:
            return k_max
        w = min(1.0, waiters / max(snap.n_clients, 1))
        t_round = cost_model.decode_round_time(max(snap.n_active, 1))
        if t_round <= 0 or cost_model.decode_dispatch <= 0:
            return 1
        k_star = (2.0 * cost_model.decode_dispatch / (w * t_round)) ** 0.5
        return max(1, min(k_max, int(k_star)))

    def decide(
        self, snap: SystemSnapshot, cost_model: CostModel, k_max: int = 1
    ) -> Decision:
        """Stage choice plus the decode horizon to run if decoding."""
        if self(snap, cost_model):
            return Decision(prefill=True)
        return Decision(
            prefill=False, horizon=self.decode_horizon(snap, cost_model, k_max)
        )

    def __call__(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        # Progress guarantees, shared by all policies:
        if not snap.candidate:
            return False                      # nothing to prefill
        if snap.n_active == 0:
            return True                       # nothing to decode — must prefill
        return self.decide_prefill(snap, cost_model)


class PrefillFirstPolicy(IterationPolicy):
    """Baseline: prefill whenever possible (FCFS prefill-first, §I)."""

    name = "prefill_first"

    def decide_prefill(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        return True


class DecodeFirstPolicy(IterationPolicy):
    """Anti-baseline for ablations: only prefill when forced."""

    name = "decode_first"

    def decide_prefill(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        return False


class LagrangianPolicy(IterationPolicy):
    """The paper's heuristic (Eqs. 41–43).

    C_p = T_l^p for the smallest level fitting the candidate batch (Eq. 42 —
    the marginal makespan cost of opening prefill stage k at level l).
    C_d = T^d Σ_i N_i^d over the candidate's requests (Eq. 43 — the decode
    time the batch will contribute; inserting the prefill *now* unlocks it).

    If C_p ≥ C_d: continue decoding and accumulate more waiters (the stage
    overhead isn't amortized yet); else execute the prefill stage.

    Progress refinement: when no further waiters can arrive (pending ≤ idle
    slots — the drain phase of an offline batch), waiting is pointless and
    the candidate is admitted immediately. Without this the rule strands the
    last sub-threshold request until all decodes finish, serializing its
    entire decode onto the makespan.
    """

    name = "lagrangian"

    def decide_prefill(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        if snap.pending_requests <= snap.n_idle:
            return True  # drain phase: no future waiters to amortize with
        batch_tokens = snap.candidate.effective_prefill_tokens
        if batch_tokens >= cost_model.max_level.cap_tokens:
            return True  # batch already fills the largest level
        c_p = cost_model.quantized_prefill_time(batch_tokens)
        c_d = cost_model.decode_per_token * snap.candidate.total_decode_est
        return c_p < c_d


class BalancedLagrangianPolicy(IterationPolicy):
    """Beyond-paper fix of the Lagrangian rule's starvation mode.

    The paper's rule compares C_p (level duration) to C_d (decode work of the
    *candidate batch*). The candidate is capacity-capped at N_L^cap tokens,
    so C_d ≤ T^d · N_L^cap · (N̄_d / N̄_p): for prompt-heavy workloads
    (N_d/N_p below T_L^p / (T^d·N_L^cap) ≈ 0.64 at the paper's constants)
    C_d can NEVER exceed C_p and the system starves — refills only happen
    through the n_active==0 guard, and utilization collapses (measured 39.9%
    vs 64.9% prefill-first on a long-prompt workload; EXPERIMENTS.md
    §Beyond-paper).

    Fix: when the candidate is *capacity-saturated* (more waiters exist than
    the batch can take), waiting cannot grow the batch — fire immediately.
    On decode-heavy workloads (GSM8K) the guard never triggers and behaviour
    is identical to the paper's rule.
    """

    name = "balanced_lagrangian"

    def decide_prefill(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        if snap.pending_requests <= snap.n_idle:
            return True
        cand = snap.candidate
        # capacity saturation: idle clients + pending work exist beyond the
        # batch → the batch cannot grow by waiting
        if snap.n_idle > len(cand.requests) and snap.pending_requests > len(cand.requests):
            return True
        batch_tokens = cand.effective_prefill_tokens
        if batch_tokens >= cost_model.max_level.cap_tokens:
            return True
        c_p = cost_model.quantized_prefill_time(batch_tokens)
        c_d = cost_model.decode_per_token * cand.total_decode_est
        return c_p < c_d


class AmortizedPolicy(IterationPolicy):
    """Beyond-paper: fire at the analytically-optimal batch size k*.

    Deferring a prefill by one decode round wastes k · t_r of idle
    client-time (k waiters idle for the round) but saves stage overhead by
    batching more waiters. With completion rate λ per round, gathering k
    waiters costs ≈ k²·t_r/(2λ) of idle time while merging saves
    (k−1)·T_oh·n_active of stall; balancing marginals gives

        k* = sqrt(2 · λ · n_active · T_oh / t_r)

    (≈9 at the paper's constants vs the Lagrangian's ≈2). Inherits the
    saturation and drain guards.
    """

    name = "amortized"

    def decide_prefill(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        if snap.pending_requests <= snap.n_idle:
            return True
        cand = snap.candidate
        if snap.n_idle > len(cand.requests) and snap.pending_requests > len(cand.requests):
            return True
        if cand.effective_prefill_tokens >= cost_model.max_level.cap_tokens:
            return True
        t_r = cost_model.decode_round_time(max(snap.n_active, 1))
        # completion rate: active clients finishing per round
        mean_remaining = snap.active_remaining_est / max(snap.n_active, 1)
        lam = snap.n_active / max(mean_remaining, 1.0)
        k_star = (2.0 * lam * snap.n_active * cost_model.prefill_overhead / t_r) ** 0.5
        return len(cand.requests) >= max(1.0, k_star)


class UtilizationWeightedPolicy(IterationPolicy):
    """Beyond-paper: weigh stall and idleness by the clients they touch.

    Inserting a prefill of duration C_p stalls the n_active decoders:
    wasted client-time = n_active * C_p. NOT inserting leaves the candidate's
    n_cand clients idle for at least one more decode round t_r, and (if we
    never insert) forfeits C_d of useful decode: waste ≈ n_cand * t_r
    accumulating each round. Prefill when the per-round idle waste exceeds
    the amortized stall:

        n_cand * t_r  ≥  n_active * C_p / max(1, E[rounds between prefills])

    We approximate the amortization horizon by the candidate's mean decode
    length (a batch admitted now keeps its clients busy that long).
    """

    name = "utilization_weighted"

    def decide_prefill(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        cand = snap.candidate
        batch_tokens = cand.effective_prefill_tokens
        if batch_tokens >= cost_model.max_level.cap_tokens:
            return True
        c_p = cost_model.quantized_prefill_time(batch_tokens)
        t_r = cost_model.decode_round_time(snap.n_active)
        n_cand = len(cand.requests)
        mean_decode = cand.total_decode_est / max(1, n_cand)
        horizon_rounds = max(1.0, mean_decode)
        idle_waste_per_round = n_cand * t_r
        stall_amortized = snap.n_active * c_p / horizon_rounds
        return idle_waste_per_round >= stall_amortized


class DynamicBatchPolicy(IterationPolicy):
    """Beyond-paper (paper §VI future work #3): dynamic client count.

    Wraps an inner policy but refuses to admit new requests once the active
    count reaches a dynamically-chosen cap. The cap maximizes decode
    throughput per round: tokens/s = n / (T_oh + T_tok * n) is increasing in
    n, so the cap is only binding when the *tail* is near — then admitting
    more requests prolongs the tail; we cap admission so the last requests
    finish together (see EXPERIMENTS.md §Beyond-paper).
    """

    name = "dynamic_batch"

    def __init__(self, inner: Optional[IterationPolicy] = None):
        self.inner = inner or LagrangianPolicy()
        self.name = f"dynamic_batch({self.inner.name})"

    def decide_prefill(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        # Tail detection: fewer pending requests than idle slots means the
        # run is draining; admit immediately to keep the tail short.
        if snap.pending_requests <= snap.n_idle:
            return True
        return self.inner.decide_prefill(snap, cost_model)


class TimedPolicy(IterationPolicy):
    """Decorator measuring per-decision wall time (the <5 ms claim)."""

    def __init__(self, inner: IterationPolicy):
        self.inner = inner
        self.name = inner.name
        self.decision_times_ms: List[float] = []

    def __call__(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        t0 = time.perf_counter()
        out = self.inner(snap, cost_model)
        self.decision_times_ms.append((time.perf_counter() - t0) * 1e3)
        return out

    def decide_prefill(self, snap: SystemSnapshot, cost_model: CostModel) -> bool:
        return self.inner.decide_prefill(snap, cost_model)


POLICIES = {
    "prefill_first": PrefillFirstPolicy,
    "decode_first": DecodeFirstPolicy,
    "lagrangian": LagrangianPolicy,
    "balanced_lagrangian": BalancedLagrangianPolicy,
    "amortized": AmortizedPolicy,
    "utilization_weighted": UtilizationWeightedPolicy,
    "dynamic_batch": DynamicBatchPolicy,
}


def make_policy(name: str) -> IterationPolicy:
    if name not in POLICIES:
        raise KeyError(f"unknown policy {name!r}; have {sorted(POLICIES)}")
    return POLICIES[name]()
