"""The original MIP model (Eqs. 1–25) — deterministic equivalence of the
inference-scheduling problem, solved at toy scale with scipy's HiGHS.

The paper formulates the full problem but reports it unsolvable at scale
(100 requests × 20 clients ≈ 1 h in Gurobi without closing the gap); the
hybrid method exists precisely because of this. We build the model anyway:

  * it documents the formulation as executable code,
  * toy instances validate the hybrid heuristic's optimality gap
    (``benchmarks`` §mip_toy), and
  * the LP relaxation provides an instance-specific dual bound.

Interpretation notes (see DESIGN.md §2):
  * T^d in Eq. (8) is the *round* time: every decode round serves all active
    clients and costs ``decode_round_time(J)``; a request's decode work in
    rounds equals its token count. We therefore measure decode in rounds and
    multiply by the full-batch round duration.
  * The paper omits the coupling w_{ijk} ≤ d_{ijk} (a proportion can only be
    executed in a stage assigned to that request); we add it — without it the
    model can place decode work in unassigned stages.
  * Eq. (7) forces every bin to select a level, so a K larger than the
    optimal bin count inflates t_max by the unused bins' level durations. We
    prepend an *empty level* (capacity 0, duration 0) so unused bins are
    free; this makes the objective monotone non-increasing in K, as intended.

Variable layout (column offsets into one flat vector):
  x   : I*J                binary   request→client assignment
  p   : I*J*K              binary   prefill stage assignment
  d   : I*J*K              binary   decode stage assignment
  w   : I*J*K              [0,1]    decode proportion
  y   : K*L                binary   prefill level indicator
  tsp : K                  R+       prefill stage start
  tsd : K                  R+       decode stage start
  np  : K                  R+       prefill stage length
  nd  : K                  R+       decode stage length
  tmax: 1                  R+
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cost_model import CostModel
from .types import Request


@dataclass
class MIPSolution:
    status: str
    objective: float            # t_max (seconds)
    mip_gap: float
    solve_seconds: float
    x: np.ndarray               # (I, J)
    p: np.ndarray               # (I, J, K)
    d: np.ndarray               # (I, J, K)
    w: np.ndarray               # (I, J, K)
    y: np.ndarray               # (K, L)
    stage_times: Dict[str, np.ndarray]  # tsp, tsd, np, nd


class OriginalMIP:
    """Builder/solver for Eqs. (1)–(25) on a concrete instance."""

    def __init__(
        self,
        requests: Sequence[Request],
        n_clients: int,
        n_bins: int,
        cost_model: CostModel,
        big_m: Optional[float] = None,
    ):
        self.requests = list(requests)
        self.I = len(self.requests)
        self.J = n_clients
        self.K = n_bins
        self.cm = cost_model
        # Level 0 is the explicit empty level (see docstring).
        from .cost_model import PrefillLevel

        self.levels = [PrefillLevel(index=0, cap_tokens=0, duration_s=0.0)] + [
            PrefillLevel(index=lv.index + 1, cap_tokens=lv.cap_tokens, duration_s=lv.duration_s)
            for lv in cost_model.levels
        ]
        self.L = len(self.levels)
        # Decode measured in rounds × full-batch round time (see docstring).
        self.td_round = cost_model.decode_round_time(n_clients)
        self.big_m = big_m if big_m is not None else float(self.K + 1)

        I, J, K, L = self.I, self.J, self.K, self.L
        self.off_x = 0
        self.off_p = self.off_x + I * J
        self.off_d = self.off_p + I * J * K
        self.off_w = self.off_d + I * J * K
        self.off_y = self.off_w + I * J * K
        self.off_tsp = self.off_y + K * L
        self.off_tsd = self.off_tsp + K
        self.off_np = self.off_tsd + K
        self.off_nd = self.off_np + K
        self.off_tmax = self.off_nd + K
        self.n_var = self.off_tmax + 1

    # -- index helpers ---------------------------------------------------- #
    def ix(self, i: int, j: int) -> int:
        return self.off_x + i * self.J + j

    def ip(self, i: int, j: int, k: int) -> int:
        return self.off_p + (i * self.J + j) * self.K + k

    def idd(self, i: int, j: int, k: int) -> int:
        return self.off_d + (i * self.J + j) * self.K + k

    def iw(self, i: int, j: int, k: int) -> int:
        return self.off_w + (i * self.J + j) * self.K + k

    def iy(self, k: int, l: int) -> int:
        return self.off_y + k * self.L + l

    # -- model ------------------------------------------------------------ #
    def build(self):
        import scipy.sparse as sp
        from scipy.optimize import Bounds, LinearConstraint

        I, J, K, L = self.I, self.J, self.K, self.L
        M = self.big_m
        rows_ub: List[Tuple[List[int], List[float], float]] = []  # (cols, vals, ub)
        rows_eq: List[Tuple[List[int], List[float], float]] = []

        def ub_row(cols, vals, ub):
            rows_ub.append((cols, vals, float(ub)))

        def eq_row(cols, vals, rhs):
            rows_eq.append((cols, vals, float(rhs)))

        # (2) tsd_k + nd_k - tmax <= 0
        for k in range(K):
            ub_row([self.off_tsd + k, self.off_nd + k, self.off_tmax], [1, 1, -1], 0)
        # (3) tsd_{k-1} + nd_{k-1} - tsp_k <= 0
        for k in range(1, K):
            ub_row(
                [self.off_tsd + k - 1, self.off_nd + k - 1, self.off_tsp + k],
                [1, 1, -1],
                0,
            )
        # (4) tsp_k + np_k - tsd_k <= 0
        for k in range(K):
            ub_row([self.off_tsp + k, self.off_np + k, self.off_tsd + k], [1, 1, -1], 0)
        # (5) Σ_l T_l^p y_kl - np_k <= 0
        for k in range(K):
            cols = [self.iy(k, l) for l in range(L)] + [self.off_np + k]
            vals = [lv.duration_s for lv in self.levels] + [-1.0]
            ub_row(cols, vals, 0)
        # (6) Σ_ij N_i^p p_ijk - Σ_l N_l^cap y_kl <= 0
        for k in range(K):
            cols, vals = [], []
            for i in range(I):
                for j in range(J):
                    cols.append(self.ip(i, j, k))
                    vals.append(float(self.requests[i].n_prefill))
            for l in range(L):
                cols.append(self.iy(k, l))
                vals.append(-float(self.levels[l].cap_tokens))
            ub_row(cols, vals, 0)
        # (7) Σ_l y_kl = 1
        for k in range(K):
            eq_row([self.iy(k, l) for l in range(L)], [1.0] * L, 1)
        # (8) T^d Σ_i N_i^d w_ijk - nd_k <= 0   ∀ j,k   (T^d = round time)
        for k in range(K):
            for j in range(J):
                cols = [self.iw(i, j, k) for i in range(I)] + [self.off_nd + k]
                vals = [
                    self.td_round * float(self.requests[i].n_decode_est or self.requests[i].n_decode)
                    for i in range(I)
                ] + [-1.0]
                ub_row(cols, vals, 0)
        # (9) p_ijk - d_ijk <= 0
        for i in range(I):
            for j in range(J):
                for k in range(K):
                    ub_row([self.ip(i, j, k), self.idd(i, j, k)], [1, -1], 0)
        # (10) contiguity: for k1<k2:
        #   (k2-k1+1) - M(2 - d_ijk1 - d_ijk2) - Σ_{k1..k2} d <= 0
        #   → -M d1 - M d2 - Σ d <= -(k2-k1+1) - 2M  ... rearranged:
        #   M d_ijk1 + M d_ijk2 - Σ_{k'=k1}^{k2} d_ijk' <= 2M - (k2-k1+1)
        for i in range(I):
            for j in range(J):
                for k1 in range(K):
                    for k2 in range(k1 + 1, K):
                        cols = [self.idd(i, j, k1), self.idd(i, j, k2)]
                        vals = [M, M]
                        for kk in range(k1, k2 + 1):
                            cols.append(self.idd(i, j, kk))
                            vals.append(-1.0)
                        ub_row(cols, vals, 2 * M - (k2 - k1 + 1))
        # (11) no decode before prefill: d_ijk2 <= M(1 - p_ijk1) for k1 > k2
        for i in range(I):
            for j in range(J):
                for k1 in range(K):
                    for k2 in range(k1):
                        ub_row([self.idd(i, j, k2), self.ip(i, j, k1)], [1, M], M)
        # (12) Σ_i d_ijk <= 1
        for j in range(J):
            for k in range(K):
                ub_row([self.idd(i, j, k) for i in range(I)], [1.0] * I, 1)
        # (14) Σ_k w_ijk = x_ij
        for i in range(I):
            for j in range(J):
                cols = [self.iw(i, j, k) for k in range(K)] + [self.ix(i, j)]
                eq_row(cols, [1.0] * K + [-1.0], 0)
        # (15) Σ_jk w_ijk = 1
        for i in range(I):
            cols = [self.iw(i, j, k) for j in range(J) for k in range(K)]
            eq_row(cols, [1.0] * (J * K), 1)
        # (16) Σ_i p_ijk <= 1
        for j in range(J):
            for k in range(K):
                ub_row([self.ip(i, j, k) for i in range(I)], [1.0] * I, 1)
        # (17) Σ_k p_ijk = x_ij
        for i in range(I):
            for j in range(J):
                cols = [self.ip(i, j, k) for k in range(K)] + [self.ix(i, j)]
                eq_row(cols, [1.0] * K + [-1.0], 0)
        # (18) Σ_j x_ij = 1
        for i in range(I):
            eq_row([self.ix(i, j) for j in range(J)], [1.0] * J, 1)
        # (added) w_ijk <= d_ijk
        for i in range(I):
            for j in range(J):
                for k in range(K):
                    ub_row([self.iw(i, j, k), self.idd(i, j, k)], [1, -1], 0)

        def to_csr(rows):
            r, c, v, rhs = [], [], [], []
            for ri, (cols, vals, b) in enumerate(rows):
                for cc, vv in zip(cols, vals):
                    r.append(ri)
                    c.append(cc)
                    v.append(vv)
                rhs.append(b)
            mat = sp.csr_matrix((v, (r, c)), shape=(len(rows), self.n_var))
            return mat, np.asarray(rhs)

        a_ub, b_ub = to_csr(rows_ub)
        a_eq, b_eq = to_csr(rows_eq)
        constraints = [
            LinearConstraint(a_ub, ub=b_ub),
            LinearConstraint(a_eq, lb=b_eq, ub=b_eq),
        ]
        integrality = np.zeros(self.n_var)
        for off, size in [
            (self.off_x, I * J),
            (self.off_p, I * J * K),
            (self.off_d, I * J * K),
            (self.off_y, K * L),
        ]:
            integrality[off : off + size] = 1
        lb = np.zeros(self.n_var)
        ub = np.full(self.n_var, np.inf)
        ub[: self.off_y + K * L] = 1.0  # x, p, d, w, y are all in [0, 1]
        bounds = Bounds(lb=lb, ub=ub)
        c = np.zeros(self.n_var)
        c[self.off_tmax] = 1.0
        return c, constraints, integrality, bounds

    def solve(self, time_limit_s: float = 120.0, relax: bool = False) -> MIPSolution:
        from scipy.optimize import milp

        c, constraints, integrality, bounds = self.build()
        if relax:
            integrality = np.zeros_like(integrality)
        t0 = time.perf_counter()
        res = milp(
            c=c,
            constraints=constraints,
            integrality=integrality,
            bounds=bounds,
            options={"time_limit": time_limit_s, "presolve": True},
        )
        dt = time.perf_counter() - t0
        I, J, K, L = self.I, self.J, self.K, self.L
        if res.x is None:
            return MIPSolution(
                status=f"failed({res.status})",
                objective=float("nan"),
                mip_gap=float("nan"),
                solve_seconds=dt,
                x=np.zeros((I, J)),
                p=np.zeros((I, J, K)),
                d=np.zeros((I, J, K)),
                w=np.zeros((I, J, K)),
                y=np.zeros((K, L)),
                stage_times={},
            )
        xv = np.asarray(res.x)
        sol = MIPSolution(
            status="optimal" if res.status == 0 else f"status{res.status}",
            objective=float(res.fun),
            mip_gap=float(getattr(res, "mip_gap", 0.0) or 0.0),
            solve_seconds=dt,
            x=xv[self.off_x : self.off_p].reshape(I, J).round(6),
            p=xv[self.off_p : self.off_d].reshape(I, J, K).round(6),
            d=xv[self.off_d : self.off_w].reshape(I, J, K).round(6),
            w=xv[self.off_w : self.off_y].reshape(I, J, K).round(6),
            y=xv[self.off_y : self.off_tsp].reshape(K, L).round(6),
            stage_times={
                "tsp": xv[self.off_tsp : self.off_tsd],
                "tsd": xv[self.off_tsd : self.off_np],
                "np": xv[self.off_np : self.off_nd],
                "nd": xv[self.off_nd : self.off_tmax],
            },
        )
        return sol

    # -- validation ------------------------------------------------------- #
    def check_solution(self, sol: MIPSolution, atol: float = 1e-6) -> None:
        """Structural feasibility of an integral solution (used by tests)."""
        assert np.allclose(sol.x.sum(axis=1), 1, atol=atol), "Eq.(18) violated"
        assert np.allclose(sol.p.sum(axis=(1, 2)), 1, atol=atol), "Eq.(17+18)"
        assert np.allclose(sol.w.sum(axis=(1, 2)), 1, atol=atol), "Eq.(15)"
        for k in range(self.K):
            cap = float(np.dot(sol.y[k], [lv.cap_tokens for lv in self.levels]))
            used = sum(
                self.requests[i].n_prefill * sol.p[i, j, k]
                for i in range(self.I)
                for j in range(self.J)
            )
            assert used <= cap + atol, f"Eq.(6) violated at bin {k}"
        assert np.all(sol.w <= sol.d + atol), "w <= d coupling violated"
        assert np.all(sol.p.sum(axis=0) <= 1 + atol), "Eq.(16) violated"
        assert np.all(sol.d.sum(axis=0) <= 1 + atol), "Eq.(12) violated"


def recost_trace_mip_semantics(trace, cost_model: CostModel, n_clients: int) -> float:
    """Re-price a simulated trace under the MIP's planning semantics:
    prefill stages cost their quantized level duration; every decode round
    costs the full-batch round time. Under these semantics a heuristic
    schedule is directly comparable to (and can never beat) the MIP optimum
    on the same instance."""
    from .types import StageKind

    total = 0.0
    for s in trace.stages:
        if s.kind is StageKind.PREFILL:
            total += cost_model.quantized_prefill_time(
                min(s.tokens, cost_model.max_level.cap_tokens)
            )
        else:
            total += cost_model.decode_round_time(n_clients) * max(1, s.rounds)
    return total


def toy_instance(
    n_requests: int = 6,
    n_clients: int = 2,
    n_bins: int = 4,
    seed: int = 0,
    cost_model: Optional[CostModel] = None,
) -> Tuple[List[Request], int, int, CostModel]:
    """Small instance for MIP validation (decode overheads zeroed so the MIP
    round-time semantics and the simulator agree exactly at full batch)."""
    rng = np.random.default_rng(seed)
    cm = cost_model or CostModel(
        decode_overhead=0.0,
        prefill_overhead=10e-3,
        level_caps=(64, 128, 256),
    )
    reqs = [
        Request(
            rid=i,
            n_prefill=int(rng.integers(8, 33)),
            n_decode=int(rng.integers(4, 17)),
        )
        for i in range(n_requests)
    ]
    return reqs, n_clients, n_bins, cm
