"""Synthetic workload generation matching the paper's Table III statistics.

GSM8K inputs:  N_i^p ~ N(68.43, 25.04²), clipped to [1, ∞)
LLaMA-65B out: N_i^d ~ N(344.83, 187.99²), clipped to [1, 512]

The scheduler plans with *estimates* of the decode length; we model the
estimate as the distribution mean (what an offline profiler would predict)
unless ``estimate_noise_std`` injects a per-request estimator.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from ..core.types import Request


@dataclass(frozen=True)
class WorkloadSpec:
    """Workload distribution spec.

    ``output_mean/std`` are the *post-cap* sample moments the paper reports
    (their outputs were generated with max_output_length=512, so the
    published moments already include the cap). ``output_mu0/sigma0`` are the
    pre-clip normal parameters calibrated so that clip(N(mu0, sigma0), 1,
    512) reproduces those moments exactly (solved numerically; ~40% of
    outputs hit the cap, which is what a hard cap at 0.9 sigma above the
    mean implies).
    """

    n_requests: int = 1319
    input_mean: float = 68.43
    input_std: float = 25.04
    output_mean: float = 344.83
    output_std: float = 187.99
    output_max: int = 512
    input_max: Optional[int] = None
    output_mu0: float = 423.508
    output_sigma0: float = 340.894


PAPER_WORKLOAD_SPEC = WorkloadSpec()

# Output-length predictor error (std, tokens) used for the offline planner's
# T_i estimates. The paper does not publish its predictor; σ=40 is calibrated
# once so the *offline* configuration reproduces the paper's Fig. 7 result,
# and the online/hybrid numbers then fall out untuned (see EXPERIMENTS.md).
PAPER_PREDICTOR_NOISE_STD = 40.0


def gsm8k_like_workload(
    spec: WorkloadSpec = PAPER_WORKLOAD_SPEC,
    seed: int = 0,
    known_lengths: bool = False,
    estimate_noise_std: float = 0.0,
    ttft_slo_s: Optional[float] = None,
    tbt_slo_s: Optional[float] = None,
) -> List[Request]:
    """Draw a request set from the paper's published moments.

    ``known_lengths=True`` gives the scheduler oracle decode lengths (used to
    isolate the value of uncertainty); default plans with the mean.
    ``ttft_slo_s``/``tbt_slo_s`` stamp every request with a latency deadline
    (``ScheduleTrace`` then reports goodput and SLO attainment next to
    throughput); the default leaves the workload deadline-free.
    """
    rng = np.random.default_rng(seed)
    p = rng.normal(spec.input_mean, spec.input_std, size=spec.n_requests)
    p = np.clip(np.round(p), 1, spec.input_max or np.inf).astype(int)
    d = rng.normal(spec.output_mu0, spec.output_sigma0, size=spec.n_requests)
    d = np.clip(np.round(d), 1, spec.output_max).astype(int)

    requests = []
    for i in range(spec.n_requests):
        if known_lengths:
            est = int(d[i])
        elif estimate_noise_std > 0:
            est = int(
                np.clip(
                    round(d[i] + rng.normal(0, estimate_noise_std)),
                    1,
                    spec.output_max,
                )
            )
        else:
            est = int(round(spec.output_mean))
        requests.append(
            Request(
                rid=i, n_prefill=int(p[i]), n_decode=int(d[i]),
                n_decode_est=est, ttft_slo_s=ttft_slo_s, tbt_slo_s=tbt_slo_s,
            )
        )
    return requests


def shared_prefix_workload(
    spec: WorkloadSpec = PAPER_WORKLOAD_SPEC,
    seed: int = 0,
    n_groups: int = 4,
    prefix_mean: float = 48.0,
    prefix_std: float = 12.0,
    zipf_a: float = 1.5,
    known_lengths: bool = False,
) -> List[Request]:
    """GSM8K-shaped requests whose prompts share per-group prefixes — the
    system-prompt / few-shot-template workload prefix caching exists for.

    Each request joins one of ``n_groups`` prefix groups, Zipf-skewed
    (``zipf_a``) so a few hot templates dominate — the regime where a
    content-addressed prefix cache pays. Group ``g`` owns a prefix of
    ``clip(N(prefix_mean, prefix_std²))`` tokens (drawn once per group);
    every member's prompt opens with it, and ``n_prefill`` is stretched to
    at least prefix + 1 so at least one token is always unique per request.
    The engine derives the actual token content from ``(prefix_group,
    prefix_len, rid)`` alone, so the sharing survives migration/restore."""
    rng = np.random.default_rng(seed)
    requests = gsm8k_like_workload(spec, seed=seed, known_lengths=known_lengths)
    plens = np.clip(
        np.round(rng.normal(prefix_mean, prefix_std, size=n_groups)), 8, None
    ).astype(int)
    # Zipf over group ranks, folded into [0, n_groups)
    groups = (rng.zipf(zipf_a, size=len(requests)) - 1) % n_groups
    for r, g in zip(requests, groups):
        r.prefix_group = int(g)
        r.prefix_len = int(plens[g])
        if r.n_prefill <= r.prefix_len:
            r.n_prefill = r.prefix_len + 1
    return requests


def attach_slos(
    requests: List[Request],
    ttft_slo_s: Optional[float] = None,
    tbt_slo_s: Optional[float] = None,
    online_only: bool = True,
) -> List[Request]:
    """Stamp latency SLOs onto an existing request set, in place.

    ``online_only=True`` (default) deadlines only requests with a positive
    arrival time — the offline backlog keeps ``None`` so overload policies
    can defer it freely (deadline-free work is the degradation budget).
    Returns the same list for chaining.
    """
    for r in requests:
        if online_only and r.arrival <= 0:
            continue
        if ttft_slo_s is not None:
            r.ttft_slo_s = ttft_slo_s
        if tbt_slo_s is not None:
            r.tbt_slo_s = tbt_slo_s
    return requests
