from .workload import (
    WorkloadSpec,
    attach_slos,
    gsm8k_like_workload,
    shared_prefix_workload,
    PAPER_WORKLOAD_SPEC,
    PAPER_PREDICTOR_NOISE_STD,
)
