"""Checkpointing — atomic step directories, mesh-shape-independent restore.

Layout:
    <dir>/step_0000100/
        manifest.json        tree structure + leaf metadata + user metadata
        arrays.npz           all leaves, flattened with path-derived keys
    <dir>/step_0000100.COMPLETE   (commit marker — written last)

Properties needed at fleet scale:
  * **atomic**: a crash mid-write never corrupts the latest checkpoint — the
    COMPLETE marker is written only after fsync of the payload; restore only
    considers marked steps.
  * **elastic**: arrays are stored unsharded (gathered); restore re-shards
    onto whatever mesh/sharding the caller provides, so a 512-chip job can
    restart on 256 chips (see distributed.elastic + tests).
  * **self-describing**: the manifest stores dtype/shape per leaf and a user
    metadata dict (step, scheduler state, RNG, workload cursor).

For multi-host deployment each host would write its address-space shard
(process-local npz) — single-process here, noted in DESIGN.md.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

Tree = Any
_SEP = "/"


def _flatten_with_paths(tree: Tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx") else str(p)
            for p in path
        )
        out.append((key, leaf))
    return out


def save_checkpoint(
    directory: str | Path,
    step: int,
    tree: Tree,
    metadata: Optional[Dict[str, Any]] = None,
    keep: int = 3,
) -> Path:
    """Atomically write ``tree`` (params/opt/engine state) at ``step``."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    step_name = f"step_{step:08d}"
    final = directory / step_name
    marker = directory / f"{step_name}.COMPLETE"
    tmp = Path(tempfile.mkdtemp(prefix=f".{step_name}.", dir=directory))
    try:
        leaves = _flatten_with_paths(tree)
        # npz has no bfloat16: store such leaves as a uint16 bit-view and
        # record the logical dtype in the manifest for exact restore.
        arrays = {}
        for k, v in leaves:
            a = np.asarray(v)
            if a.dtype.name == "bfloat16":
                a = a.view(np.uint16)
            arrays[k] = a
        np.savez(tmp / "arrays.npz", **arrays)
        manifest = {
            "step": step,
            "keys": [k for k, _ in leaves],
            "leaf_meta": {
                k: {"shape": list(np.shape(v)), "dtype": str(np.asarray(v).dtype)}
                for k, v in leaves
            },
            "metadata": metadata or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        # fsync payload before commit
        for f in tmp.iterdir():
            fd = os.open(f, os.O_RDONLY)
            os.fsync(fd)
            os.close(fd)
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        marker.touch()
        fd = os.open(directory, os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
    finally:
        if tmp.exists():
            shutil.rmtree(tmp, ignore_errors=True)
    _prune(directory, keep)
    return final


def _prune(directory: Path, keep: int) -> None:
    steps = sorted(latest_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        name = f"step_{s:08d}"
        (directory / f"{name}.COMPLETE").unlink(missing_ok=True)
        shutil.rmtree(directory / name, ignore_errors=True)


def latest_steps(directory: str | Path) -> List[int]:
    directory = Path(directory)
    out = []
    for marker in directory.glob("step_*.COMPLETE"):
        name = marker.name[: -len(".COMPLETE")]
        if (directory / name).is_dir():
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(directory: str | Path) -> Optional[int]:
    steps = latest_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(
    directory: str | Path,
    step: Optional[int] = None,
    target: Optional[Tree] = None,
    shardings: Optional[Tree] = None,
) -> Tuple[Tree, Dict[str, Any]]:
    """Restore a checkpoint.

    ``target``: a tree of the same structure (arrays or ShapeDtypeStructs);
    required to rebuild the pytree. ``shardings``: optional matching tree of
    NamedShardings — leaves are placed with jax.device_put onto them (this is
    the elastic-restore path: the mesh may differ from the writer's).
    Returns (tree, metadata).
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoints under {directory}")
    final = directory / f"step_{step:08d}"
    manifest = json.loads((final / "manifest.json").read_text())
    arrays = np.load(final / "arrays.npz")
    by_key = {k: arrays[k] for k in manifest["keys"]}
    if target is None:
        return by_key, manifest["metadata"]
    flat = _flatten_with_paths(target)
    leaves = []
    shard_flat = None
    if shardings is not None:
        shard_flat = [s for _, s in _flatten_with_paths(shardings)]
    import ml_dtypes  # ships with jax

    for i, (key, tgt) in enumerate(flat):
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = by_key[key]
        stored_dtype = manifest["leaf_meta"][key]["dtype"]
        if stored_dtype == "bfloat16" and arr.dtype == np.uint16:
            arr = arr.view(ml_dtypes.bfloat16)
        want_dtype = tgt.dtype if hasattr(tgt, "dtype") else arr.dtype
        if str(arr.dtype) != str(want_dtype):
            arr = np.asarray(
                arr.astype(np.float32)
            ).astype(ml_dtypes.bfloat16 if str(want_dtype) == "bfloat16" else want_dtype)
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(target)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["metadata"]


class CheckpointManager:
    """Save-every-N policy + resume helper used by the train loop/engine."""

    def __init__(self, directory: str | Path, save_every: int = 100, keep: int = 3):
        self.directory = Path(directory)
        self.save_every = save_every
        self.keep = keep

    def maybe_save(self, step: int, tree: Tree, metadata: Optional[dict] = None):
        if step % self.save_every == 0:
            return save_checkpoint(self.directory, step, tree, metadata, self.keep)
        return None

    def resume(self, target: Tree, shardings: Optional[Tree] = None):
        step = latest_step(self.directory)
        if step is None:
            return None, 0, {}
        tree, meta = restore_checkpoint(self.directory, step, target, shardings)
        return tree, step, meta
