"""Failure detection without an oracle: heartbeat/suspicion health monitoring.

Everything the fleet knew about failures through PR 7 came from a fault
*plan*: ``Fleet._apply_due_faults`` fired each ``ReplicaFault`` at its
declared instant, so recovery was triggered by an oracle. Production fleets
mostly die the other way — hangs and gray failures, a replica that stops
making progress (or degrades ×4) without ever announcing it. This module is
the observer that replaces the oracle:

  * **Heartbeats** — the fleet stamps one per replica at every stage
    boundary, in fleet virtual time (``beat``). Replicas idling with no
    work beat passively when the fleet advances their clocks; a hung
    replica stamps nothing, which is the whole signal.
  * **Adaptive suspicion** — per replica, the monitor learns the observed
    inter-beat gap distribution (windowed mean + deviation, phi-accrual
    style) and scores the current silence against it:
    ``score = (now - last_beat - mean) / spread``. SUSPECT at
    ``suspect_sigma``, CONDEMNED at ``condemn_sigma``. Thresholds adapt to
    the workload: a replica running long prefill chunks earns a wide
    expected gap, one running tight decode rounds a narrow one — which is
    exactly what a fixed timeout cannot do.
  * **Degraded (gray) detection** — each work-beat also carries the stage's
    measured duration and the duration the replica's own ``CostModel``
    predicted for that stage's composition. The ratio (observed/predicted)
    is a dimensionless slowdown sample; its running level is compared
    against a baseline captured from the replica's own early samples, so
    systematic model-fit error cancels and an intrinsically slow replica is
    NOT flagged — only a *change* is. A replica whose recent slowdown
    exceeds ``degraded_factor`` × its baseline is flagged degraded and
    moved to SUSPECT even while technically progressing.
  * **State machine** — ``ALIVE → SUSPECT → CONDEMNED``. SUSPECT is
    reversible: a beat that arrives while suspicion is below the suspect
    threshold clears the replica back to ALIVE and counts one false
    suspicion (the detector's honest error metric). CONDEMNED is terminal
    and one-way — the fleet bumps the replica's epoch and evacuates; if the
    replica was merely stalled, epoch fencing (not the detector) is what
    keeps its zombie harmless.
  * **Fixed-timeout ablation** — ``detector="fixed"`` scores silence
    against a constant ``fixed_timeout_s`` (suspect at 1×, condemn at
    ``condemn_factor``×): the naive detector an operator without gap
    statistics would deploy. ``benchmarks/detection.py`` gates that the
    adaptive detector strictly beats it on time-to-recover at token parity.

The monitor never reads the fault plan, the fault log, or any injection
state — it sees only beats and the clock. ``state_dict``/``load_state_dict``
round-trip every cursor so a restored fleet resumes suspicion where it left
off (a SUSPECT replica must not wake up ALIVE).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

ALIVE = "alive"
SUSPECT = "suspect"
CONDEMNED = "condemned"


@dataclasses.dataclass
class HealthConfig:
    """Detector selection + thresholds (all in fleet virtual seconds).

    ``detector="adaptive"`` scores silence against the learned per-replica
    gap distribution; ``"fixed"`` against ``fixed_timeout_s`` (the naive
    ablation). ``warmup_beats`` gates condemnation until the gap window has
    real samples — before that the monitor may suspect but never condemns.
    ``redispatch_backoff_s`` is the grace a SUSPECT replica's queued work
    waits before re-placement, in case the suspicion clears; a request
    whose TTFT deadline would expire within ``deadline_slack_s`` skips the
    backoff (deadline-aware redispatch)."""

    detector: str = "adaptive"            # "adaptive" | "fixed"
    suspect_sigma: float = 6.0            # adaptive: suspicion z to SUSPECT
    condemn_sigma: float = 12.0           # adaptive: suspicion z to CONDEMN
    min_spread_frac: float = 0.25         # spread floor, as fraction of mean
    gap_window: int = 32                  # gap samples kept per replica
    warmup_beats: int = 4                 # beats before condemnation allowed
    fixed_timeout_s: float = 0.25         # fixed: silence to SUSPECT
    condemn_factor: float = 2.0           # fixed: condemn at factor × timeout
    degraded_factor: float = 3.0          # slowdown vs own baseline
    degraded_window: int = 8              # slowdown rolling-median window
    baseline_beats: int = 6               # slowdown samples fixing baseline
    redispatch_backoff_s: float = 0.05    # SUSPECT queue re-placement grace
    deadline_slack_s: float = 0.0         # TTFT margin that skips the backoff

    def __post_init__(self) -> None:
        if self.detector not in ("adaptive", "fixed"):
            raise ValueError(f"unknown detector {self.detector!r}")
        if self.condemn_sigma <= self.suspect_sigma:
            raise ValueError("condemn_sigma must exceed suspect_sigma")
        if self.fixed_timeout_s <= 0:
            raise ValueError("fixed_timeout_s must be positive")
        if self.condemn_factor <= 1.0:
            raise ValueError("condemn_factor must exceed 1.0")
        if self.degraded_factor <= 1.0:
            raise ValueError("degraded_factor must exceed 1.0")


@dataclasses.dataclass
class _ReplicaHealth:
    """Per-replica monitor cursors (one heartbeat ledger)."""

    state: str = ALIVE
    last_beat_s: float = 0.0
    beats: int = 0
    gaps: List[float] = dataclasses.field(default_factory=list)
    suspect_since: Optional[float] = None
    suspect_reason: str = ""
    degraded: bool = False
    # slowdown = observed stage duration / cost-model-predicted duration;
    # ``baseline`` is the median of the replica's own first samples, so a
    # systematically mispredicted (or intrinsically slow) replica is not
    # flagged — only a departure from its own normal is.
    slowdown_level: Optional[float] = None
    slowdown_baseline: Optional[float] = None
    slowdown_samples: List[float] = dataclasses.field(default_factory=list)
    # cost-model fit the baseline was captured under (profiler refit
    # counter); a refit invalidates the baseline — see ``_note_slowdown``
    model_version: int = -1


class ReplicaHealthMonitor:
    def __init__(self, n_replicas: int, cfg: Optional[HealthConfig] = None):
        self.cfg = cfg or HealthConfig()
        self.n_replicas = n_replicas
        self.replicas = [_ReplicaHealth() for _ in range(n_replicas)]
        self.suspect_events = 0
        self.false_suspicions = 0
        self.condemned_events = 0
        self.degraded_events = 0
        self.transitions: List[Dict[str, Any]] = []
        # observability sink (repro.obs.Observation), set by the fleet when
        # a serve opts in; None executes zero obs callbacks
        self.obs = None

    # ------------------------------------------------------------------ #
    # Observation                                                        #
    # ------------------------------------------------------------------ #
    def reset(self) -> None:
        self.__init__(self.n_replicas, self.cfg)

    def state(self, i: int) -> str:
        return self.replicas[i].state

    def is_healthy(self, i: int) -> bool:
        """Dispatch/steal eligibility: ALIVE and not flagged degraded."""
        return self.replicas[i].state == ALIVE

    def beat(
        self,
        i: int,
        t: float,
        duration_s: Optional[float] = None,
        predicted_s: Optional[float] = None,
        model_version: int = 0,
    ) -> None:
        """One heartbeat for replica ``i`` at fleet virtual time ``t``.

        Work-beats (a stage completed) pass the stage's measured
        ``duration_s`` and, when the replica's cost model could price the
        stage, its ``predicted_s`` — feeding the gray-failure slowdown
        signal. ``model_version`` identifies the cost-model fit the
        prediction came from (the profiler's refit counter): when it
        changes, the slowdown baseline is recaptured, because a baseline
        taken under the old fit no longer cancels the new fit's systematic
        error. Idle beats (no work, clock advanced by the fleet) pass
        neither: they assert liveness without polluting the duration
        statistics."""
        r = self.replicas[i]
        if r.state == CONDEMNED:
            return                        # terminal; late beats are fenced
        gap = max(t - r.last_beat_s, 0.0)
        # same-instant beats (an idle replica re-asserting liveness before
        # fleet time moved) carry no cadence information — recording their
        # zero gaps would collapse the learned distribution toward 0 and
        # make any real stage look like silence
        if r.beats > 0 and gap > 0.0:
            r.gaps.append(gap)
            if len(r.gaps) > self.cfg.gap_window:
                del r.gaps[: len(r.gaps) - self.cfg.gap_window]
        r.last_beat_s = max(r.last_beat_s, t)
        r.beats += 1
        if duration_s is not None and predicted_s is not None and predicted_s > 0:
            self._note_slowdown(i, duration_s / predicted_s, t, model_version)
        # a beat while SUSPECT (and not degraded) clears the suspicion if
        # the silence score has dropped back under the suspect threshold
        if r.state == SUSPECT and not r.degraded:
            if self.suspicion(i, t) < self._suspect_threshold():
                self._transition(i, ALIVE, t, "beat resumed")
                self.false_suspicions += 1
                r.suspect_since = None
                r.suspect_reason = ""

    def _note_slowdown(
        self, i: int, slowdown: float, t: float, model_version: int
    ) -> None:
        r = self.replicas[i]
        cfg = self.cfg
        if model_version != r.model_version:
            # the cost model was refit: predictions changed scale, so the
            # baseline (whose whole job is cancelling the fit's systematic
            # error) must be recaptured under the new fit. Note this also
            # means a refit that has absorbed a degradation un-flags it —
            # the detector targets the transition window, the period before
            # the profiler normalizes the new slowness into "expected".
            r.model_version = model_version
            r.slowdown_baseline = None
            r.slowdown_samples = []
        if r.slowdown_baseline is None:
            r.slowdown_samples.append(slowdown)
            if len(r.slowdown_samples) >= cfg.baseline_beats:
                ordered = sorted(r.slowdown_samples)
                r.slowdown_baseline = max(ordered[len(ordered) // 2], 1e-9)
                r.slowdown_samples = []
                r.slowdown_level = r.slowdown_baseline
            return
        # rolling median over the recent window, NOT an EWMA: measured
        # stage durations carry one-off spikes (first-hit compiles, host
        # jitter) large enough to drag any mean past the threshold — a
        # median needs half the window genuinely slow before it moves
        r.slowdown_samples.append(slowdown)
        if len(r.slowdown_samples) > cfg.degraded_window:
            del r.slowdown_samples[
                : len(r.slowdown_samples) - cfg.degraded_window
            ]
        ordered = sorted(r.slowdown_samples)
        r.slowdown_level = ordered[len(ordered) // 2]  # reported level
        was = r.degraded
        r.degraded = (
            len(r.slowdown_samples) >= cfg.degraded_window
            and r.slowdown_level > cfg.degraded_factor * r.slowdown_baseline
        )
        if r.degraded and not was:
            self.degraded_events += 1
            if r.state == ALIVE:
                self._suspect(i, t, "degraded")
        elif was and not r.degraded and r.state == SUSPECT and (
            r.suspect_reason == "degraded"
        ):
            self._transition(i, ALIVE, t, "slowdown recovered")
            self.false_suspicions += 1
            r.suspect_since = None
            r.suspect_reason = ""

    # ------------------------------------------------------------------ #
    # Scoring                                                            #
    # ------------------------------------------------------------------ #
    def _gap_stats(self, i: int) -> tuple:
        r = self.replicas[i]
        if not r.gaps:
            return 0.0, self.cfg.fixed_timeout_s
        mean = sum(r.gaps) / len(r.gaps)
        var = sum((g - mean) ** 2 for g in r.gaps) / len(r.gaps)
        spread = max(var ** 0.5, self.cfg.min_spread_frac * mean, 1e-9)
        return mean, spread

    def _suspect_threshold(self) -> float:
        return (
            self.cfg.suspect_sigma
            if self.cfg.detector == "adaptive" else 1.0
        )

    def _condemn_threshold(self) -> float:
        return (
            self.cfg.condemn_sigma
            if self.cfg.detector == "adaptive" else self.cfg.condemn_factor
        )

    def suspicion(self, i: int, now: float) -> float:
        """The continuous suspicion score for replica ``i`` at ``now``.

        Adaptive: the silence z-score against the learned gap distribution.
        Fixed: silence / fixed_timeout_s. Both are 0-anchored — a replica
        beating at its usual cadence scores ~0 regardless of detector."""
        r = self.replicas[i]
        silence = max(now - r.last_beat_s, 0.0)
        if self.cfg.detector == "fixed":
            return silence / self.cfg.fixed_timeout_s
        mean, spread = self._gap_stats(i)
        return (silence - mean) / spread

    # ------------------------------------------------------------------ #
    # Evaluation (the fleet calls this once per step)                    #
    # ------------------------------------------------------------------ #
    def evaluate(self, now: float, replicas: Optional[List[int]] = None) -> List[int]:
        """Score every (given) replica's silence at fleet time ``now`` and
        run the state machine. Returns the replicas newly CONDEMNED this
        call — the fleet fences + evacuates them. Degraded flags move
        through ``beat``; this pass handles pure silence."""
        newly_condemned: List[int] = []
        for i in (replicas if replicas is not None else range(self.n_replicas)):
            r = self.replicas[i]
            if r.state == CONDEMNED:
                continue
            score = self.suspicion(i, now)
            if r.state == ALIVE and score >= self._suspect_threshold():
                self._suspect(i, now, "silence")
            if (
                r.state == SUSPECT
                and score >= self._condemn_threshold()
                and r.beats >= self.cfg.warmup_beats
            ):
                self._transition(i, CONDEMNED, now, r.suspect_reason or "silence")
                self.condemned_events += 1
                newly_condemned.append(i)
        return newly_condemned

    def _suspect(self, i: int, now: float, reason: str) -> None:
        r = self.replicas[i]
        self._transition(i, SUSPECT, now, reason)
        self.suspect_events += 1
        r.suspect_since = now
        r.suspect_reason = reason

    def _transition(self, i: int, state: str, now: float, reason: str) -> None:
        prev = self.replicas[i].state
        self.replicas[i].state = state
        self.transitions.append(
            {"replica": i, "state": state, "at_s": now, "reason": reason}
        )
        if self.obs is not None:
            self.obs.instant(
                "health_transition", now, replica=i,
                state=state, prev=prev, reason=reason,
            )
            self.obs.audit_record(
                "health_transition", now, i,
                {"prev": prev, "reason": reason}, state,
            )

    def condemn(self, i: int, now: float, reason: str = "external") -> None:
        """Force-condemn (fleet-initiated, e.g. an operator decision)."""
        r = self.replicas[i]
        if r.state == CONDEMNED:
            return
        self._transition(i, CONDEMNED, now, reason)
        self.condemned_events += 1

    # ------------------------------------------------------------------ #
    # Checkpoint                                                         #
    # ------------------------------------------------------------------ #
    def state_dict(self) -> str:
        """JSON string (fleet checkpoints flatten leaves through
        ``np.asarray``; a string survives, nested dicts would not)."""
        return json.dumps({
            "replicas": [dataclasses.asdict(r) for r in self.replicas],
            "suspect_events": self.suspect_events,
            "false_suspicions": self.false_suspicions,
            "condemned_events": self.condemned_events,
            "degraded_events": self.degraded_events,
            "transitions": self.transitions,
        })

    def load_state_dict(self, state: str) -> None:
        data = json.loads(state)
        if len(data["replicas"]) != self.n_replicas:
            raise ValueError(
                f"health checkpoint covers {len(data['replicas'])} replicas, "
                f"monitor has {self.n_replicas}"
            )
        self.replicas = [_ReplicaHealth(**r) for r in data["replicas"]]
        self.suspect_events = int(data["suspect_events"])
        self.false_suspicions = int(data["false_suspicions"])
        self.condemned_events = int(data["condemned_events"])
        self.degraded_events = int(data["degraded_events"])
        self.transitions = list(data["transitions"])
