"""Slot managers: the engine-side realization of the paper's "clients".

J slots ↔ the paper's J parallel clients. Two KV ownership models:

  * ``SlotManager`` — each slot owns one dense row of the batched KV cache
    (or recurrent state), preallocated at ``max_len``. Simple, but KV memory
    is n_slots × max_len regardless of what the slots actually hold, and
    every prefill scatters whole padded rows into place.
  * ``PagedSlotManager`` — slots own *pages* of a shared pool, handed out by
    a host-side ``BlockAllocator`` and resolved through a device block table
    (see models.cache paged layout). KV memory is pages-in-use; prefills
    write chunks straight into the slot's pages (serving.engine's chunked
    path), so there is no throwaway prefill cache and no padded row scatter.

Both track the same host-side slot state (free/active, request binding,
emitted tokens) behind the same interface, so the engine treats them
uniformly.
"""
from __future__ import annotations

import functools
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.types import Request

Tree = Any


class PageIntegrityError(RuntimeError):
    """A migrated KV payload failed its content checksum at import. The
    destination pool is untouched when this raises — callers fall back to
    recompute-on-resume (``Fleet.migrate_slot``) instead of continuing a
    poisoned stream."""


def page_checksum(k_pages: jax.Array, v_pages: jax.Array, kv_length: int) -> int:
    """Content checksum of a page-copy payload: CRC32 over the K and V
    payload bytes plus the valid-KV length. Computed at ``export_pages``
    and verified at ``import_pages`` — the cost is one host copy of a
    payload that is being copied across pools anyway."""
    h = zlib.crc32(np.ascontiguousarray(np.asarray(k_pages)).tobytes())
    h = zlib.crc32(np.ascontiguousarray(np.asarray(v_pages)).tobytes(), h)
    return zlib.crc32(str(int(kv_length)).encode(), h)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_cache(main: Tree, pref: Tree, slots: jax.Array) -> Tree:
    """Scatter prefill-cache rows (batch dim per leaf) into slot rows.

    Leaves with a leading layer dim have batch at axis 1 ("k"/"v" and
    recurrent states); rank-≤2 leaves ("length", ring "pos") carry batch at
    axis 0. The prefill cache's sequence axis (axis 2 of rank-≥3 leaves) may
    be a shorter bucket than the main cache — the target rows are zeroed and
    the bucket prefix written, so no stale data from a previous occupant
    survives; ring "pos" rows are padded with -1 (invalid) likewise.
    """

    def scatter(m, p):
        p = p.astype(m.dtype)
        if m.ndim == 1:
            return m.at[slots].set(p)
        if m.ndim == 2:
            if m.shape[1] != p.shape[1]:       # ring pos, shorter bucket
                pad = jnp.full((p.shape[0], m.shape[1] - p.shape[1]), -1, m.dtype)
                p = jnp.concatenate([p, pad], axis=1)
            return m.at[slots].set(p)
        if m.shape[2:] == p.shape[2:]:
            return m.at[:, slots].set(p)
        # seq axis (2) shorter in the prefill bucket: zero-fill then prefix
        z = jnp.zeros((m.shape[0], p.shape[1]) + m.shape[2:], m.dtype)
        z = z.at[:, :, : p.shape[2]].set(p)
        return m.at[:, slots].set(z)

    return jax.tree_util.tree_map(scatter, main, pref)


class SlotManager:
    def __init__(self, model, n_slots: int, max_len: int):
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = model.cache_init(n_slots, max_len)
        self.request_of: List[Optional[Request]] = [None] * n_slots
        self.emitted: List[int] = [0] * n_slots

    # ------------------------------------------------------------------ #
    @property
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.request_of) if r is None]

    @property
    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.request_of) if r is not None]

    def bind(self, slot: int, request: Request) -> None:
        if self.request_of[slot] is not None:
            raise RuntimeError(f"slot {slot} already bound")
        self.request_of[slot] = request
        self.emitted[slot] = 0

    def release(self, slot: int) -> Request:
        req = self.request_of[slot]
        if req is None:
            raise RuntimeError(f"slot {slot} not bound")
        self.request_of[slot] = None
        self.emitted[slot] = 0
        return req

    def merge_prefill(self, prefill_cache: Tree, slots: Sequence[int]) -> None:
        """Move a packed prefill's cache (batch = len(slots)) into the slot
        cache rows."""
        idx = jnp.asarray(list(slots), jnp.int32)
        self.cache = _scatter_cache(self.cache, prefill_cache, idx)

    def active_mask(self) -> jax.Array:
        return jnp.asarray(
            [r is not None for r in self.request_of], dtype=jnp.bool_
        )


# --------------------------------------------------------------------------- #
# Paged layout                                                                #
# --------------------------------------------------------------------------- #
class BlockAllocator:
    """Host-side free-list allocator for the paged KV pool.

    Pure bookkeeping — page contents live on device; this hands out page ids
    and guarantees no two slots ever share a page. LIFO reuse keeps recently
    freed (cache-warm) pages hot. A persistent free-*set* shadows the LIFO
    list so double-free detection is O(pages released), not O(pool) — under
    preemption churn every eviction releases pages, so this is a hot path."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("num_pages and page_size must be positive")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._free_set: set = set(self._free)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.num_pages - len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.page_size))

    def can_allocate(self, n_pages: int) -> bool:
        return n_pages <= len(self._free)

    def allocate(self, n_pages: int) -> List[int]:
        if not self.can_allocate(n_pages):
            raise RuntimeError(
                f"page pool exhausted: want {n_pages}, have {len(self._free)} "
                f"of {self.num_pages}"
            )
        out = self._free[-n_pages:][::-1]
        del self._free[-n_pages:]
        self._free_set.difference_update(out)
        return out

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if not 0 <= p < self.num_pages:
                raise ValueError(f"page {p} out of range")
        if any(p in self._free_set for p in pages):
            raise RuntimeError("double free of KV page")
        self._free.extend(pages)
        self._free_set.update(pages)
        self.check_consistency()

    def reset(self, in_use: Sequence[int] = ()) -> None:
        """Rebuild the free list from a known set of in-use pages (checkpoint
        restore)."""
        used = set(in_use)
        self._free = [p for p in range(self.num_pages - 1, -1, -1) if p not in used]
        self._free_set = set(self._free)

    def check_consistency(self) -> None:
        """The free list and free set must always describe the same pages —
        a divergence means a page was leaked or double-owned."""
        if len(self._free) != len(self._free_set):
            raise AssertionError(
                f"allocator free list ({len(self._free)}) and free set "
                f"({len(self._free_set)}) diverged"
            )


class PagedSlotManager:
    """SlotManager counterpart for the paged cache layout.

    ``reserve`` hands a slot pages covering an initial token span (the
    engine decides how much: the prompt under on-demand paging, the whole
    prompt + decode bound under up-front reservation) and ``ensure_tokens``
    grows the slot's table page-by-page as decode crosses page boundaries.
    When growth finds the pool exhausted the *engine* preempts a
    lowest-priority slot (``free_pages_of`` + re-queue) — the manager only
    does page bookkeeping. Block table rows are mirrored to the device cache
    on reserve/grow/release."""

    def __init__(
        self,
        model,
        n_slots: int,
        max_len: int,
        page_size: int,
        num_pages: Optional[int] = None,
    ):
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.page_size = page_size
        self.max_pages_per_slot = -(-max_len // page_size)
        self.num_pages = (
            num_pages if num_pages is not None
            else n_slots * self.max_pages_per_slot
        )
        self.cache = model.paged_cache_init(
            self.num_pages, page_size, n_slots, self.max_pages_per_slot
        )
        self.allocator = BlockAllocator(self.num_pages, page_size)
        self.tables: List[List[int]] = [[] for _ in range(n_slots)]
        self.request_of: List[Optional[Request]] = [None] * n_slots
        self.emitted: List[int] = [0] * n_slots
        self.peak_pages = 0

    # -- same read interface as SlotManager ---------------------------- #
    @property
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.request_of) if r is None]

    @property
    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.request_of) if r is not None]

    def bind(self, slot: int, request: Request) -> None:
        if self.request_of[slot] is not None:
            raise RuntimeError(f"slot {slot} already bound")
        self.request_of[slot] = request
        self.emitted[slot] = 0

    def active_mask(self) -> jax.Array:
        return jnp.asarray(
            [r is not None for r in self.request_of], dtype=jnp.bool_
        )

    # -- page ownership ------------------------------------------------ #
    def _mirror_row(self, slot: int) -> None:
        """Push ``slot``'s host block-table row to the device cache."""
        row = np.full((self.max_pages_per_slot,), -1, np.int32)
        pages = self.tables[slot]
        row[: len(pages)] = pages
        self.cache["block_tables"] = (
            self.cache["block_tables"].at[slot].set(jnp.asarray(row))
        )

    def reserve(self, slot: int, n_tokens: int) -> None:
        """Give ``slot`` pages covering ``n_tokens`` and mirror its block
        table row to the device."""
        if self.tables[slot]:
            raise RuntimeError(f"slot {slot} already holds pages")
        n_tokens = min(n_tokens, self.max_len)
        pages = self.allocator.allocate(self.allocator.pages_for(n_tokens))
        self.tables[slot] = pages
        self.peak_pages = max(self.peak_pages, self.allocator.num_used)
        self._mirror_row(slot)

    def owned_tokens(self, slot: int) -> int:
        """Token capacity of the pages ``slot`` currently owns."""
        return len(self.tables[slot]) * self.page_size

    def pages_to_cover(self, slot: int, n_tokens: int) -> int:
        """Additional pages ``slot`` needs to hold ``n_tokens`` KV entries
        (0 when its current pages already cover them)."""
        n_tokens = min(n_tokens, self.max_len)
        return max(
            0, self.allocator.pages_for(n_tokens) - len(self.tables[slot])
        )

    def ensure_tokens(self, slot: int, n_tokens: int) -> int:
        """Grow ``slot``'s page span to cover ``n_tokens`` (on-demand decode
        growth). Returns the pages added; raises if the pool cannot supply
        them — the engine preempts a victim and retries."""
        need = self.pages_to_cover(slot, n_tokens)
        if need == 0:
            return 0
        pages = self.allocator.allocate(need)
        self.tables[slot].extend(pages)
        self.peak_pages = max(self.peak_pages, self.allocator.num_used)
        self._mirror_row(slot)
        return need

    def release(self, slot: int) -> Request:
        req = self.request_of[slot]
        if req is None:
            raise RuntimeError(f"slot {slot} not bound")
        self.request_of[slot] = None
        self.emitted[slot] = 0
        self.free_pages_of(slot)
        return req

    def free_pages_of(self, slot: int) -> None:
        if self.tables[slot]:
            self.allocator.free(self.tables[slot])
            self.tables[slot] = []
        self.cache["block_tables"] = self.cache["block_tables"].at[slot].set(-1)
        self.cache["length"] = self.cache["length"].at[slot].set(0)

    # -- page-copy migration (live cross-engine slot transfer) ---------- #
    def export_pages(
        self, slot: int
    ) -> Tuple[List[int], jax.Array, jax.Array, int, int]:
        """Gather ``slot``'s KV pages out of the pool for migration.

        Returns ``(pages, k_payload, v_payload, kv_length, checksum)``
        where the payloads are ``(L, KV, n_pages, page_size, D)`` device
        arrays — a plain gather along the pool's page axis, independent of
        *which* page ids the destination pool will assign — and
        ``checksum`` is a CRC over the payload bytes (``page_checksum``),
        computed at export time so a corrupted transfer is caught at
        import instead of silently poisoning the resumed stream. The
        caller frees the source pages afterwards (``release`` /
        ``free_pages_of``)."""
        pages = list(self.tables[slot])
        if not pages:
            raise RuntimeError(f"slot {slot} holds no pages to export")
        idx = jnp.asarray(pages, jnp.int32)
        k = jnp.take(self.cache["k"], idx, axis=2)
        v = jnp.take(self.cache["v"], idx, axis=2)
        length = int(np.asarray(self.cache["length"][slot]))
        return pages, k, v, length, page_checksum(k, v, length)

    def import_pages(
        self,
        slot: int,
        k_pages: jax.Array,
        v_pages: jax.Array,
        kv_length: int,
        checksum: Optional[int] = None,
    ) -> List[int]:
        """Land exported KV payloads in freshly allocated pages of THIS
        pool: allocate, scatter, point ``slot``'s block table at the new
        pages, and restore its valid-KV length. The page ids differ from
        the source's — only the block-table indirection has to agree, which
        is the whole point of the paged layout. Returns the new pages.

        When ``checksum`` is given, the received payload is re-hashed and
        verified BEFORE any pool state changes; a mismatch raises
        ``PageIntegrityError`` with the pool untouched, so the caller can
        fall back to recompute-on-resume rather than continue a poisoned
        stream."""
        if self.tables[slot]:
            raise RuntimeError(f"slot {slot} already holds pages")
        if checksum is not None:
            got = page_checksum(k_pages, v_pages, kv_length)
            if got != checksum:
                raise PageIntegrityError(
                    f"slot {slot}: KV payload checksum {got:#010x} != "
                    f"exported {checksum:#010x} — migration payload corrupt"
                )
        n = int(k_pages.shape[2])
        pages = self.allocator.allocate(n)
        idx = jnp.asarray(pages, jnp.int32)
        self.cache["k"] = self.cache["k"].at[:, :, idx].set(
            k_pages.astype(self.cache["k"].dtype)
        )
        self.cache["v"] = self.cache["v"].at[:, :, idx].set(
            v_pages.astype(self.cache["v"].dtype)
        )
        self.tables[slot] = pages
        self.peak_pages = max(self.peak_pages, self.allocator.num_used)
        self._mirror_row(slot)
        self.cache["length"] = self.cache["length"].at[slot].set(int(kv_length))
        return pages

    def check_block_table_mirror(self) -> None:
        """The host ``tables`` and the device ``block_tables`` must describe
        the same page ownership row for row, and a slot owning no pages must
        hold no KV length — a divergence means a reserve/grow/release path
        skipped its mirror write (``EngineConfig.debug_invariants`` asserts
        this at stage boundaries)."""
        bt = np.asarray(self.cache["block_tables"])
        lengths = np.asarray(self.cache["length"])
        for slot, pages in enumerate(self.tables):
            row = np.full((self.max_pages_per_slot,), -1, np.int32)
            row[: len(pages)] = pages
            if not np.array_equal(bt[slot], row):
                raise AssertionError(
                    f"slot {slot}: host block table {pages} diverged from "
                    f"device row {bt[slot].tolist()}"
                )
            if not pages and int(lengths[slot]) != 0:
                raise AssertionError(
                    f"slot {slot}: owns no pages but device KV length is "
                    f"{int(lengths[slot])}"
                )

    def sync_from_device(self) -> None:
        """Rebuild host tables + allocator from the device block table
        (checkpoint restore path — the device array is the durable record)."""
        bt = np.asarray(self.cache["block_tables"])
        self.tables = [[int(p) for p in row if p >= 0] for row in bt]
        self.allocator.reset([p for row in self.tables for p in row])
        self.peak_pages = max(self.peak_pages, self.allocator.num_used)

    # -- accounting ---------------------------------------------------- #
    def kv_bytes_in_use(self) -> int:
        """Bytes of KV pool actually owned by slots right now."""
        return self.allocator.num_used * (
            self.kv_bytes_capacity() // self.allocator.num_pages
        )

    def kv_bytes_capacity(self) -> int:
        return self.cache["k"].nbytes + self.cache["v"].nbytes

    def peak_kv_bytes(self) -> int:
        """High-water mark of slot-owned KV bytes over the run."""
        if self.allocator.num_pages == 0:
            return 0
        return self.peak_pages * (self.kv_bytes_capacity() // self.allocator.num_pages)
