"""Slot managers: the engine-side realization of the paper's "clients".

J slots ↔ the paper's J parallel clients. Two KV ownership models:

  * ``SlotManager`` — each slot owns one dense row of the batched KV cache
    (or recurrent state), preallocated at ``max_len``. Simple, but KV memory
    is n_slots × max_len regardless of what the slots actually hold, and
    every prefill scatters whole padded rows into place.
  * ``PagedSlotManager`` — slots own *pages* of a shared pool, handed out by
    a host-side ``BlockAllocator`` and resolved through a device block table
    (see models.cache paged layout). KV memory is pages-in-use; prefills
    write chunks straight into the slot's pages (serving.engine's chunked
    path), so there is no throwaway prefill cache and no padded row scatter.

Both track the same host-side slot state (free/active, request binding,
emitted tokens) behind the same interface, so the engine treats them
uniformly.
"""
from __future__ import annotations

import functools
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.types import Request

Tree = Any


class PageIntegrityError(RuntimeError):
    """A migrated KV payload failed its content checksum at import. The
    destination pool is untouched when this raises — callers fall back to
    recompute-on-resume (``Fleet.migrate_slot``) instead of continuing a
    poisoned stream."""


def page_checksum(k_pages: jax.Array, v_pages: jax.Array, kv_length: int) -> int:
    """Content checksum of a page-copy payload: CRC32 over the K and V
    payload bytes plus the valid-KV length. Computed at ``export_pages``
    and verified at ``import_pages`` — the cost is one host copy of a
    payload that is being copied across pools anyway."""
    h = zlib.crc32(np.ascontiguousarray(np.asarray(k_pages)).tobytes())
    h = zlib.crc32(np.ascontiguousarray(np.asarray(v_pages)).tobytes(), h)
    return zlib.crc32(str(int(kv_length)).encode(), h)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_cache(main: Tree, pref: Tree, slots: jax.Array) -> Tree:
    """Scatter prefill-cache rows (batch dim per leaf) into slot rows.

    Leaves with a leading layer dim have batch at axis 1 ("k"/"v" and
    recurrent states); rank-≤2 leaves ("length", ring "pos") carry batch at
    axis 0. The prefill cache's sequence axis (axis 2 of rank-≥3 leaves) may
    be a shorter bucket than the main cache — the target rows are zeroed and
    the bucket prefix written, so no stale data from a previous occupant
    survives; ring "pos" rows are padded with -1 (invalid) likewise.
    """

    def scatter(m, p):
        p = p.astype(m.dtype)
        if m.ndim == 1:
            return m.at[slots].set(p)
        if m.ndim == 2:
            if m.shape[1] != p.shape[1]:       # ring pos, shorter bucket
                pad = jnp.full((p.shape[0], m.shape[1] - p.shape[1]), -1, m.dtype)
                p = jnp.concatenate([p, pad], axis=1)
            return m.at[slots].set(p)
        if m.shape[2:] == p.shape[2:]:
            return m.at[:, slots].set(p)
        # seq axis (2) shorter in the prefill bucket: zero-fill then prefix
        z = jnp.zeros((m.shape[0], p.shape[1]) + m.shape[2:], m.dtype)
        z = z.at[:, :, : p.shape[2]].set(p)
        return m.at[:, slots].set(z)

    return jax.tree_util.tree_map(scatter, main, pref)


class SlotManager:
    def __init__(self, model, n_slots: int, max_len: int):
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = model.cache_init(n_slots, max_len)
        self.request_of: List[Optional[Request]] = [None] * n_slots
        self.emitted: List[int] = [0] * n_slots

    # ------------------------------------------------------------------ #
    @property
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.request_of) if r is None]

    @property
    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.request_of) if r is not None]

    def bind(self, slot: int, request: Request) -> None:
        if self.request_of[slot] is not None:
            raise RuntimeError(f"slot {slot} already bound")
        self.request_of[slot] = request
        self.emitted[slot] = 0

    def release(self, slot: int) -> Request:
        req = self.request_of[slot]
        if req is None:
            raise RuntimeError(f"slot {slot} not bound")
        self.request_of[slot] = None
        self.emitted[slot] = 0
        return req

    def merge_prefill(self, prefill_cache: Tree, slots: Sequence[int]) -> None:
        """Move a packed prefill's cache (batch = len(slots)) into the slot
        cache rows."""
        idx = jnp.asarray(list(slots), jnp.int32)
        self.cache = _scatter_cache(self.cache, prefill_cache, idx)

    def active_mask(self) -> jax.Array:
        return jnp.asarray(
            [r is not None for r in self.request_of], dtype=jnp.bool_
        )


# --------------------------------------------------------------------------- #
# Paged layout                                                                #
# --------------------------------------------------------------------------- #
class BlockAllocator:
    """Host-side refcounted free-list allocator for the paged KV pool.

    Pure bookkeeping — page contents live on device; this hands out page ids
    and tracks how many owners each page has. ``allocate`` hands out fresh
    pages at refcount 1, ``share`` adds an owner to a live page (prefix-cache
    adoption / index holds), and ``release`` drops one owner — a page returns
    to the free list only when its last reference goes. LIFO reuse keeps
    recently freed (cache-warm) pages hot. A persistent free-*set* shadows
    the LIFO list so double-free detection is O(pages released), not
    O(pool) — under preemption churn every eviction releases pages, so this
    is a hot path."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("num_pages and page_size must be positive")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._free_set: set = set(self._free)
        self._refs: List[int] = [0] * num_pages

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.num_pages - len(self._free)

    def ref_count(self, page: int) -> int:
        return self._refs[page]

    def num_shared(self) -> int:
        """Pages with more than one live owner right now."""
        return sum(1 for r in self._refs if r >= 2)

    def pages_for(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.page_size))

    def can_allocate(self, n_pages: int) -> bool:
        return n_pages <= len(self._free)

    def allocate(self, n_pages: int) -> List[int]:
        if not self.can_allocate(n_pages):
            raise RuntimeError(
                f"page pool exhausted: want {n_pages}, have {len(self._free)} "
                f"of {self.num_pages}"
            )
        out = self._free[-n_pages:][::-1]
        del self._free[-n_pages:]
        self._free_set.difference_update(out)
        for p in out:
            self._refs[p] = 1
        return out

    def share(self, pages: Sequence[int]) -> None:
        """Add one owner to each page. Only live pages can gain owners —
        sharing a free page means the caller holds a stale id."""
        for p in pages:
            if not 0 <= p < self.num_pages:
                raise ValueError(f"page {p} out of range")
            if self._refs[p] <= 0:
                raise RuntimeError(f"share of free KV page {p}")
        for p in pages:
            self._refs[p] += 1

    def release(self, pages: Sequence[int]) -> List[int]:
        """Drop one owner per page; pages whose last reference goes return
        to the free list. Returns the pages actually freed. Releasing a page
        with no owners is the refcount-world double free."""
        for p in pages:
            if not 0 <= p < self.num_pages:
                raise ValueError(f"page {p} out of range")
        if any(self._refs[p] <= 0 or p in self._free_set for p in pages):
            raise RuntimeError("double free of KV page")
        freed = []
        for p in pages:
            self._refs[p] -= 1
            if self._refs[p] == 0:
                freed.append(p)
        self._free.extend(freed)
        self._free_set.update(freed)
        self.check_consistency()
        return freed

    # kept as the historical name — release IS free in refcount world
    free = release

    def reset(self, in_use: Sequence[int] = ()) -> None:
        """Rebuild the free list from the in-use pages of a restored
        checkpoint. ``in_use`` may repeat a page id — multiplicity IS the
        refcount (a page shared by k block-table rows appears k times)."""
        refs = [0] * self.num_pages
        for p in in_use:
            refs[p] += 1
        self._refs = refs
        self._free = [
            p for p in range(self.num_pages - 1, -1, -1) if refs[p] == 0
        ]
        self._free_set = set(self._free)

    def check_consistency(self) -> None:
        """Free list, free set, and refcounts must describe the same pages —
        a divergence means a page was leaked, double-owned, or freed while
        referenced."""
        if len(self._free) != len(self._free_set):
            raise AssertionError(
                f"allocator free list ({len(self._free)}) and free set "
                f"({len(self._free_set)}) diverged"
            )
        for p in self._free_set:
            if self._refs[p] != 0:
                raise AssertionError(
                    f"page {p} is on the free list with refcount {self._refs[p]}"
                )
        live = sum(1 for r in self._refs if r > 0)
        if live != self.num_used:
            raise AssertionError(
                f"{live} pages hold references but {self.num_used} are "
                f"off the free list — a page leaked or was double-owned"
            )


class PrefixCacheIndex:
    """Content-addressed index of *full* KV pages for prefix-cache reuse.

    Pages are keyed by a chained hash à la vLLM: a page holding prompt
    tokens ``t[i·ps:(i+1)·ps]`` hashes as ``H(parent_key, page_tokens)``
    where ``parent_key`` is the key of the page before it (root sentinel
    for the first page). Two prompts that share a prefix walk to the same
    keys, so lookup is a chain walk that stops at the first miss; the
    divergence *within* a page is found by scanning the last matched key's
    children for the longest common token prefix — that page is the
    copy-on-write source.

    The index holds one allocator reference per published page, so cached
    pages survive their publisher's release. ``reclaim`` evicts
    least-recently-touched entries whose page has no owner besides the
    index (refcount 1) and no children still in the index — eviction of a
    page some slot still shares is structurally impossible, and parents
    are never removed from under reachable children (which would leak the
    child's hold forever)."""

    _ROOT = 0xA5A5A5A5

    def __init__(self, allocator: BlockAllocator, page_size: int):
        self.allocator = allocator
        self.page_size = page_size
        # key -> (page, tokens tuple, parent key); insertion order is
        # maintained separately as the LRU clock
        self._entries: Dict[int, Tuple[int, Tuple[int, ...], int]] = {}
        self._children: Dict[int, set] = {}
        self._clock = 0
        self._touched: Dict[int, int] = {}
        self.lookups = 0
        self.hit_tokens = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def _hash(parent_key: int, tokens: Sequence[int]) -> int:
        import hashlib

        data = int(parent_key).to_bytes(8, "big") + np.asarray(
            tokens, dtype=np.int64
        ).tobytes()
        return int.from_bytes(
            hashlib.blake2b(data, digest_size=8).digest(), "big"
        )

    def _touch(self, key: int) -> None:
        self._clock += 1
        self._touched[key] = self._clock

    def held_pages(self) -> List[int]:
        """Pages the index itself holds a reference on (one per entry)."""
        return [page for page, _, _ in self._entries.values()]

    # -- lookup ---------------------------------------------------------- #
    def match(
        self, tokens: np.ndarray
    ) -> Tuple[List[int], Optional[Tuple[int, int]]]:
        """Longest cached prefix of ``tokens``: a list of fully matched
        pages (position order) plus, at the divergence point, the best
        partially matching page as ``(page, n_matched_tokens)`` — the COW
        source — or None if the next page is a clean miss."""
        self.lookups += 1
        ps = self.page_size
        toks = np.asarray(tokens)
        full, parent = [], self._ROOT
        n_full = len(toks) // ps
        i = 0
        while i < n_full:
            key = self._hash(parent, toks[i * ps:(i + 1) * ps])
            ent = self._entries.get(key)
            if ent is None:
                break
            self._touch(key)
            full.append(ent[0])
            parent = key
            i += 1
        # partial match inside the next page: scan the last matched key's
        # children for the longest common prefix with the remaining tokens
        rest = toks[i * ps:]
        best: Optional[Tuple[int, int]] = None
        if len(rest) > 0:
            for key in self._children.get(parent, ()):
                page, ent_toks, _ = self._entries[key]
                n = 0
                m = min(len(rest), len(ent_toks))
                while n < m and int(rest[n]) == ent_toks[n]:
                    n += 1
                if n > 0 and (best is None or n > best[1]):
                    best = (page, n)
                    if n == m:
                        break
            if best is not None:
                self._touch(
                    next(
                        k for k in self._children.get(parent, ())
                        if self._entries[k][0] == best[0]
                    )
                )
        return full, best

    # -- publication ------------------------------------------------------ #
    def insert(self, tokens: np.ndarray, pages: Sequence[int]) -> int:
        """Publish the full pages of a completed prompt: ``pages[i]`` holds
        ``tokens[i·ps:(i+1)·ps]``. Already-indexed content is skipped (the
        existing entry keeps serving hits); new entries take one allocator
        reference each. Returns the number of pages newly published."""
        ps = self.page_size
        toks = np.asarray(tokens)
        parent, added = self._ROOT, 0
        for i in range(min(len(toks) // ps, len(pages))):
            page_toks = tuple(int(t) for t in toks[i * ps:(i + 1) * ps])
            key = self._hash(parent, page_toks)
            if key not in self._entries:
                self.allocator.share([pages[i]])
                self._entries[key] = (pages[i], page_toks, parent)
                self._children.setdefault(parent, set()).add(key)
                added += 1
            self._touch(key)
            parent = key
        return added

    # -- eviction ---------------------------------------------------------- #
    def _evictable(self, key: int) -> bool:
        page = self._entries[key][0]
        return (
            self.allocator.ref_count(page) == 1
            and not self._children.get(key)
        )

    def _evict(self, key: int) -> None:
        page, _, parent = self._entries.pop(key)
        kids = self._children.get(parent)
        if kids is not None:
            kids.discard(key)
            if not kids:
                del self._children[parent]
        self._children.pop(key, None)
        self._touched.pop(key, None)
        self.allocator.release([page])
        self.evictions += 1

    def reclaim(self, n_pages: int) -> int:
        """Evict index-only pages (LRU first, leaves before parents) until
        ``n_pages`` have been freed or nothing evictable remains. Returns
        pages freed."""
        freed = 0
        while freed < n_pages:
            cands = [
                k for k in sorted(
                    self._entries, key=lambda k: self._touched.get(k, 0)
                )
                if self._evictable(k)
            ]
            if not cands:
                break
            for k in cands:
                self._evict(k)
                freed += 1
                if freed >= n_pages:
                    break
        return freed

    def reclaimable_pages(self) -> int:
        """How many pages eviction could free right now: entries whose page
        has no owner but the index, counted with leaf-to-root cascading
        (a parent counts only if its whole reachable subtree is index-only)."""
        kids = {k: set(v) for k, v in self._children.items()}
        alive = set(self._entries)
        n = 0
        progress = True
        while progress:
            progress = False
            for k in list(alive):
                if kids.get(k):
                    continue
                if self.allocator.ref_count(self._entries[k][0]) != 1:
                    continue
                alive.discard(k)
                parent = self._entries[k][2]
                if parent in kids:
                    kids[parent].discard(k)
                n += 1
                progress = True
        return n

    def clear(self) -> int:
        """Drop every entry, releasing the index's holds (end-of-serve
        refcount audit, cold-start). Returns pages whose last reference
        this released."""
        freed = 0
        for page, _, _ in self._entries.values():
            freed += len(self.allocator.release([page]))
        self._entries.clear()
        self._children.clear()
        self._touched.clear()
        return freed

    def invalidate(self) -> None:
        """Forget every entry WITHOUT touching the allocator — for restore
        paths where the allocator was rebuilt from the device block tables
        and the index's holds are already gone."""
        self._entries.clear()
        self._children.clear()
        self._touched.clear()


class PagedSlotManager:
    """SlotManager counterpart for the paged cache layout.

    ``reserve`` hands a slot pages covering an initial token span (the
    engine decides how much: the prompt under on-demand paging, the whole
    prompt + decode bound under up-front reservation) and ``ensure_tokens``
    grows the slot's table page-by-page as decode crosses page boundaries.
    When growth finds the pool exhausted the *engine* preempts a
    lowest-priority slot (``free_pages_of`` + re-queue) — the manager only
    does page bookkeeping. Block table rows are mirrored to the device cache
    on reserve/grow/release."""

    def __init__(
        self,
        model,
        n_slots: int,
        max_len: int,
        page_size: int,
        num_pages: Optional[int] = None,
        prefix_cache: bool = False,
    ):
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.page_size = page_size
        self.max_pages_per_slot = -(-max_len // page_size)
        self.num_pages = (
            num_pages if num_pages is not None
            else n_slots * self.max_pages_per_slot
        )
        self.cache = model.paged_cache_init(
            self.num_pages, page_size, n_slots, self.max_pages_per_slot
        )
        self.allocator = BlockAllocator(self.num_pages, page_size)
        self.prefix_index: Optional[PrefixCacheIndex] = (
            PrefixCacheIndex(self.allocator, page_size) if prefix_cache else None
        )
        self.tables: List[List[int]] = [[] for _ in range(n_slots)]
        self.request_of: List[Optional[Request]] = [None] * n_slots
        self.emitted: List[int] = [0] * n_slots
        self.peak_pages = 0
        self.shared_pages_peak = 0
        self.cow_copies = 0
        # observability hooks, set by the owning engine when a serve opts
        # in (EngineConfig.observe); obs_now is refreshed each serve step
        # so COW instants land at the engine's current virtual time
        self.obs = None
        self.obs_replica = 0
        self.obs_now = 0.0

    # -- same read interface as SlotManager ---------------------------- #
    @property
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.request_of) if r is None]

    @property
    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.request_of) if r is not None]

    def bind(self, slot: int, request: Request) -> None:
        if self.request_of[slot] is not None:
            raise RuntimeError(f"slot {slot} already bound")
        self.request_of[slot] = request
        self.emitted[slot] = 0

    def active_mask(self) -> jax.Array:
        return jnp.asarray(
            [r is not None for r in self.request_of], dtype=jnp.bool_
        )

    # -- page ownership ------------------------------------------------ #
    def _mirror_row(self, slot: int) -> None:
        """Push ``slot``'s host block-table row to the device cache."""
        row = np.full((self.max_pages_per_slot,), -1, np.int32)
        pages = self.tables[slot]
        row[: len(pages)] = pages
        self.cache["block_tables"] = (
            self.cache["block_tables"].at[slot].set(jnp.asarray(row))
        )

    def _alloc(self, n_pages: int) -> List[int]:
        """Allocate fresh pages, evicting index-only cached pages on demand
        when the free list alone can't supply them."""
        short = n_pages - self.allocator.num_free
        if short > 0 and self.prefix_index is not None:
            self.prefix_index.reclaim(short)
        pages = self.allocator.allocate(n_pages)
        self.peak_pages = max(self.peak_pages, self.allocator.num_used)
        return pages

    def reserve(self, slot: int, n_tokens: int) -> None:
        """Give ``slot`` pages covering ``n_tokens`` and mirror its block
        table row to the device."""
        if self.tables[slot]:
            raise RuntimeError(f"slot {slot} already holds pages")
        n_tokens = min(n_tokens, self.max_len)
        pages = self._alloc(self.allocator.pages_for(n_tokens))
        self.tables[slot] = pages
        self._mirror_row(slot)

    # -- prefix-cache adoption / publication ----------------------------- #
    def probe_prefix(self, prompt: np.ndarray) -> int:
        """Read-only estimate of how many of ``prompt``'s tokens the cache
        could supply (clamped so at least one token is always recomputed —
        the first output token needs live logits)."""
        if self.prefix_index is None or len(prompt) == 0:
            return 0
        full, partial = self.prefix_index.match(prompt)
        cached = len(full) * self.page_size + (partial[1] if partial else 0)
        return min(cached, len(prompt) - 1)

    def _copy_page(self, src: int, dst: int) -> None:
        """Device copy of one page's K/V content (the COW divergence page)."""
        self.cache["k"] = self.cache["k"].at[:, :, dst].set(
            self.cache["k"][:, :, src]
        )
        self.cache["v"] = self.cache["v"].at[:, :, dst].set(
            self.cache["v"][:, :, src]
        )
        self.cow_copies += 1
        if self.obs is not None:
            self.obs.instant(
                "cow_copy", self.obs_now, replica=self.obs_replica,
                src_page=src, dst_page=dst,
            )

    def reserve_with_prefix(
        self, slot: int, prompt: np.ndarray, n_tokens: int
    ) -> int:
        """Like ``reserve``, but adopt the longest cached prefix of
        ``prompt`` first: fully matched pages are shared read-only
        (refcount + 1), and the page at the divergence point — including a
        divergence inside the partial last page — is copy-on-write: its
        content is device-copied into a fresh private page so the adopter's
        chunked prefill can keep writing without touching the shared
        original. Returns the number of prompt tokens served from cache;
        chunked prefill should start at that offset."""
        if self.tables[slot]:
            raise RuntimeError(f"slot {slot} already holds pages")
        if self.prefix_index is None:
            self.reserve(slot, n_tokens)
            return 0
        ps = self.page_size
        full, partial = self.prefix_index.match(prompt)
        cached = len(full) * ps + (partial[1] if partial else 0)
        # always recompute ≥ 1 token: the final prompt token's logits seed
        # the first output token, and the page it lands in must be private
        cached = min(cached, len(prompt) - 1)
        n_shared = cached // ps
        shared = full[:n_shared]
        # the COW source: a fully matched page demoted by the clamp, or the
        # partially matched child at the divergence point
        cow_src: Optional[int] = None
        if cached % ps:
            cow_src = full[n_shared] if n_shared < len(full) else partial[0]
        self.allocator.share(shared)
        n_total = self.allocator.pages_for(min(n_tokens, self.max_len))
        try:
            fresh = self._alloc(max(n_total - n_shared, 0))
        except RuntimeError:
            self.allocator.release(shared)
            raise
        if cow_src is not None and fresh:
            self._copy_page(cow_src, fresh[0])
        self.tables[slot] = shared + fresh
        self.shared_pages_peak = max(
            self.shared_pages_peak, self.allocator.num_shared()
        )
        self._mirror_row(slot)
        self.cache["length"] = self.cache["length"].at[slot].set(int(cached))
        return int(cached)

    def publish_prefix(self, slot: int, prompt: np.ndarray) -> int:
        """Publish the completed prompt's *full* pages to the prefix index
        (the partial last page keeps taking decode writes, so only pages
        whose every token is prompt content are immutable and shareable).
        Returns pages newly indexed."""
        if self.prefix_index is None:
            return 0
        n_full = len(prompt) // self.page_size
        return self.prefix_index.insert(prompt, self.tables[slot][:n_full])

    def reclaimable_pages(self) -> int:
        """Pages the prefix index could surrender on demand (admission and
        decode-growth headroom count these as supply)."""
        return (
            self.prefix_index.reclaimable_pages()
            if self.prefix_index is not None else 0
        )

    def owned_tokens(self, slot: int) -> int:
        """Token capacity of the pages ``slot`` currently owns."""
        return len(self.tables[slot]) * self.page_size

    def pages_to_cover(self, slot: int, n_tokens: int) -> int:
        """Additional pages ``slot`` needs to hold ``n_tokens`` KV entries
        (0 when its current pages already cover them)."""
        n_tokens = min(n_tokens, self.max_len)
        return max(
            0, self.allocator.pages_for(n_tokens) - len(self.tables[slot])
        )

    def ensure_tokens(self, slot: int, n_tokens: int) -> int:
        """Grow ``slot``'s page span to cover ``n_tokens`` (on-demand decode
        growth). Returns the pages added; raises if the pool cannot supply
        them — the engine preempts a victim and retries."""
        need = self.pages_to_cover(slot, n_tokens)
        if need == 0:
            return 0
        pages = self._alloc(need)
        self.tables[slot].extend(pages)
        self._mirror_row(slot)
        return need

    def release(self, slot: int) -> Request:
        req = self.request_of[slot]
        if req is None:
            raise RuntimeError(f"slot {slot} not bound")
        self.request_of[slot] = None
        self.emitted[slot] = 0
        self.free_pages_of(slot)
        return req

    def free_pages_of(self, slot: int) -> None:
        if self.tables[slot]:
            self.allocator.free(self.tables[slot])
            self.tables[slot] = []
        self.cache["block_tables"] = self.cache["block_tables"].at[slot].set(-1)
        self.cache["length"] = self.cache["length"].at[slot].set(0)

    # -- page-copy migration (live cross-engine slot transfer) ---------- #
    def export_pages(
        self, slot: int
    ) -> Tuple[List[int], jax.Array, jax.Array, int, int]:
        """Gather ``slot``'s KV pages out of the pool for migration.

        Returns ``(pages, k_payload, v_payload, kv_length, checksum)``
        where the payloads are ``(L, KV, n_pages, page_size, D)`` device
        arrays — a plain gather along the pool's page axis, independent of
        *which* page ids the destination pool will assign — and
        ``checksum`` is a CRC over the payload bytes (``page_checksum``),
        computed at export time so a corrupted transfer is caught at
        import instead of silently poisoning the resumed stream. The
        caller frees the source pages afterwards (``release`` /
        ``free_pages_of``)."""
        pages = list(self.tables[slot])
        if not pages:
            raise RuntimeError(f"slot {slot} holds no pages to export")
        idx = jnp.asarray(pages, jnp.int32)
        k = jnp.take(self.cache["k"], idx, axis=2)
        v = jnp.take(self.cache["v"], idx, axis=2)
        length = int(np.asarray(self.cache["length"][slot]))
        return pages, k, v, length, page_checksum(k, v, length)

    def import_pages(
        self,
        slot: int,
        k_pages: jax.Array,
        v_pages: jax.Array,
        kv_length: int,
        checksum: Optional[int] = None,
    ) -> List[int]:
        """Land exported KV payloads in freshly allocated pages of THIS
        pool: allocate, scatter, point ``slot``'s block table at the new
        pages, and restore its valid-KV length. The page ids differ from
        the source's — only the block-table indirection has to agree, which
        is the whole point of the paged layout. Returns the new pages.

        When ``checksum`` is given, the received payload is re-hashed and
        verified BEFORE any pool state changes; a mismatch raises
        ``PageIntegrityError`` with the pool untouched, so the caller can
        fall back to recompute-on-resume rather than continue a poisoned
        stream."""
        if self.tables[slot]:
            raise RuntimeError(f"slot {slot} already holds pages")
        if checksum is not None:
            got = page_checksum(k_pages, v_pages, kv_length)
            if got != checksum:
                raise PageIntegrityError(
                    f"slot {slot}: KV payload checksum {got:#010x} != "
                    f"exported {checksum:#010x} — migration payload corrupt"
                )
        n = int(k_pages.shape[2])
        pages = self._alloc(n)
        idx = jnp.asarray(pages, jnp.int32)
        self.cache["k"] = self.cache["k"].at[:, :, idx].set(
            k_pages.astype(self.cache["k"].dtype)
        )
        self.cache["v"] = self.cache["v"].at[:, :, idx].set(
            v_pages.astype(self.cache["v"].dtype)
        )
        self.tables[slot] = pages
        self.peak_pages = max(self.peak_pages, self.allocator.num_used)
        self._mirror_row(slot)
        self.cache["length"] = self.cache["length"].at[slot].set(int(kv_length))
        return pages

    def check_block_table_mirror(self) -> None:
        """The host ``tables`` and the device ``block_tables`` must describe
        the same page ownership row for row, and a slot owning no pages must
        hold no KV length — a divergence means a reserve/grow/release path
        skipped its mirror write (``EngineConfig.debug_invariants`` asserts
        this at stage boundaries)."""
        bt = np.asarray(self.cache["block_tables"])
        lengths = np.asarray(self.cache["length"])
        for slot, pages in enumerate(self.tables):
            row = np.full((self.max_pages_per_slot,), -1, np.int32)
            row[: len(pages)] = pages
            if not np.array_equal(bt[slot], row):
                raise AssertionError(
                    f"slot {slot}: host block table {pages} diverged from "
                    f"device row {bt[slot].tolist()}"
                )
            if not pages and int(lengths[slot]) != 0:
                raise AssertionError(
                    f"slot {slot}: owns no pages but device KV length is "
                    f"{int(lengths[slot])}"
                )

    def check_refcounts(self) -> None:
        """Every page's allocator refcount must equal its owners as the
        manager sees them: one per block-table row it appears in, plus one
        if the prefix index holds it. A mismatch means a share/release path
        leaked or double-counted an owner (``EngineConfig.debug_invariants``
        asserts this at stage boundaries)."""
        expected = [0] * self.allocator.num_pages
        for pages in self.tables:
            for p in pages:
                expected[p] += 1
        if self.prefix_index is not None:
            for p in self.prefix_index.held_pages():
                expected[p] += 1
        for p, want in enumerate(expected):
            got = self.allocator.ref_count(p)
            if got != want:
                raise AssertionError(
                    f"page {p}: allocator refcount {got} != {want} owners "
                    f"(block-table rows + index hold)"
                )

    def sync_from_device(self) -> None:
        """Rebuild host tables + allocator from the device block table
        (checkpoint restore path — the device array is the durable record).
        Refcounts are rebuilt from block-table multiplicity (a page shared
        by k rows appears k times); the prefix index's holds are not part
        of the device record, so the index restarts cold."""
        bt = np.asarray(self.cache["block_tables"])
        self.tables = [[int(p) for p in row if p >= 0] for row in bt]
        self.allocator.reset([p for row in self.tables for p in row])
        if self.prefix_index is not None:
            self.prefix_index.invalidate()
        self.peak_pages = max(self.peak_pages, self.allocator.num_used)

    # -- accounting ---------------------------------------------------- #
    def kv_bytes_in_use(self) -> int:
        """Bytes of KV pool actually owned by slots right now."""
        return self.allocator.num_used * (
            self.kv_bytes_capacity() // self.allocator.num_pages
        )

    def kv_bytes_capacity(self) -> int:
        return self.cache["k"].nbytes + self.cache["v"].nbytes

    def peak_kv_bytes(self) -> int:
        """High-water mark of slot-owned KV bytes over the run."""
        if self.allocator.num_pages == 0:
            return 0
        return self.peak_pages * (self.kv_bytes_capacity() // self.allocator.num_pages)
