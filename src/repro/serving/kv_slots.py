"""Slot manager: the engine-side realization of the paper's "clients".

J slots ↔ the paper's J parallel clients. Each slot owns one row of the
batched KV cache (or recurrent state). The manager tracks host-side slot
state (free/active, request binding, emitted tokens) and provides the jitted
scatter that moves a packed prefill's cache rows into the main slot cache.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.types import Request

Tree = Any


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_cache(main: Tree, pref: Tree, slots: jax.Array) -> Tree:
    """Scatter prefill-cache rows (batch dim per leaf) into slot rows.

    Leaves with a leading layer dim have batch at axis 1 ("k"/"v" and
    recurrent states); rank-≤2 leaves ("length", ring "pos") carry batch at
    axis 0. The prefill cache's sequence axis (axis 2 of rank-≥3 leaves) may
    be a shorter bucket than the main cache — the target rows are zeroed and
    the bucket prefix written, so no stale data from a previous occupant
    survives; ring "pos" rows are padded with -1 (invalid) likewise.
    """

    def scatter(m, p):
        p = p.astype(m.dtype)
        if m.ndim == 1:
            return m.at[slots].set(p)
        if m.ndim == 2:
            if m.shape[1] != p.shape[1]:       # ring pos, shorter bucket
                pad = jnp.full((p.shape[0], m.shape[1] - p.shape[1]), -1, m.dtype)
                p = jnp.concatenate([p, pad], axis=1)
            return m.at[slots].set(p)
        if m.shape[2:] == p.shape[2:]:
            return m.at[:, slots].set(p)
        # seq axis (2) shorter in the prefill bucket: zero-fill then prefix
        z = jnp.zeros((m.shape[0], p.shape[1]) + m.shape[2:], m.dtype)
        z = z.at[:, :, : p.shape[2]].set(p)
        return m.at[:, slots].set(z)

    return jax.tree_util.tree_map(scatter, main, pref)


class SlotManager:
    def __init__(self, model, n_slots: int, max_len: int):
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = model.cache_init(n_slots, max_len)
        self.request_of: List[Optional[Request]] = [None] * n_slots
        self.emitted: List[int] = [0] * n_slots

    # ------------------------------------------------------------------ #
    @property
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.request_of) if r is None]

    @property
    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.request_of) if r is not None]

    def bind(self, slot: int, request: Request) -> None:
        if self.request_of[slot] is not None:
            raise RuntimeError(f"slot {slot} already bound")
        self.request_of[slot] = request
        self.emitted[slot] = 0

    def release(self, slot: int) -> Request:
        req = self.request_of[slot]
        if req is None:
            raise RuntimeError(f"slot {slot} not bound")
        self.request_of[slot] = None
        self.emitted[slot] = 0
        return req

    def merge_prefill(self, prefill_cache: Tree, slots: Sequence[int]) -> None:
        """Move a packed prefill's cache (batch = len(slots)) into the slot
        cache rows."""
        idx = jnp.asarray(list(slots), jnp.int32)
        self.cache = _scatter_cache(self.cache, prefill_cache, idx)

    def active_mask(self) -> jax.Array:
        return jnp.asarray(
            [r is not None for r in self.request_of], dtype=jnp.bool_
        )
