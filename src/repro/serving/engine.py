"""The serving engine: continuous batching under PD Competition, with the
paper's hybrid offline-online scheduler as the dispatch policy.

This is the real-execution counterpart of ``core.simulator`` — the same
``RequestScheduler`` (offline assignment / Algorithm 1 stealing) and
``IterationPolicy`` (prefill-first / Lagrangian) objects drive actual jitted
model steps:

  * a *prefill stage* packs ≤ 1 new request per idle slot (Eq. 16), pads to
    a bucket shape (the paper's levels ↔ jit compilation buckets), runs
    ``model.prefill`` and scatters the produced KV rows into the slot cache;
  * a *decode round* runs ``model.decode_step`` over all J slots (one token
    per active slot), exactly the paper's iteration granularity;
  * between rounds the iteration policy decides prefill-vs-decode using the
    online profiler's continuously refit cost model.

The engine emits the same ``ScheduleTrace`` as the simulator, so utilization
and Gantt accounting are directly comparable, and it can checkpoint/restore
mid-run (slot cache + queues + scheduler state) for fault tolerance.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.cost_model import CostModel
from ..core.iteration import CandidateBatch, IterationPolicy, SystemSnapshot
from ..core.online import RequestScheduler
from ..core.types import (
    ClientState,
    Request,
    ScheduleTrace,
    StageKind,
    StageRecord,
)
from .kv_slots import SlotManager
from .profiler import OnlineProfiler
from .sampler import greedy

Tree = Any


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 8
    max_len: int = 256
    prefill_seq_buckets: Tuple[int, ...] = (32, 64, 128)
    prefill_req_buckets: Tuple[int, ...] = (1, 2, 4, 8)
    eos_id: Optional[int] = None          # None → workload-driven stop
    max_stages: int = 200_000
    # Straggler mitigation: a prefill stage measuring > straggler_factor ×
    # the cost model's prediction halves the packing budget for subsequent
    # stages (smaller stages bound the blast radius of a slow node); the
    # budget recovers by one step per on-prediction stage.
    straggler_factor: float = 3.0


def _bucket(x: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if x <= b:
            return b
    return buckets[-1]


class Engine:
    def __init__(
        self,
        model,
        params: Tree,
        config: EngineConfig,
        profiler: Optional[OnlineProfiler] = None,
        sampler: Callable = greedy,
    ):
        self.model = model
        self.params = params
        self.cfg = config
        self.profiler = profiler or OnlineProfiler()
        self.sampler = sampler
        self.slots = SlotManager(model, config.n_slots, config.max_len)
        self.pending_token = np.zeros(config.n_slots, dtype=np.int32)
        self._budget_shift = 0            # straggler mitigation state
        self.straggler_events = 0

        self._decode_jit = jax.jit(
            lambda p, t, c: model.decode_step(p, t, c), donate_argnums=(2,)
        )
        self._prefill_jit = jax.jit(
            lambda p, t, c, l: model.prefill(p, t, c, lengths=l),
            donate_argnums=(2,),
        )

    # ------------------------------------------------------------------ #
    def _run_prefill_stage(self, pairs: List[Tuple[ClientState, Request]]):
        """Execute one packed prefill; returns (duration_s, total_tokens)."""
        reqs = [r for _, r in pairs]
        slots = [c.cid for c, _ in pairs]
        max_len = max(r.n_prefill for r in reqs)
        s_pad = _bucket(max_len, self.cfg.prefill_seq_buckets)
        n_pad = _bucket(len(reqs), self.cfg.prefill_req_buckets)
        tokens = np.zeros((n_pad, s_pad), dtype=np.int32)
        lengths = np.ones(n_pad, dtype=np.int32)
        for i, r in enumerate(reqs):
            # synthetic prompt tokens derived from the request id (demo data;
            # a production engine receives the tokenized prompt here)
            rng = np.random.default_rng(r.rid)
            tokens[i, : r.n_prefill] = rng.integers(
                1, self._vocab(), size=r.n_prefill
            )
            lengths[i] = r.n_prefill
        cache = self.model.cache_init(n_pad, s_pad)
        t0 = time.perf_counter()
        logits, pref_cache = self._prefill_jit(
            self.params, jnp.asarray(tokens), cache, jnp.asarray(lengths)
        )
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        first = np.asarray(self.sampler(logits))
        # scatter only the real rows (the batch was padded to a bucket)
        real_cache = jax.tree_util.tree_map(
            lambda x: x[:, : len(slots)] if x.ndim >= 3 else x[: len(slots)],
            pref_cache,
        )
        self.slots.merge_prefill(real_cache, slots)
        for i, (client, req) in enumerate(pairs):
            self.slots.bind(client.cid, req)
            self.slots.emitted[client.cid] = 1     # prefill samples token #1
            self.pending_token[client.cid] = int(first[i])
            client.current = req
        total_tokens = sum(r.n_prefill for r in reqs)
        self.profiler.record_prefill(total_tokens, dt)
        # straggler mitigation (request-level stealing is Algorithm 1's job;
        # this handles slow *stages*)
        predicted = self.profiler.cost_model.prefill_time(total_tokens)
        if predicted > 0 and dt > self.cfg.straggler_factor * predicted:
            self._budget_shift = min(self._budget_shift + 1, 3)
            self.straggler_events += 1
        elif self._budget_shift > 0 and dt < 1.5 * predicted:
            self._budget_shift -= 1
        return dt, total_tokens

    def _vocab(self) -> int:
        return self.model.cfg.vocab_size

    def _run_decode_round(self) -> Tuple[float, List[int]]:
        """One decode round over all slots; returns (duration, finished slots)."""
        tokens = jnp.asarray(self.pending_token)
        t0 = time.perf_counter()
        logits, self.slots.cache = self._decode_jit(
            self.params, tokens, self.slots.cache
        )
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        nxt = np.asarray(self.sampler(logits))
        finished = []
        for slot in self.slots.active_slots:
            req = self.slots.request_of[slot]
            self.slots.emitted[slot] += 1
            self.pending_token[slot] = int(nxt[slot])
            req.decoded = self.slots.emitted[slot]
            done = (
                self.cfg.eos_id is not None and int(nxt[slot]) == self.cfg.eos_id
            ) or (self.cfg.eos_id is None and self.slots.emitted[slot] >= req.n_decode)
            if done:
                finished.append(slot)
        n_active = len(self.slots.active_slots)
        self.profiler.record_decode(n_active, dt)
        return dt, finished

    # ------------------------------------------------------------------ #
    def serve(
        self,
        requests: Sequence[Request],
        clients: List[ClientState],
        request_scheduler: RequestScheduler,
        iteration_policy: IterationPolicy,
        policy_name: str = "",
    ) -> ScheduleTrace:
        """Serve a request set to completion; returns the execution trace."""
        cfg = self.cfg
        if len(clients) != cfg.n_slots:
            raise ValueError("clients must match n_slots")
        trace = ScheduleTrace(
            num_clients=cfg.n_slots,
            requests=list(requests),
            policy_name=policy_name or f"engine/{iteration_policy.name}",
        )
        for r in requests:
            r.reset()
        t = 0.0
        bin_index = -1

        for _ in range(cfg.max_stages):
            max_cap = max(
                self.profiler.cost_model.max_level.cap_tokens >> self._budget_shift,
                self.profiler.cost_model.level_caps[0],
            )
            active = [c for c in clients if c.current is not None]
            idle = [c for c in clients if c.current is None]
            if not active and not request_scheduler.has_pending():
                break
            pairs = request_scheduler.propose_batch(idle, max_cap)
            candidate = CandidateBatch(
                requests=[r for _, r in pairs],
                client_ids=[c.cid for c, _ in pairs],
            )
            snap = SystemSnapshot(
                n_clients=cfg.n_slots,
                n_active=len(active),
                n_idle=len(idle),
                active_remaining_est=sum(
                    max(0, (c.current.n_decode_est or 0) - c.current.decoded)
                    for c in active
                ),
                pending_requests=request_scheduler.pending_count(),
                candidate=candidate,
                now=t,
            )
            t0 = time.perf_counter()
            do_prefill = iteration_policy(snap, self.profiler.cost_model)
            trace.decision_times_ms.append((time.perf_counter() - t0) * 1e3)

            if do_prefill and candidate:
                request_scheduler.commit_batch(pairs)
                bin_index += 1
                dt, tok = self._run_prefill_stage(pairs)
                busy = {}
                for client, req in pairs:
                    req.client = client.cid
                    req.prefill_bin = bin_index
                    req.t_prefill_start = t
                    req.t_prefill_end = t + dt
                    req.decoded = 1
                    busy[client.cid] = req.rid
                trace.stages.append(
                    StageRecord(
                        kind=StageKind.PREFILL,
                        t_start=t, t_end=t + dt,
                        bin_index=bin_index, busy=busy, tokens=tok,
                        level=self.profiler.cost_model.level_for(
                            min(tok, max_cap)
                        ).index,
                    )
                )
                t += dt
                # requests with n_decode == 1 finish at prefill
                for client, req in pairs:
                    if self.cfg.eos_id is None and req.n_decode <= 1:
                        req.t_done = t
                        self.slots.release(client.cid)
                        client.current = None
            elif active:
                dt, finished = self._run_decode_round()
                busy = {
                    c.cid: c.current.rid for c in active if c.current is not None
                }
                trace.stages.append(
                    StageRecord(
                        kind=StageKind.DECODE,
                        t_start=t, t_end=t + dt,
                        bin_index=max(bin_index, 0), busy=busy,
                        tokens=len(active), rounds=1,
                    )
                )
                t += dt
                for slot in finished:
                    req = self.slots.release(slot)
                    req.t_done = t
                    clients[slot].current = None
            else:
                if candidate:
                    continue  # policy refused but nothing to decode: retry
                raise RuntimeError("engine deadlock: pending but no candidate")
        else:
            raise RuntimeError("max_stages exceeded")
        trace.validate()
        return trace

    # ------------------------------------------------------------------ #
    # Checkpoint / restore (fault tolerance)                              #
    # ------------------------------------------------------------------ #
    def state_dict(self) -> Dict[str, Any]:
        return {
            "cache": jax.tree_util.tree_map(np.asarray, self.slots.cache),
            "request_of": [
                (r.rid if r is not None else -1) for r in self.slots.request_of
            ],
            "emitted": list(self.slots.emitted),
            "pending_token": self.pending_token.copy(),
        }

    def load_state_dict(self, state: Dict[str, Any], requests_by_rid) -> None:
        self.slots.cache = jax.tree_util.tree_map(
            jnp.asarray, state["cache"]
        )
        self.slots.request_of = [
            (requests_by_rid[rid] if rid >= 0 else None)
            for rid in state["request_of"]
        ]
        self.slots.emitted = list(state["emitted"])
        self.pending_token = np.asarray(state["pending_token"], dtype=np.int32)
