"""The serving engine: continuous batching under PD Competition, with the
paper's hybrid offline-online scheduler as the dispatch policy.

This is the real-execution counterpart of ``core.simulator`` — the same
``RequestScheduler`` (offline assignment / Algorithm 1 stealing) and
``IterationPolicy`` (prefill-first / Lagrangian) objects drive actual jitted
model steps:

  * a *prefill stage* packs ≤ 1 new request per idle slot (Eq. 16), pads to
    a bucket shape (the paper's levels ↔ jit compilation buckets), runs
    ``model.prefill`` and scatters the produced KV rows into the slot cache;
  * a *decode round* runs ``model.decode_step`` over all J slots (one token
    per active slot), exactly the paper's iteration granularity;
  * between rounds the iteration policy decides prefill-vs-decode using the
    online profiler's continuously refit cost model.

With ``kv_layout="paged"`` the execution layer swaps to a paged KV pool
(``PagedSlotManager`` + block tables) and *chunked prefill*: prompts are
split into ``prefill_chunk``-token chunks written directly into the slot's
pages by ``model.prefill_chunk`` — no per-prefill throwaway cache, no padded
full-row scatter — and the iteration policy prices inserting *one chunk
round* (``CandidateBatch.chunk_tokens``) instead of a whole prompt, so
decode rounds interleave between a long prompt's chunks instead of stalling
behind it. KV memory is pages-in-use rather than n_slots × max_len, with
admission control against the page pool.

With ``mixed_schedule=True`` (the default for paged layouts) the
prefill-stage / decode-stage *alternation disappears*: whenever prefill work
is pending alongside active decoders, the engine dispatches ONE mixed batch
per iteration (``model.mixed_step``) containing the decode tokens of every
active slot plus a policy-priced share of prefill-chunk tokens written
straight into the paged pool — prefill piggybacks on decode instead of
preempting it. The iteration policy's ``prefill_share`` prices the marginal
chunk token (decode-latency inflation per co-scheduled prefill token, from
the cost model's separable mixed fit t(n_decode, n_prefill_tokens)) instead
of making the paper's binary stage choice, and ``prefill_stall_time`` — the
wall-clock decoders spend frozen behind preempting prefills — goes to ~0 by
construction. Iterations with no prefill in view still take the fused
multi-step decode fast path below.

Decode runs as *fused multi-step stages*: instead of paying one host↔device
round trip per decoded token (dispatch → ``block_until_ready`` → host argmax
→ re-upload), the engine commits to a decode *horizon* of K iterations and
dispatches ONE jitted call (``model.decode_steps``) that loops attention +
KV append + on-device sampling, keeping ``pending_token`` and per-slot stop
state device-resident and syncing to host only at the horizon boundary. The
iteration policy prices K from the cost model (amortized dispatch cost vs
the expected regret of delaying a prefill insertion mid-horizon), and the
online profiler learns per-horizon timings so K adapts to the hardware. A
slot that hits its stop condition mid-horizon becomes a no-op inside the
fused loop rather than forcing an early exit. ``max_decode_horizon=1``
reproduces the legacy per-token loop exactly.

The engine emits the same ``ScheduleTrace`` as the simulator, so utilization
and Gantt accounting are directly comparable, and it can checkpoint/restore
mid-run (slot cache + queues + scheduler state) for fault tolerance.

Serving is *step-driven*: ``serve()`` is a loop over ``begin_serve`` /
``serve_step`` / ``finish_serve``, and a ``serving.fleet.Fleet`` drives many
engines' sessions interleaved in virtual time instead — always stepping the
lowest-clock replica, pushing externally-dispatched arrivals and stolen
requests into the session's scheduler between stages.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.cost_model import CostModel
from ..core.iteration import CandidateBatch, IterationPolicy, SystemSnapshot
from ..core.online import RequestScheduler
from ..core.types import (
    ClientState,
    Request,
    ScheduleTrace,
    StageKind,
    StageRecord,
)
from .kv_slots import PagedSlotManager, SlotManager
from .overload import OverloadPolicy
from .profiler import OnlineProfiler
from .sampler import fold_row_keys, greedy

Tree = Any


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 8
    max_len: int = 256
    prefill_seq_buckets: Tuple[int, ...] = (32, 64, 128)
    prefill_req_buckets: Tuple[int, ...] = (1, 2, 4, 8)
    eos_id: Optional[int] = None          # None → workload-driven stop
    max_stages: int = 200_000
    # Straggler mitigation: a prefill stage measuring > straggler_factor ×
    # the cost model's prediction halves the packing budget for subsequent
    # stages (smaller stages bound the blast radius of a slow node); the
    # budget recovers by one step per on-prediction stage.
    straggler_factor: float = 3.0
    # KV layout. "dense" preallocates one max_len row per slot and prefills
    # whole (padded) prompts; "paged" shares a pool of page_size-token pages
    # through block tables and prefills in prefill_chunk-token chunks written
    # directly into the slot's pages — decode rounds can interleave between a
    # long prompt's chunks, and KV memory is pages-in-use, not
    # n_slots × max_len. num_pages=None sizes the pool to full capacity;
    # smaller pools trade memory for admission backpressure.
    kv_layout: str = "dense"              # "dense" | "paged"
    page_size: int = 16
    prefill_chunk: int = 32
    num_pages: Optional[int] = None
    # Page reservation discipline (paged layout). "ondemand" grants a new
    # request pages for its *prompt* only and grows the slot page-by-page as
    # decode crosses page boundaries; when the pool genuinely exhausts, the
    # engine preempts the lowest-priority slot — deallocates its pages and
    # re-queues the request with its generated prefix for recompute-on-resume
    # (token streams stay bit-identical: the sampler is a pure function of
    # (seed, rid, token index) and the pre-preemption tokens are restored,
    # never re-sampled). "upfront" reserves prompt + decode bound at
    # admission — no preemption can ever be needed, but the over-reservation
    # backpressures admission long before the pool is actually full; kept as
    # the ablation baseline for benchmarks/overload.py.
    page_reserve: str = "ondemand"        # "ondemand" | "upfront"
    # Fused decode. Each decode stage runs one on-device loop of K
    # iterations (one dispatch, one host sync). ``max_decode_horizon`` caps
    # the policy-priced K; 1 reproduces the per-token baseline exactly.
    # ``decode_horizon`` pins K instead of asking the policy (benchmarks /
    # ablations). Horizons are bucketed down to powers of two so at most
    # log2(K_max)+1 jit variants ever compile, and capped by the largest
    # remaining decode budget so the drain tail never runs all-no-op rounds.
    max_decode_horizon: int = 8
    decode_horizon: Optional[int] = None
    # Mixed-step scheduling (paged layout only). True collapses the
    # prefill-stage / decode-stage alternation into continuous batching:
    # every iteration with prefill work pending dispatches ONE mixed batch
    # (``model.mixed_step``) holding the decode tokens of all active slots
    # plus up to ``prefill_share`` prefill-chunk tokens written straight
    # into the paged pool — prefill piggybacks on decode instead of
    # preempting it, so ``prefill_stall_time`` goes to ~0 by construction.
    # Pure-decode iterations still take the fused ``decode_steps`` fast
    # path. False restores the alternating loop (the ablation baseline in
    # ``benchmarks/mixed_batch.py``); dense layouts always alternate.
    mixed_schedule: bool = True
    # Quantization levels for the chunk-token share of a mixed round (the
    # mixed analogue of the paper's prefill levels): the policy's priced
    # share rounds down to a bucket, and the largest entry caps the budget
    # it may price at all — bounding the worst-case decode-latency
    # inflation a single round can absorb (a small cap protects burst p95,
    # a large one drains prefill faster). Jit shapes are NOT driven by this
    # table — a mixed dispatch is always (n_slots decode lanes) +
    # (prefill_req_buckets rows × prefill_chunk), the same rectangles the
    # alternating chunk rounds compile.
    mixed_token_buckets: Tuple[int, ...] = (16, 32, 64, 128, 256)
    # PRNG seed for stochastic samplers. Token streams are reproducible as a
    # pure function of (seed, request id, token index) — independent of
    # horizon grouping, slot placement, batch composition, or KV layout.
    sample_seed: int = 0
    # Invariant checking (paged layout): assert allocator free-list/free-set
    # consistency plus the host↔device block-table mirror at every stage
    # boundary and every migration export/import. Each check costs a device
    # sync, so it must stay out of timed regions: None resolves from the
    # REPRO_DEBUG_INVARIANTS env var — the test suite turns it on globally
    # (tests/conftest.py), benchmarks leave it off.
    debug_invariants: Optional[bool] = None
    # Prefix caching (paged layout only). True keeps a content-addressed
    # index over completed prompts' FULL KV pages (chained page hashing, à
    # la vLLM): a new prompt sharing a prefix with a cached one adopts the
    # matching pages read-only (refcounted) and copy-on-writes the page
    # holding its first divergent token, so chunked prefill starts at the
    # first uncached token. The index holds one reference per published
    # page; held pages are reclaimed LRU-leaf-first when the free list
    # can't fund an allocation, so a warm cache never deadlocks admission.
    prefix_cache: bool = False
    # Price scheduling by UNCACHED prefill tokens (the work actually
    # computed) instead of nominal prompt length. False is the cache-blind
    # ablation: the cache still serves hits, but the Lagrangian prefill
    # share and the offline packer see full prompt lengths.
    cache_aware_pricing: bool = True
    # Observability sink (a ``repro.obs.Observation``). None — the default —
    # is the zero-cost path: every emission site in the serve loop guards on
    # a single ``is not None`` and a disabled serve executes zero obs
    # callbacks (tests enforce this via Observation.tripwire). Benches and
    # traced serves pass one instance; a Fleet shares the engine config's
    # instance across every replica so request spans chain causally through
    # migrations. One Observation records exactly one serve.
    observe: Optional[Any] = None


# Declarations for the typed metrics registry mirroring the engine's
# ``trace.meta`` counters (units + help text; keys not listed here default
# to unit-less counters). ``summary()`` output is unchanged — the registry
# is the typed, documented view over the same numbers.
_METRIC_SPECS: Dict[str, Tuple[str, str, str]] = {
    "mixed_rounds": ("counter", "", "mixed prefill+decode rounds dispatched"),
    "prefill_stall_time_s": (
        "counter", "s",
        "wall-clock decoders spent frozen behind preempting prefill stages",
    ),
    "decode_dispatches": ("counter", "", "fused decode dispatches"),
    "preemption_events": ("counter", "", "slots preempted by page eviction"),
    "peak_concurrency": (
        "gauge", "", "peak simultaneously in-flight requests on one replica",
    ),
    "offline_deferrals": (
        "counter", "", "offline admissions deferred by overload control",
    ),
    "recomputed_tokens": (
        "counter", "tokens", "tokens re-prefilled on recompute-on-resume",
    ),
    "migrations_in": ("counter", "", "slots imported by page-copy migration"),
    "migrations_out": ("counter", "", "slots exported by page-copy migration"),
    "cached_prefill_tokens": (
        "counter", "tokens", "prompt tokens served from the prefix cache",
    ),
    "shared_pages_peak": (
        "gauge", "pages", "peak KV pages shared read-only across slots",
    ),
    "cow_copies": ("counter", "pages", "copy-on-write page copies"),
    "decoded_tokens": ("counter", "tokens", "tokens decoded"),
}


def _bucket(x: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if x <= b:
            return b
    raise ValueError(
        f"value {x} exceeds the largest bucket {buckets[-1]}; a request "
        f"padded into it would silently overflow the batch — raise the "
        f"bucket table (EngineConfig.prefill_seq_buckets / "
        f"prefill_req_buckets) to cover the workload"
    )


@dataclasses.dataclass
class _ServeSession:
    """Host-side state of one in-progress serve (the step-driven API).

    ``Engine.serve`` is a loop over ``serve_step``; a ``Fleet`` drives many
    engines' sessions interleaved in virtual time instead. ``t`` is the
    session's stage clock (sum of measured stage durations plus any arrival
    fast-forwards), which is what "replicas run in parallel" means for
    fleet accounting: every replica's clock starts at 0.
    """

    trace: ScheduleTrace
    clients: List[ClientState]
    scheduler: RequestScheduler
    policy: IterationPolicy
    t: float = 0.0
    bin_index: int = -1
    stages_run: int = 0
    # adopt requests into the trace as the scheduler commits them (fleet
    # dispatch and work stealing route requests in mid-serve, so the final
    # request set is discovered, not declared)
    track_requests: bool = False


@dataclasses.dataclass
class _ChunkState:
    """One slot's in-flight chunked prefill (paged layout only).

    A *resumed* (previously preempted) request recomputes prompt + generated
    prefix in one pass: ``prompt`` then holds n_prefill + emitted - 1 tokens,
    and the final chunk restores ``(resume_emitted, resume_pending)`` instead
    of sampling — re-sampling the already-emitted token would risk
    FP divergence for zero benefit, so the stream continues bit-identical to
    an unpreempted serve."""

    slot: int
    req: Request
    prompt: np.ndarray
    done: int = 0
    resume_emitted: int = 0               # >0 → recompute of a preemptee
    resume_pending: int = -1              # pending token to restore at bind
    cached: int = 0                       # prompt tokens adopted from cache

    @property
    def total(self) -> int:
        return len(self.prompt)

    @property
    def remaining(self) -> int:
        return self.total - self.done


@dataclasses.dataclass
class SlotCheckpoint:
    """Portable mid-request slot state for live KV migration by page-copy.

    ``Engine.export_slot`` gathers everything a destination engine needs to
    continue a request bit-identically with ZERO recomputed tokens: the
    slot's KV pages (gathered out of the source pool, page-id-agnostic),
    the pending token awaiting its next decode round, the sampler cursor
    (``emitted`` — sampling is a pure function of (seed, rid, token index),
    so the destination resumes the exact stream), the generated-so-far
    prefix (output record + budget bookkeeping), and mid-chunk prefill
    progress for requests migrated before their prompt finished.

    ``prefill_credit`` is the number of prefill completions the request has
    performed on OTHER traces so far: a bound slot has completed all
    ``1 + preemptions`` it will ever need; a mid-chunk prefill has completed
    ``preemptions`` (its current pass is still in flight). The importer
    records it in ``ScheduleTrace.external_prefills`` so exactly-once
    prefill accounting validates on both sides of the move.

    ``checksum`` is the KV payload's content CRC, computed at
    ``export_pages`` and verified at ``import_pages`` — a corrupted
    transfer raises ``PageIntegrityError`` instead of silently resuming a
    poisoned stream. ``src_replica``/``src_epoch`` are the exporter's
    ``(replica, epoch)`` lease, stamped by the fleet: an export from an
    epoch that has since been fenced (the source was condemned mid-flight)
    is discarded at the fleet layer, never imported."""

    req: Request
    kind: str                             # "bound" | "chunking"
    emitted: int                          # sampler cursor (bound slots)
    pending_token: int                    # next decode round's input token
    kv_length: int                        # valid KV entries in the payload
    k_pages: Any                          # (L, KV, n_pages, page_size, D)
    v_pages: Any
    n_pages: int
    prefix: List[int]                     # every token generated so far
    prefill_credit: int
    # mid-chunk prefill progress (kind == "chunking" only)
    chunk_done: int = 0
    resume_emitted: int = 0
    resume_pending: int = -1
    # KV payload integrity (None = exporter predates checksums)
    checksum: Optional[int] = None
    # (replica, epoch) lease of the exporter (fleet-stamped; -1 = unset)
    src_replica: int = -1
    src_epoch: int = -1


def _fused_decode(
    model, sampler, eos_id,
    num_steps, params, tokens, cache, active, budgets, rids, token_idx0,
    base_key,
):
    """Jit target for the fused decode stage (module-level so the partial
    closing over (model, sampler, eos_id) hashes stably across calls)."""
    return model.decode_steps(
        params, tokens, cache,
        num_steps=num_steps, sampler=sampler, active=active, budgets=budgets,
        rids=rids, token_idx0=token_idx0, base_key=base_key, eos_id=eos_id,
    )


def _mixed_dispatch(
    model, sampler,
    params, dec_tokens, cache, chunk_tokens, chunk_slots, chunk_starts,
    chunk_lens, dec_active, rids, token_idx, sample_rows, base_key,
):
    """Jit target for the mixed prefill+decode stage (module-level for the
    same stable-hash reason as ``_fused_decode``)."""
    return model.mixed_step(
        params, dec_tokens, cache, chunk_tokens, chunk_slots, chunk_starts,
        chunk_lens, sampler=sampler, dec_active=dec_active, rids=rids,
        token_idx=token_idx, sample_rows=sample_rows, base_key=base_key,
    )


class Engine:
    def __init__(
        self,
        model,
        params: Tree,
        config: EngineConfig,
        profiler: Optional[OnlineProfiler] = None,
        sampler: Callable = greedy,
        speed_factor: float = 1.0,
        overload_policy: Optional[OverloadPolicy] = None,
    ):
        self.model = model
        self.params = params
        self.cfg = config
        self.profiler = profiler or OnlineProfiler()
        self.sampler = sampler
        if config.page_reserve not in ("ondemand", "upfront"):
            raise ValueError(f"unknown page_reserve {config.page_reserve!r}")
        # Admission-side overload control (None = admit everything the
        # scheduler proposes; see serving.overload for the SLO-aware policy).
        self.overload = overload_policy
        # Relative machine speed for virtual-time accounting: every measured
        # stage duration divides by this before it reaches the session
        # clock, the trace, and the profiler. 1.0 is a no-op (the default,
        # bare-engine case); a heterogeneous Fleet sets it per replica so a
        # mixed-generation fleet is emulatable — and its scheduling
        # decisions deterministically testable — on one host: a
        # speed_factor=0.5 replica *is* a machine whose stages take twice
        # as long, as far as every scheduler and profiler can observe.
        if speed_factor <= 0:
            raise ValueError("speed_factor must be positive")
        self.speed_factor = float(speed_factor)
        if config.prefix_cache and config.kv_layout != "paged":
            raise ValueError(
                "prefix_cache requires kv_layout='paged' — dense layout has "
                "no page identity to share"
            )
        if config.kv_layout == "paged":
            self.slots: Any = PagedSlotManager(
                model, config.n_slots, config.max_len,
                config.page_size, config.num_pages,
                prefix_cache=config.prefix_cache,
            )
            self._chunk_jit = jax.jit(
                lambda p, t, c, s, st, ln: model.prefill_chunk(p, t, c, s, st, ln),
                donate_argnums=(2,),
            )
            self._mixed_jit = jax.jit(
                functools.partial(_mixed_dispatch, model, sampler),
                donate_argnums=(2,),
            )
        elif config.kv_layout == "dense":
            self.slots = SlotManager(model, config.n_slots, config.max_len)
            self._prefill_jit = jax.jit(
                lambda p, t, c, l: model.prefill(p, t, c, lengths=l),
                donate_argnums=(2,),
            )
        else:
            raise ValueError(f"unknown kv_layout {config.kv_layout!r}")
        # Stochastic samplers draw per-row keys folded from this base key;
        # greedy engines carry no key (None short-circuits key plumbing).
        self._base_key = (
            jax.random.key(config.sample_seed)
            if getattr(sampler, "stochastic", False) else None
        )
        # ONE decode path for both layouts and every horizon: a fused
        # K-iteration on-device loop (K static → one executable per horizon
        # bucket). The cache is donated, so K-step decode updates it in
        # place; tokens stay on device until the horizon boundary.
        self._fused_jit = jax.jit(
            functools.partial(
                _fused_decode, model, sampler, config.eos_id
            ),
            static_argnums=(0,),
            donate_argnums=(3,),
        )
        self.pending_token = np.zeros(config.n_slots, dtype=np.int32)
        # Device-side copy of pending tokens, carried between consecutive
        # decode stages so back-to-back horizons never re-upload (None →
        # stale, rebuild from the host array; prefills invalidate it).
        self._dev_pending: Optional[jax.Array] = None
        # dispatch accounting (the quantity this subsystem optimizes; each
        # dispatch implies exactly one host sync at its horizon boundary)
        self.decode_dispatches = 0
        self.decoded_tokens = 0
        # mixed-step accounting: mixed rounds dispatched, and the wall-clock
        # decoders spent frozen behind a preempting prefill stage (only the
        # alternating path can accumulate it — in mixed mode the stall is
        # structurally impossible, which is the point)
        self.mixed_rounds = 0
        self.prefill_stall_time = 0.0
        self._budget_shift = 0            # straggler mitigation state
        self.straggler_events = 0
        self._chunking: Dict[int, _ChunkState] = {}
        # Preemption-by-eviction bookkeeping: rids whose generated prefix
        # must be recomputed on (re-)admission, and overload counters.
        self._resume_rids: set = set()
        self.preemption_events = 0
        self.offline_deferrals = 0
        # Recovery/migration accounting. ``recomputed_tokens`` counts every
        # token re-prefilled on a recompute-on-resume pass (prompt + restored
        # prefix — work that had already been paid for once); page-copy
        # migration contributes zero here by construction, which is what the
        # chaos bench hard-gates. ``migrated_pages_in/out`` count KV pages
        # that physically moved through export/import.
        self.recomputed_tokens = 0
        self.migrated_pages_in = 0
        self.migrated_pages_out = 0
        self.migrations_in = 0
        self.migrations_out = 0
        # Prefix-cache accounting: prompt tokens served from cached KV pages
        # instead of being computed (adoption at admission).
        self.cache_hit_tokens = 0
        self._use_prefix_cache = (
            config.kv_layout == "paged" and config.prefix_cache
        )
        # Stage-boundary invariant checks (see EngineConfig.debug_invariants)
        self.debug_invariants = (
            config.debug_invariants
            if config.debug_invariants is not None
            else os.environ.get("REPRO_DEBUG_INVARIANTS", "") == "1"
        )
        # High-water mark of simultaneously in-flight requests (bound slots
        # + mid-chunk prefills) — the admission-concurrency metric the
        # on-demand-vs-upfront reservation comparison is judged on.
        self.peak_concurrency = 0
        # Observability (repro.obs.Observation). None (the default) keeps
        # every emission site dead; a Fleet overwrites obs_replica with the
        # engine's replica index after construction.
        self.obs = config.observe
        self.obs_replica = 0
        if config.kv_layout == "paged":
            self.slots.obs = self.obs
        # migrated-in slots awaiting their first post-import dispatch —
        # capacity attribution classifies that wait as "migration"
        self._mig_pending: set = set()
        # rid -> every token this engine sampled for it (parity testing and
        # the place a production engine would stream detokenized output from)
        self.generated: Dict[int, List[int]] = {}
        # the open step-driven serve session (begin_serve → serve_step*
        # → finish_serve); ``serve()`` owns it for closed-loop runs, a
        # Fleet drives it directly for interleaved multi-replica serving
        self._sv: Optional[_ServeSession] = None

    # ------------------------------------------------------------------ #
    def _prompt_tokens(self, req: Request) -> np.ndarray:
        """Synthetic prompt tokens derived from the request id (demo data; a
        production engine receives the tokenized prompt here).

        Requests carrying a ``prefix_group`` share their first
        ``prefix_len`` tokens — derived from the group id, not the rid — so
        shared-prefix workloads (system prompts, few-shot templates) exist
        at the token level and survive migration/restore: the prompt is
        reconstructible from the ``Request`` alone on any replica."""
        n = req.n_prefill
        if req.prefix_group is not None and req.prefix_len > 0:
            head_rng = np.random.default_rng(10_000_019 + req.prefix_group)
            head = head_rng.integers(
                1, self._vocab(), size=req.prefix_len
            ).astype(np.int32)
            tail_rng = np.random.default_rng(req.rid)
            tail = tail_rng.integers(
                1, self._vocab(), size=n - req.prefix_len
            ).astype(np.int32)
            return np.concatenate([head, tail])
        rng = np.random.default_rng(req.rid)
        return rng.integers(1, self._vocab(), size=n).astype(np.int32)

    def _sample_first(self, logits, rids: Sequence[int]) -> np.ndarray:
        """Sample each prefill row's first token (token index 0 of its
        request). Per-row keys fold (seed, rid, 0) — the same derivation the
        fused decode loop uses for later indices, so the stream is seamless."""
        if self._base_key is None:
            return np.asarray(self.sampler(logits))
        n_pad = logits.shape[0]
        rid_vec = np.full(n_pad, -1, np.int32)     # pad rows sample garbage
        rid_vec[: len(rids)] = rids
        keys = fold_row_keys(
            self._base_key, jnp.asarray(rid_vec), jnp.zeros(n_pad, jnp.int32)
        )
        return np.asarray(self.sampler(logits, keys))

    def _observe_prefill(self, total_tokens: int, dt: float) -> None:
        """Feed the profiler and run straggler mitigation (request-level
        stealing is Algorithm 1's job; this handles slow *stages*)."""
        self.profiler.record_prefill(total_tokens, dt)
        predicted = self.profiler.cost_model.prefill_time(total_tokens)
        if predicted > 0 and dt > self.cfg.straggler_factor * predicted:
            self._budget_shift = min(self._budget_shift + 1, 3)
            self.straggler_events += 1
        elif self._budget_shift > 0 and dt < 1.5 * predicted:
            self._budget_shift -= 1

    def _run_prefill_stage(self, pairs: List[Tuple[ClientState, Request]]):
        """Execute one packed prefill; returns (duration_s, total_tokens)."""
        reqs = [r for _, r in pairs]
        slots = [c.cid for c, _ in pairs]
        max_len = max(r.n_prefill for r in reqs)
        s_pad = _bucket(max_len, self.cfg.prefill_seq_buckets)
        n_pad = _bucket(len(reqs), self.cfg.prefill_req_buckets)
        tokens = np.zeros((n_pad, s_pad), dtype=np.int32)
        lengths = np.ones(n_pad, dtype=np.int32)
        for i, r in enumerate(reqs):
            tokens[i, : r.n_prefill] = self._prompt_tokens(r)
            lengths[i] = r.n_prefill
        cache = self.model.cache_init(n_pad, s_pad)
        t0 = time.perf_counter()
        logits, pref_cache = self._prefill_jit(
            self.params, jnp.asarray(tokens), cache, jnp.asarray(lengths)
        )
        logits.block_until_ready()
        dt = (time.perf_counter() - t0) / self.speed_factor
        first = self._sample_first(logits, [r.rid for r in reqs])
        self._dev_pending = None          # prefill rewrites pending tokens
        # scatter only the real rows (the batch was padded to a bucket)
        real_cache = jax.tree_util.tree_map(
            lambda x: x[:, : len(slots)] if x.ndim >= 3 else x[: len(slots)],
            pref_cache,
        )
        self.slots.merge_prefill(real_cache, slots)
        for i, (client, req) in enumerate(pairs):
            self.slots.bind(client.cid, req)
            self.slots.emitted[client.cid] = 1     # prefill samples token #1
            self.pending_token[client.cid] = int(first[i])
            self.generated.setdefault(req.rid, []).append(int(first[i]))
            client.current = req
        self._note_concurrency()
        total_tokens = sum(r.n_prefill for r in reqs)
        self._observe_prefill(total_tokens, dt)
        return dt, total_tokens

    def _vocab(self) -> int:
        return self.model.cfg.vocab_size

    # ------------------------------------------------------------------ #
    # Chunked prefill (paged layout)                                      #
    # ------------------------------------------------------------------ #
    def _tokens_bound(self, req: Request) -> int:
        """KV tokens a request can touch over its lifetime: prompt plus the
        decode bound (known output length when the workload drives stops, the
        slot capacity otherwise). Decode round k writes KV position
        n_prefill + k - 1 and the last round only samples, hence the -1."""
        if self.cfg.eos_id is None:
            tokens = req.n_prefill + max(req.n_decode - 1, 0)
        else:
            tokens = self.cfg.max_len
        return min(tokens, self.cfg.max_len)

    def _prompt_total(self, req: Request) -> int:
        """Tokens the request's next (re)prefill will write: the prompt,
        plus — for a preempted request — its recomputed generated prefix
        (emitted - 1 tokens; the last generated token is restored as the
        pending token, never prefilled)."""
        extra = 0
        if req.rid in self._resume_rids:
            extra = max(len(self.generated.get(req.rid, ())) - 1, 0)
        return req.n_prefill + extra

    def _pages_needed(self, req: Request) -> int:
        """Pages admission must secure now: the whole lifetime bound under
        up-front reservation, just the (re)prefill span under on-demand
        paging (decode grows page-by-page later)."""
        if self.cfg.page_reserve == "upfront":
            return self.slots.allocator.pages_for(self._tokens_bound(req))
        return self.slots.allocator.pages_for(self._prompt_total(req))

    def _deadline_class(self, req: Request) -> int:
        """Admission priority class: 1 = online arrival carrying a TTFT
        deadline, 0 = everything else (offline backlog, no-SLO online)."""
        return 1 if (req.ttft_slo_s is not None and req.arrival > 0) else 0

    def _admissible(
        self, pairs: List[Tuple[ClientState, Request]]
    ) -> List[Tuple[ClientState, Request]]:
        """Trim a proposed batch to what the page pool can host.

        Head-of-line rule, re-derived for on-demand paging: admission stays
        FCFS *within* a priority class — a request that doesn't fit blocks
        everything of its own class (and every lower class) behind it, so
        the blocked head always gets in eventually: the pages freed by
        finishing decoders cannot be snapped up by same-class followers (the
        no-starvation guarantee the original stop-at-first-blocked rule
        bought for the whole queue). The one sanctioned bypass: a smaller
        *online* request carrying a TTFT deadline may jump a blocked
        offline head — holding deadline traffic behind backlog work it can
        never overtake would convert pool pressure directly into SLO misses,
        and offline work cannot starve under it because class-1 traffic is
        finite per burst while the pool drains monotonically.

        Under on-demand reservation the budget also sets aside the pages
        active decoders need for their *next* round, so admission cannot
        grab the exact pages whose absence would immediately force a
        preemption."""
        out = []
        # pages the prefix-cache index holds with no other owner count as
        # headroom: the manager reclaims them LRU-leaf-first on demand, so a
        # warm cache holding most of the pool never deadlocks admission
        free = self.slots.allocator.num_free + self.slots.reclaimable_pages()
        if self.cfg.page_reserve != "upfront":
            free -= self._decode_growth_pages(1)
        blocked: set = set()
        for client, req in pairs:
            full = self.slots.allocator.pages_for(self._tokens_bound(req))
            if full > self.slots.allocator.num_pages:
                raise ValueError(
                    f"request {req.rid} needs {full} pages but the pool only "
                    f"has {self.slots.allocator.num_pages}; raise "
                    f"EngineConfig.num_pages"
                )
            cls = self._deadline_class(req)
            if any(b >= cls for b in blocked):
                continue
            need = self._pages_needed(req)
            if need > free:
                blocked.add(cls)
                continue
            out.append((client, req))
            free -= need
        return out

    # ------------------------------------------------------------------ #
    # On-demand page growth + preemption-by-eviction                      #
    # ------------------------------------------------------------------ #
    def _growth_target(self, slot: int, k: int) -> int:
        """KV tokens ``slot`` must own to run ``k`` more decode rounds: at
        emitted e, round j writes position n_prefill + e + j - 2, so k
        rounds need n_prefill + e + k - 1 tokens — capped by the request's
        lifetime bound (budget-exhausted lanes no-op inside the fused
        loop)."""
        req = self.slots.request_of[slot]
        return min(
            req.n_prefill + self.slots.emitted[slot] + k - 1,
            self._tokens_bound(req),
        )

    def _decode_growth_pages(self, k: int) -> int:
        """Pages the active decoders collectively need for ``k`` rounds."""
        return sum(
            self.slots.pages_to_cover(s, self._growth_target(s, k))
            for s in self.slots.active_slots
        )

    def _preemption_victims(self) -> List[int]:
        """Eviction order when the pool genuinely exhausts: offline before
        deadline traffic, then least progress lost (fewest emitted tokens —
        the cheapest recompute), newest rid first as the tie-break."""
        cands = []
        for s in range(self.cfg.n_slots):
            if self.slots.request_of[s] is not None:
                cands.append((s, self.slots.request_of[s]))
            elif s in self._chunking:
                cands.append((s, self._chunking[s].req))
        cands.sort(
            key=lambda sr: (
                self._deadline_class(sr[1]),
                self.slots.emitted[sr[0]],
                -sr[1].rid,
            )
        )
        return [s for s, _ in cands]

    def _preempt_slot(self, slot: int) -> None:
        """Evict ``slot`` (nano-vllm's preempt): deallocate its pages and
        re-queue its request for recompute-on-resume. A bound slot keeps its
        generated prefix in ``generated`` and is marked for resume (the
        prefix is recomputed into KV and the pending token restored, so the
        stream stays bit-identical); a mid-chunk prefill simply restarts."""
        sv = self._sv
        if slot in self._chunking:
            st = self._chunking.pop(slot)
            req = st.req
            if st.resume_emitted > 0:
                # a resumed recompute evicted mid-chunk resumes again later
                self._resume_rids.add(req.rid)
            self.slots.free_pages_of(slot)
        else:
            req = self.slots.request_of[slot]
            if self.generated.get(req.rid):
                self._resume_rids.add(req.rid)
                # its prefill completed once and will complete again —
                # trace validation expects 1 + preemptions completions
                req.preemptions += 1
            self.slots.release(slot)
            sv.clients[slot].current = None
        self.preemption_events += 1
        self._mig_pending.discard(slot)
        if self.obs is not None:
            self.obs.span(
                req.rid, "preempt", sv.t, replica=self.obs_replica,
                slot=slot, reason="page_pressure",
            )
        sv.scheduler.push(req)

    def _ensure_decode_capacity(self, k: int, allow_shrink: bool = False) -> int:
        """Secure pages for ``k`` decode rounds over every active slot.

        Prefers shrinking a policy-driven horizon (halving keeps the
        power-of-two jit buckets) over evicting work; when even k=1 cannot
        be funded it preempts victims lowest-priority-first until growth
        fits. Returns the horizon actually funded. Admission guarantees a
        request's lifetime bound fits the pool, so the last surviving slot
        can always grow once everything else is evicted — the loop
        terminates."""
        if self.cfg.kv_layout != "paged":
            return k
        while True:
            active = self.slots.active_slots
            if not active:
                return k
            headroom = (
                self.slots.allocator.num_free + self.slots.reclaimable_pages()
            )
            if self._decode_growth_pages(k) <= headroom:
                # ensure_tokens reclaims index-held pages on demand, so
                # eviction of live work stays the last resort
                for s in active:
                    self.slots.ensure_tokens(s, self._growth_target(s, k))
                return k
            if allow_shrink and k > 1:
                k //= 2
                continue
            victims = self._preemption_victims()
            if not victims:
                return k
            self._preempt_slot(victims[0])

    def _note_concurrency(self) -> None:
        cur = len(self.slots.active_slots) + len(self._chunking)
        if cur > self.peak_concurrency:
            self.peak_concurrency = cur

    def _note_first_token(self, req: Request, t: float) -> None:
        """Pin TTFT to the FIRST prefill completion (a preemption recomputes
        the prefill later, which must not move it) and feed the overload
        policy's attainment window."""
        if req.t_first_token is None:
            req.t_first_token = t
            if self.overload is not None and req.ttft_slo_s is not None:
                self.overload.record_ttft(t - req.arrival, req.ttft_slo_s)
            if self.obs is not None:
                self.obs.span(
                    req.rid, "first_token", t, replica=self.obs_replica,
                    slot=req.client, ttft_s=round(t - req.arrival, 6),
                )

    # ------------------------------------------------------------------ #
    # Observability emission (every call site guards on self.obs)         #
    # ------------------------------------------------------------------ #
    def _obs_admit(
        self, req: Request, t: float, slot: int, resumed: bool, cached: int
    ) -> None:
        """Admission span; a request's first-ever event is its arrival."""
        if not self.obs.spans.has(req.rid):
            self.obs.span(
                req.rid, "arrival", max(req.arrival, 0.0),
                replica=self.obs_replica,
            )
        self.obs.span(
            req.rid, "resume" if resumed else "admit", t,
            replica=self.obs_replica, slot=slot,
            cached_tokens=cached, prefill_tokens=req.n_prefill,
        )

    def _obs_complete(self, req: Request, t: float, slot: int) -> None:
        self.obs.span(
            req.rid, "complete", t, replica=self.obs_replica, slot=slot,
            decoded=req.decoded,
        )

    def _capacity_classes(
        self, busy: Dict[int, int], busy_partial: Dict[int, int], dt: float
    ) -> Dict[str, float]:
        """Classify every slot's share of one stage: each of ``n_slots``
        slots contributes exactly ``dt`` to exactly one class, so the sample
        sums to ``dt × n_slots`` by construction (the conservation the
        capacity-attribution rollup hard-checks)."""
        cls: Dict[str, float] = {}
        for s in range(self.cfg.n_slots):
            if s in busy or s in busy_partial:
                st = self._chunking.get(s)
                if st is not None and st.resume_emitted > 0:
                    c = "preempted"        # recomputing an evicted request
                elif st is not None and st.cached > 0:
                    c = "cache_hit"        # prefill riding adopted pages
                else:
                    c = "busy"
                self._mig_pending.discard(s)
            elif s in self._mig_pending:
                c = "migration"            # imported, not yet dispatched
            elif self.slots.request_of[s] is not None or s in self._chunking:
                c = "stall"                # holds work but was not dispatched
            else:
                c = "idle_gap"             # free slot during the stage
            cls[c] = cls.get(c, 0.0) + dt
        return cls

    def _obs_finish(self, trace: ScheduleTrace) -> None:
        """Mirror the trace's meta counters into the typed registry and
        record this replica's capacity denominator."""
        obs = self.obs
        for k, v in trace.meta.items():
            kind, unit, help_ = _METRIC_SPECS.get(
                k, ("counter", "", "engine meta counter")
            )
            obs.declare(k, kind, unit=unit, help=help_)
            if kind == "counter":
                obs.inc(k, float(v))
            else:
                # fleet semantics for per-replica peaks: the registry keeps
                # the max across replicas
                obs.set(k, max(obs.registry.value(k), float(v)))
        kind, unit, help_ = _METRIC_SPECS["decoded_tokens"]
        obs.declare("decoded_tokens", kind, unit=unit, help=help_)
        obs.inc("decoded_tokens", float(self.decoded_tokens))
        obs.finish_replica(self.obs_replica, trace.makespan, self.cfg.n_slots)

    def _start_chunked_batch(
        self, pairs: List[Tuple[ClientState, Request]], bin_index: int, now: float
    ) -> None:
        for client, req in pairs:
            prompt = self._prompt_tokens(req)
            resume_emitted = 0
            resume_pending = -1
            resumed = False
            if req.rid in self._resume_rids:
                self._resume_rids.discard(req.rid)
                prefix = self.generated.get(req.rid, [])
                if prefix:
                    resumed = True
                    resume_emitted = len(prefix)
                    resume_pending = int(prefix[-1])
                    if len(prefix) > 1:
                        prompt = np.concatenate(
                            [prompt, np.asarray(prefix[:-1], np.int32)]
                        )
            if self.cfg.page_reserve == "upfront":
                span = self._tokens_bound(req)
            else:
                span = len(prompt)
            if self._use_prefix_cache:
                # adopt cached full pages read-only (COW at the divergence
                # page); chunked prefill starts at the first uncached token
                cached = self.slots.reserve_with_prefix(
                    client.cid, prompt, span
                )
            else:
                self.slots.reserve(client.cid, span)
                cached = 0
            if resumed:
                # the re-prefilled span (prompt + prefix) is work this
                # request already paid for once — the cost page-copy
                # migration exists to avoid; cache hits shrink it further
                self.recomputed_tokens += len(prompt) - cached
            req.cached_prefill = min(cached, req.n_prefill)
            self.cache_hit_tokens += cached
            if self.obs is not None:
                self._obs_admit(req, now, client.cid, resumed, cached)
            self._chunking[client.cid] = _ChunkState(
                slot=client.cid, req=req, prompt=prompt, done=cached,
                resume_emitted=resume_emitted, resume_pending=resume_pending,
                cached=cached,
            )
            req.client = client.cid
            req.prefill_bin = bin_index
            req.t_prefill_start = now
        self._note_concurrency()

    def _next_chunk_tokens(self) -> int:
        return sum(
            min(self.cfg.prefill_chunk, st.remaining)
            for st in self._chunking.values()
        )

    def _run_chunk_round(self):
        """One chunk round over every mid-prefill slot; returns
        (duration, chunk_tokens, finished_slots, busy, busy_partial)."""
        states = [self._chunking[s] for s in sorted(self._chunking)]
        c = self.cfg.prefill_chunk
        n_pad = _bucket(len(states), self.cfg.prefill_req_buckets)
        tokens = np.zeros((n_pad, c), dtype=np.int32)
        # pad rows point one past the last slot: their (len-0) writes drop
        slot_ids = np.full(n_pad, self.cfg.n_slots, dtype=np.int32)
        starts = np.zeros(n_pad, dtype=np.int32)
        lens = np.zeros(n_pad, dtype=np.int32)
        for i, st in enumerate(states):
            n = min(c, st.remaining)
            tokens[i, :n] = st.prompt[st.done : st.done + n]
            slot_ids[i] = st.slot
            starts[i] = st.done
            lens[i] = n
        t0 = time.perf_counter()
        logits, self.slots.cache = self._chunk_jit(
            self.params, jnp.asarray(tokens), self.slots.cache,
            jnp.asarray(slot_ids), jnp.asarray(starts), jnp.asarray(lens),
        )
        logits.block_until_ready()
        dt = (time.perf_counter() - t0) / self.speed_factor
        first = self._sample_first(logits, [st.req.rid for st in states])
        self._dev_pending = None          # prefill rewrites pending tokens
        busy: Dict[int, int] = {}
        busy_partial: Dict[int, int] = {}
        finished: List[int] = []
        chunk_tokens = int(lens.sum())
        for i, st in enumerate(states):
            slot = st.slot
            st.done += int(lens[i])
            if st.done >= st.total:
                self.slots.bind(slot, st.req)
                if self._use_prefix_cache:
                    # publish the prompt's FULL pages (the partial last page
                    # still takes decode writes and must stay private)
                    self.slots.publish_prefix(slot, st.prompt)
                if st.resume_emitted > 0:
                    # recompute complete: restore the pre-preemption stream
                    # state instead of sampling (bit-identical continuation)
                    self.slots.emitted[slot] = st.resume_emitted
                    self.pending_token[slot] = st.resume_pending
                else:
                    self.slots.emitted[slot] = 1   # final chunk samples token #1
                    self.pending_token[slot] = int(first[i])
                    self.generated.setdefault(st.req.rid, []).append(int(first[i]))
                busy[slot] = st.req.rid
                finished.append(slot)
                del self._chunking[slot]
            else:
                busy_partial[slot] = st.req.rid
        self._observe_prefill(chunk_tokens, dt)
        return dt, chunk_tokens, finished, busy, busy_partial

    # ------------------------------------------------------------------ #
    # Mixed prefill+decode rounds (paged layout, mixed_schedule=True)     #
    # ------------------------------------------------------------------ #
    def _plan_mixed_round(
        self, pairs: List[Tuple[ClientState, Request]], share: int
    ) -> Tuple[List[Tuple[_ChunkState, int]], List[Tuple[ClientState, Request, int]]]:
        """Split the policy-priced chunk-token share across prefill work.

        Grants are WHOLE chunks (a prompt's final partial chunk excepted):
        a mixed dispatch pays for full ``prefill_chunk``-wide rows whatever
        they hold, so funding a fraction of a chunk burns the same compute
        for half the prefill progress — under sustained arrivals that can
        push prefill supply below demand and grow the queue without bound.
        The share therefore picks *how many* chunk rows ride along (the
        last grant may overshoot it), not where inside a chunk to stop.

        Continuations of in-flight chunked prefills are funded first (finish
        what holds pages before opening new prompts), then new admissions —
        the rest stay queued for a later round. Returns the per-state token
        counts for this round and the admissions to commit.
        """
        plan: List[Tuple[_ChunkState, int]] = []
        budget = share
        for slot in sorted(self._chunking):
            if budget <= 0:
                break
            st = self._chunking[slot]
            n = min(self.cfg.prefill_chunk, st.remaining)
            if n > 0:
                plan.append((st, n))
                budget -= n
        admitted: List[Tuple[ClientState, Request, int]] = []
        for client, req in pairs:
            if budget <= 0:
                break
            n = min(self.cfg.prefill_chunk, req.n_prefill)
            admitted.append((client, req, n))
            budget -= n
        return plan, admitted

    def _run_mixed_stage(self, plan: List[Tuple[_ChunkState, int]]):
        """ONE unified dispatch: a decode round over every active slot plus
        the planned prefill-chunk rows, written straight into the paged
        pool. Decode lanes sample their next token on device; a prompt whose
        final chunk lands this round emits its first token in the same call.
        Returns (duration, finished_decode_slots, decode_tokens,
        chunk_tokens, finished_chunk_slots, busy, busy_partial).
        """
        cfg = self.cfg
        j = cfg.n_slots
        decode_slots = self.slots.active_slots
        n_chunk = sum(n for _, n in plan)
        c = cfg.prefill_chunk
        # chunk rows pad to the same rectangles the alternating chunk round
        # compiles — no extra jit variants for the mixed path
        r_pad = _bucket(max(len(plan), 1), cfg.prefill_req_buckets)
        chunk_tokens = np.zeros((r_pad, c), dtype=np.int32)
        chunk_slots = np.full(r_pad, j, dtype=np.int32)    # j → pad row
        starts = np.zeros(r_pad, dtype=np.int32)
        lens = np.zeros(r_pad, dtype=np.int32)
        dec_active = np.zeros(j, dtype=bool)
        sample_rows = np.zeros(j + r_pad, dtype=bool)
        rids = np.full(j + r_pad, -1, dtype=np.int32)
        token_idx = np.zeros(j + r_pad, dtype=np.int32)
        budgets: Dict[int, int] = {}
        for slot in decode_slots:
            req = self.slots.request_of[slot]
            dec_active[slot] = True
            sample_rows[slot] = True
            rids[slot] = req.rid
            token_idx[slot] = self.slots.emitted[slot]
            budgets[slot] = self._decode_budget(slot)
        final_row: Dict[int, int] = {}     # slot → sample row of final chunk
        for i, (st, n) in enumerate(plan):
            chunk_tokens[i, :n] = st.prompt[st.done : st.done + n]
            chunk_slots[i] = st.slot
            starts[i] = st.done
            lens[i] = n
            if st.done + n >= st.total:
                if st.resume_emitted == 0:
                    # resumed rows never sample: their first token already
                    # exists and is restored, not re-drawn
                    sample_rows[j + i] = True
                    rids[j + i] = st.req.rid
                final_row[st.slot] = j + i
        pending = (
            self._dev_pending if self._dev_pending is not None
            else jnp.asarray(self.pending_token)
        )
        t0 = time.perf_counter()
        sampled, self.slots.cache = self._mixed_jit(
            self.params, pending, self.slots.cache,
            jnp.asarray(chunk_tokens), jnp.asarray(chunk_slots),
            jnp.asarray(starts), jnp.asarray(lens),
            jnp.asarray(dec_active), jnp.asarray(rids),
            jnp.asarray(token_idx), jnp.asarray(sample_rows), self._base_key,
        )
        sampled = np.asarray(sampled)      # the ONE host sync for this round
        dt = (time.perf_counter() - t0) / self.speed_factor
        self._dev_pending = None           # pending rebuilt from host below

        finished_decode: List[int] = []
        decode_tokens = 0
        busy: Dict[int, int] = {}
        busy_partial: Dict[int, int] = {}
        for slot in decode_slots:
            tok = int(sampled[slot])
            req = self.slots.request_of[slot]
            self.slots.emitted[slot] += 1
            self.pending_token[slot] = tok
            self.generated.setdefault(req.rid, []).append(tok)
            req.decoded = self.slots.emitted[slot]
            decode_tokens += 1
            busy[slot] = req.rid
            if budgets[slot] <= 1 or (
                cfg.eos_id is not None and tok == cfg.eos_id
            ):
                finished_decode.append(slot)
        finished_chunks: List[int] = []
        for st, n in plan:
            st.done += n
            slot = st.slot
            if st.done >= st.total:
                self.slots.bind(slot, st.req)
                if self._use_prefix_cache:
                    self.slots.publish_prefix(slot, st.prompt)
                if st.resume_emitted > 0:
                    self.slots.emitted[slot] = st.resume_emitted
                    self.pending_token[slot] = st.resume_pending
                else:
                    self.slots.emitted[slot] = 1   # final chunk samples token #1
                    first = int(sampled[final_row[slot]])
                    self.pending_token[slot] = first
                    self.generated.setdefault(st.req.rid, []).append(first)
                busy[slot] = st.req.rid
                finished_chunks.append(slot)
                del self._chunking[slot]
            else:
                busy_partial[slot] = st.req.rid
        self.mixed_rounds += 1
        if decode_slots:
            self.decode_dispatches += 1
            self.decoded_tokens += decode_tokens
        # rounds with no active decoders route to _run_chunk_round in the
        # serve loop, so every mixed sample carries real decode lanes
        self.profiler.record_mixed(len(decode_slots), n_chunk, dt)
        return (
            dt, finished_decode, decode_tokens, n_chunk, finished_chunks,
            busy, busy_partial,
        )

    def _finish_prefills(
        self, slots: List[int], clients: List[ClientState], t: float
    ) -> None:
        """Post-stage bookkeeping for requests whose final chunk just
        landed (shared by the mixed and alternating chunk-round branches)."""
        for slot in slots:
            req = self.slots.request_of[slot]
            clients[slot].current = req
            req.t_prefill_end = t
            # resumed slots re-enter decode at their pre-preemption count
            req.decoded = self.slots.emitted[slot]
            if self.obs is not None:
                self.obs.span(
                    req.rid, "prefill_done", t, replica=self.obs_replica,
                    slot=slot,
                )
            self._note_first_token(req, t)
            # requests with n_decode == 1 finish at prefill
            if self.cfg.eos_id is None and req.n_decode <= 1:
                req.t_done = t
                self.slots.release(slot)
                clients[slot].current = None
                if self.obs is not None:
                    self._obs_complete(req, t, slot)

    def warm_serving_shapes(self) -> None:
        """Pre-compile every paged serving-dispatch variant the scheduler
        can reach — mixed-round row buckets, chunk-round rectangles, and
        fused-decode horizons — with all-pad / all-inactive no-op calls
        (writes dropped, lengths untouched, nothing recorded).

        Which variant a stage lands in depends on live policy decisions
        that shift with the online fit, so a measured serve can hit a shape
        its warm pass never saw — and one first-hit compile dwarfs every
        real stage. Benchmarks call this after their warm pass so the timed
        serve only sees compiled code."""
        if self.cfg.kv_layout != "paged":
            return
        cfg = self.cfg
        j = cfg.n_slots
        row_buckets = sorted({
            _bucket(rows, cfg.prefill_req_buckets)
            for rows in range(1, j + 1)
        })
        for r_pad in row_buckets:
            if cfg.mixed_schedule:
                # mixed round: j decode lanes + r_pad chunk rows, padded out
                # (unreachable — and so not warmed — in alternating mode)
                sampled, self.slots.cache = self._mixed_jit(
                    self.params,
                    jnp.zeros(j, jnp.int32), self.slots.cache,
                    jnp.zeros((r_pad, cfg.prefill_chunk), jnp.int32),
                    jnp.full(r_pad, j, jnp.int32),
                    jnp.zeros(r_pad, jnp.int32), jnp.zeros(r_pad, jnp.int32),
                    jnp.zeros(j, bool), jnp.full(j + r_pad, -1, jnp.int32),
                    jnp.zeros(j + r_pad, jnp.int32),
                    jnp.zeros(j + r_pad, bool),
                    self._base_key,
                )
                sampled.block_until_ready()
            # chunk round: r_pad prompt rows, all padded out
            logits, self.slots.cache = self._chunk_jit(
                self.params,
                jnp.zeros((r_pad, cfg.prefill_chunk), jnp.int32),
                self.slots.cache,
                jnp.full(r_pad, j, jnp.int32),
                jnp.zeros(r_pad, jnp.int32), jnp.zeros(r_pad, jnp.int32),
            )
            logits.block_until_ready()
        k_cap = max(cfg.decode_horizon or cfg.max_decode_horizon, 1)
        horizons = {k_cap}                 # a pinned K dispatches exactly
        k = 1
        while k <= k_cap:                  # plus the power-of-two buckets
            horizons.add(k)
            k *= 2
        for k in sorted(horizons):
            # fused decode at horizon k, every slot inactive
            out = self._fused_jit(
                k, self.params, jnp.zeros(j, jnp.int32), self.slots.cache,
                jnp.zeros(j, bool), jnp.zeros(j, jnp.int32),
                jnp.zeros(j, jnp.int32), jnp.zeros(j, jnp.int32),
                self._base_key,
            )
            self.slots.cache = out[-1]
            out[0].block_until_ready()

    def _choose_horizon(self, policy_horizon: int) -> int:
        """Final decode horizon, capped by the largest remaining per-slot
        budget (no all-no-op tail rounds). A pinned ``decode_horizon`` is
        honored exactly (ablations must measure the K they asked for); the
        policy-driven path buckets down to a power of two so at most
        log2(K_max)+1 jit variants ever compile."""
        cfg = self.cfg
        rem = max(
            (self._decode_budget(s) for s in self.slots.active_slots),
            default=1,
        )
        if cfg.decode_horizon is not None:
            k = max(1, min(cfg.decode_horizon, rem))
            # run the pinned K exactly while budgets allow; bucket only the
            # drain tail (rem < K), else every distinct tail value would
            # compile a fresh executable inside a measured region
            return k if k == cfg.decode_horizon else 1 << (k.bit_length() - 1)
        k = max(1, min(policy_horizon, cfg.max_decode_horizon, rem))
        return 1 << (k.bit_length() - 1)

    def _decode_budget(self, slot: int) -> int:
        """Tokens this slot may still emit: its known output budget, or (eos
        mode) the KV capacity left — round r writes position
        n_prefill + emitted - 1, which must stay below max_len."""
        req = self.slots.request_of[slot]
        emitted = self.slots.emitted[slot]
        if self.cfg.eos_id is None:
            return max(1, req.n_decode - emitted)
        return max(1, self.cfg.max_len - (req.n_prefill + emitted - 1))

    def _run_decode_stage(self, k: int) -> Tuple[float, List[int], int]:
        """One fused decode stage of ``k`` iterations over all active slots:
        ONE device dispatch, ONE host sync at the horizon boundary. Returns
        (duration, finished slots, tokens emitted)."""
        cfg = self.cfg
        slots = self.slots.active_slots
        active = np.zeros(cfg.n_slots, dtype=bool)
        budgets = np.zeros(cfg.n_slots, dtype=np.int32)
        rids = np.zeros(cfg.n_slots, dtype=np.int32)
        emit0 = np.zeros(cfg.n_slots, dtype=np.int32)
        for slot in slots:
            active[slot] = True
            budgets[slot] = self._decode_budget(slot)
            rids[slot] = self.slots.request_of[slot].rid
            emit0[slot] = self.slots.emitted[slot]
        pending = (
            self._dev_pending if self._dev_pending is not None
            else jnp.asarray(self.pending_token)
        )
        t0 = time.perf_counter()
        token_block, emitted_k, active_out, last_tok, self.slots.cache = (
            self._fused_jit(
                k, self.params, pending, self.slots.cache,
                jnp.asarray(active), jnp.asarray(budgets), jnp.asarray(rids),
                jnp.asarray(emit0), self._base_key,
            )
        )
        # the ONE host sync for this horizon: everything the scheduler needs
        block = np.asarray(token_block)                    # (K, n_slots)
        emitted_k = np.asarray(emitted_k)
        active_out = np.asarray(active_out)
        dt = (time.perf_counter() - t0) / self.speed_factor
        self._dev_pending = last_tok      # stays device-resident across stages
        self.decode_dispatches += 1
        finished: List[int] = []
        total = 0
        for slot in slots:
            cnt = int(emitted_k[slot])
            req = self.slots.request_of[slot]
            toks = block[:cnt, slot]
            self.slots.emitted[slot] += cnt
            self.pending_token[slot] = int(toks[-1])
            self.generated.setdefault(req.rid, []).extend(int(x) for x in toks)
            req.decoded = self.slots.emitted[slot]
            total += cnt
            if not bool(active_out[slot]):
                finished.append(slot)
        self.decoded_tokens += total
        self.profiler.record_decode(len(slots), dt, rounds=k)
        return dt, finished, total

    # ------------------------------------------------------------------ #
    def begin_serve(
        self,
        requests: Sequence[Request],
        clients: List[ClientState],
        request_scheduler: RequestScheduler,
        iteration_policy: IterationPolicy,
        policy_name: str = "",
        track_requests: bool = False,
    ) -> None:
        """Open a step-driven serve session (``serve_step`` runs stages one
        at a time; ``finish_serve`` closes the trace).

        ``serve()`` wraps the three; a ``Fleet`` drives many engines'
        sessions interleaved by virtual time instead, routing arrivals and
        stolen requests in mid-serve via the scheduler's ``push``. With
        ``track_requests=True`` the trace adopts requests as the scheduler
        commits them (the request set is discovered, not declared — fleet
        dispatch and stealing decide placement while the serve runs)."""
        cfg = self.cfg
        if len(clients) != cfg.n_slots:
            raise ValueError("clients must match n_slots")
        trace = ScheduleTrace(
            num_clients=cfg.n_slots,
            requests=list(requests),
            policy_name=policy_name or f"engine/{iteration_policy.name}",
        )
        for r in requests:
            r.reset()
        # per-serve output record (rids repeat across workloads; in-flight
        # _chunking state is deliberately NOT cleared — it's the resume path)
        self.generated = {}
        self.decode_dispatches = 0
        self.decoded_tokens = 0
        self.mixed_rounds = 0
        self.prefill_stall_time = 0.0
        self.preemption_events = 0
        self.offline_deferrals = 0
        self.peak_concurrency = 0
        self.recomputed_tokens = 0
        self.migrated_pages_in = 0
        self.migrated_pages_out = 0
        self.migrations_in = 0
        self.migrations_out = 0
        self.cache_hit_tokens = 0
        self._mig_pending = set()
        self._sv = _ServeSession(
            trace=trace, clients=clients, scheduler=request_scheduler,
            policy=iteration_policy, track_requests=track_requests,
        )

    def has_work(self) -> bool:
        """Anything to run right now or later: a bound slot, an in-flight
        chunked prefill, or a queued request (arrived or future)."""
        return (
            bool(self.slots.active_slots)
            or bool(self._chunking)
            or self._sv.scheduler.has_pending()
        )

    @property
    def clock(self) -> float:
        """The open session's stage clock (virtual serve time)."""
        return self._sv.t

    def advance_clock(self, t: float) -> None:
        """Fast-forward the session clock (fleet-level idle gaps — the fleet
        routes arrivals itself, so the engine never sees them coming)."""
        if t > self._sv.t:
            self._sv.t = t

    def _commit_pairs(self, pairs: List[Tuple[ClientState, Request]]) -> None:
        sv = self._sv
        sv.scheduler.commit_batch(pairs)
        if sv.track_requests:
            # a preempted request is committed again on resume — adopt each
            # request into the trace once
            known = {r.rid for r in sv.trace.requests}
            sv.trace.requests.extend(
                r for _, r in pairs if r.rid not in known
            )

    def queued_requests(self) -> Tuple[Request, ...]:
        """Not-yet-admitted requests of the open session (overload policies
        inspect these for queue pressure)."""
        if self._sv is None:
            return ()
        return self._sv.scheduler.queued

    def adopt_resume(self, req: Request, prefix: List[int]) -> None:
        """Adopt a request recovered from another replica mid-decode (fleet
        fault recovery): seed its generated-so-far prefix and queue it for
        recompute-on-resume — the same path a locally preempted request
        takes, so the resumed stream is bit-identical to an uninterrupted
        serve."""
        self.generated[req.rid] = list(prefix)
        self._resume_rids.add(req.rid)
        self._sv.scheduler.push(req)

    # ------------------------------------------------------------------ #
    # Live migration by page-copy (fleet drain / rebalancing / recovery)  #
    # ------------------------------------------------------------------ #
    def _check_invariants(self) -> None:
        """debug_invariants hook: allocator free-list/free-set consistency,
        the host↔device block-table mirror, and per-page refcount agreement
        (block-table multiplicity + prefix-index holds) — paged layout
        only."""
        if self.cfg.kv_layout != "paged":
            return
        self.slots.allocator.check_consistency()
        self.slots.check_block_table_mirror()
        self.slots.check_refcounts()

    def _local_prefill_completions(self, rid: int) -> int:
        """Prefill completions for ``rid`` recorded in THIS session's trace
        so far — the same counting rule ``ScheduleTrace.validate`` applies.
        An import must subtract these from the checkpoint's total credit so
        a request that leaves and later returns is not double-counted."""
        cnt = 0
        for s in self._sv.trace.stages:
            if s.kind is StageKind.PREFILL:
                cnt += sum(1 for r in s.busy.values() if r == rid)
            elif s.kind is StageKind.MIXED:
                cnt += sum(1 for r in s.prefilled.values() if r == rid)
        return cnt

    def can_import(self, n_pages: int) -> bool:
        """Whether this engine can host a migrated slot of ``n_pages`` right
        now: a truly free slot, and pool headroom beyond the pages its own
        active decoders need for their next round — an import must never be
        the thing that immediately forces a preemption here."""
        if self.cfg.kv_layout != "paged" or self._sv is None:
            return False
        if not any(s not in self._chunking for s in self.slots.free_slots):
            return False
        free = (
            self.slots.allocator.num_free + self.slots.reclaimable_pages()
            - self._decode_growth_pages(1)
        )
        return n_pages <= free

    def slot_pages(self, slot: int) -> int:
        """Pages ``slot`` currently owns (capacity probe for migration)."""
        return len(self.slots.tables[slot])

    def export_slot(self, slot: int) -> SlotCheckpoint:
        """Extract ``slot``'s full mid-request state as a portable
        ``SlotCheckpoint`` and release the slot: gather its KV pages off the
        pool, capture the pending token / sampler cursor / generated prefix
        (and mid-chunk prefill progress), free the pages, and drop the
        request from this trace — it continues its life, exactly-once, on
        whichever engine imports the checkpoint."""
        sv = self._sv
        if slot in self._chunking:
            st = self._chunking[slot]
            req = st.req
            kind = "chunking"
            emitted = st.resume_emitted
            pending = st.resume_pending
            chunk_done = st.done
            resume_emitted = st.resume_emitted
            resume_pending = st.resume_pending
            # the in-flight pass hasn't completed; earlier passes number
            # exactly req.preemptions (0 for a fresh prompt)
            credit = req.preemptions
        elif self.slots.request_of[slot] is not None:
            req = self.slots.request_of[slot]
            kind = "bound"
            emitted = self.slots.emitted[slot]
            pending = int(self.pending_token[slot])
            chunk_done = 0
            resume_emitted = 0
            resume_pending = -1
            # a bound slot has completed every prefill it will ever need
            credit = 1 + req.preemptions
        else:
            raise RuntimeError(f"slot {slot} holds no in-flight request")
        pages, k_pages, v_pages, kv_length, checksum = (
            self.slots.export_pages(slot)
        )
        if kind == "chunking":
            del self._chunking[slot]
            self.slots.free_pages_of(slot)
        else:
            self.slots.release(slot)
            sv.clients[slot].current = None
        prefix = self.generated.pop(req.rid, [])
        sv.trace.requests = [r for r in sv.trace.requests if r.rid != req.rid]
        sv.trace.external_prefills.pop(req.rid, None)
        self.migrations_out += 1
        self.migrated_pages_out += len(pages)
        self._mig_pending.discard(slot)
        if self.obs is not None:
            self.obs.span(
                req.rid, "migrate_out", sv.t, replica=self.obs_replica,
                slot=slot, pages=len(pages), state=kind,
            )
        if self.debug_invariants:
            self._check_invariants()
        return SlotCheckpoint(
            req=req, kind=kind, emitted=emitted, pending_token=pending,
            kv_length=kv_length, k_pages=k_pages, v_pages=v_pages,
            n_pages=len(pages), prefix=list(prefix), prefill_credit=credit,
            chunk_done=chunk_done, resume_emitted=resume_emitted,
            resume_pending=resume_pending, checksum=checksum,
        )

    def import_slot(self, ckpt: SlotCheckpoint) -> int:
        """Land a migrated slot in this engine: allocate fresh pages,
        scatter the KV payload, and rebind the request exactly where it
        left off — same pending token, same sampler cursor, so the stream
        continues bit-identical with zero recomputed tokens. Returns the
        destination slot. Callers gate on ``can_import`` first."""
        sv = self._sv
        free = [s for s in self.slots.free_slots if s not in self._chunking]
        if not free:
            raise RuntimeError("no free slot to import into")
        slot = free[0]
        # verifies the payload CRC before any pool state changes — a
        # corrupted transfer raises PageIntegrityError with nothing bound
        self.slots.import_pages(
            slot, ckpt.k_pages, ckpt.v_pages, ckpt.kv_length,
            checksum=ckpt.checksum,
        )
        req = ckpt.req
        if ckpt.prefix:
            self.generated[req.rid] = list(ckpt.prefix)
        if ckpt.kind == "bound":
            self.slots.bind(slot, req)
            self.slots.emitted[slot] = ckpt.emitted
            self.pending_token[slot] = ckpt.pending_token
            # decode stages read pending tokens from the device copy when
            # one is live — it predates this import and must be rebuilt
            self._dev_pending = None
            sv.clients[slot].current = req
            req.decoded = ckpt.emitted
        else:
            prompt = self._prompt_tokens(req)
            if ckpt.resume_emitted > 1:
                prompt = np.concatenate(
                    [prompt, np.asarray(ckpt.prefix[:-1], np.int32)]
                )
            self._chunking[slot] = _ChunkState(
                slot=slot, req=req, prompt=prompt, done=ckpt.chunk_done,
                resume_emitted=ckpt.resume_emitted,
                resume_pending=ckpt.resume_pending,
            )
        req.client = slot
        known = {r.rid for r in sv.trace.requests}
        if req.rid not in known:
            sv.trace.requests.append(req)
        # credit only the completions THIS trace hasn't recorded locally (a
        # request can leave and come back; its earlier local stages remain)
        sv.trace.external_prefills[req.rid] = (
            ckpt.prefill_credit - self._local_prefill_completions(req.rid)
        )
        self._note_concurrency()
        self.migrations_in += 1
        self.migrated_pages_in += ckpt.n_pages
        self._mig_pending.add(slot)
        if self.obs is not None:
            self.obs.span(
                req.rid, "migrate_in", sv.t, replica=self.obs_replica,
                slot=slot, pages=ckpt.n_pages, state=ckpt.kind,
            )
        if self.debug_invariants:
            self._check_invariants()
        return slot

    def _filter_overload(
        self,
        pairs: List[Tuple[ClientState, Request]],
        idle: List[ClientState],
        max_cap: int,
        request_scheduler: RequestScheduler,
        t: float,
    ) -> List[Tuple[ClientState, Request]]:
        """Run the overload policy over the proposed admissions, re-proposing
        for any client whose candidate was deferred with the deferred rids
        excluded — in an FCFS queue a deferred offline head must not shadow
        an admissible (online) request queued behind it."""
        kept = self.overload.filter_admissions(pairs, t, self)
        deferred = {r.rid for _, r in pairs} - {r.rid for _, r in kept}
        if not deferred:
            return kept
        self.offline_deferrals += len(deferred)
        while True:
            taken = {id(c) for c, _ in kept}
            freed = [c for c in idle if id(c) not in taken]
            budget = max_cap - sum(r.n_prefill for _, r in kept)
            if not freed or budget <= 0:
                return kept
            extra = request_scheduler.propose_batch(
                freed, budget,
                exclude=deferred | {r.rid for _, r in kept},
            )
            if not extra:
                return kept
            kept_extra = self.overload.filter_admissions(extra, t, self)
            newly = {r.rid for _, r in extra} - {r.rid for _, r in kept_extra}
            self.offline_deferrals += len(newly)
            kept = kept + kept_extra
            if not newly:
                return kept
            deferred |= newly

    def serve_step(self) -> str:
        """Run at most one stage of the open session. Returns:

        * ``"ran"``  — executed a stage (or made clock progress);
        * ``"done"`` — no active work and the scheduler has nothing pending
          (a fleet may push more work and call again);
        * ``"idle"`` — pending work exists but nothing can run and no
          arrival is known to wait for (closed-loop callers treat this as a
          deadlock; a fleet decides what happens next).
        """
        sv = self._sv
        cfg = self.cfg
        paged = cfg.kv_layout == "paged"
        mixed = paged and cfg.mixed_schedule
        clients = sv.clients
        request_scheduler = sv.scheduler
        iteration_policy = sv.policy
        trace = sv.trace
        for _attempt in range(4):
            if sv.stages_run >= cfg.max_stages:
                raise RuntimeError("max_stages exceeded")
            t = sv.t
            if self.obs is not None and paged:
                # COW copies fire inside reserve_with_prefix; stamp them
                # with the current virtual time
                self.slots.obs_now = t
            max_cap = max(
                self.profiler.cost_model.max_level.cap_tokens >> self._budget_shift,
                self.profiler.cost_model.level_caps[0],
            )
            active = [c for c in clients if c.current is not None]
            idle = [
                c for c in clients
                if c.current is None and c.cid not in self._chunking
            ]
            if (
                not active and not self._chunking
                and not request_scheduler.has_pending()
            ):
                return "done"
            # arrival-aware schedulers gate their queue on the stage clock
            if hasattr(request_scheduler, "set_now"):
                request_scheduler.set_now(t)
            pairs = request_scheduler.propose_batch(idle, max_cap)
            if self.overload is not None and pairs:
                pairs = self._filter_overload(
                    pairs, idle, max_cap, request_scheduler, t
                )
            if paged and pairs:
                pairs = self._admissible(pairs)
            if paged:
                # the candidate stage is one chunk round: continuations of
                # any in-flight prefills plus first chunks of new admissions
                # (idle slots keep admitting while long prompts chunk)
                cont = sorted(self._chunking)
                cached_est = 0
                if self._use_prefix_cache and cfg.cache_aware_pricing:
                    # tokens of this candidate the cache will serve: known
                    # exactly for in-flight prefills, probed (read-only) for
                    # proposed admissions — so the Lagrangian share prices
                    # the prefill work actually computed
                    cached_est = sum(
                        self._chunking[s].cached for s in cont
                    ) + sum(
                        self.slots.probe_prefix(self._prompt_tokens(r))
                        for _, r in pairs
                    )
                candidate = CandidateBatch(
                    requests=[self._chunking[s].req for s in cont]
                    + [r for _, r in pairs],
                    client_ids=cont + [c.cid for c, _ in pairs],
                    chunk_tokens=self._next_chunk_tokens()
                    + sum(min(cfg.prefill_chunk, r.n_prefill) for _, r in pairs),
                    cached_tokens=cached_est,
                )
            else:
                candidate = CandidateBatch(
                    requests=[r for _, r in pairs],
                    client_ids=[c.cid for c, _ in pairs],
                )
            snap = SystemSnapshot(
                n_clients=cfg.n_slots,
                n_active=len(active),
                n_idle=len(idle),
                active_remaining_est=sum(
                    max(0, (c.current.n_decode_est or 0) - c.current.decoded)
                    for c in active
                ),
                pending_requests=request_scheduler.pending_count(),
                candidate=candidate,
                now=t,
            )
            # actionable prefill work in flight or in view → the
            # latency-sensitive "burst" window (queued-but-unproposable
            # requests don't count: no prefill can preempt decode for them)
            burst = bool(self._chunking or pairs)
            mixed_budget: Optional[int] = None
            if mixed:
                avail = self._next_chunk_tokens() + sum(
                    min(cfg.prefill_chunk, r.n_prefill) for _, r in pairs
                )
                mixed_budget = min(avail, cfg.mixed_token_buckets[-1])
            explain = (
                {} if (self.obs is not None and mixed_budget is not None)
                else None
            )
            t0 = time.perf_counter()
            decision = iteration_policy.decide(
                snap, self.profiler.cost_model,
                k_max=cfg.decode_horizon or cfg.max_decode_horizon,
                mixed_budget=mixed_budget,
                explain=explain,
            )
            do_prefill = decision.prefill
            trace.decision_times_ms.append((time.perf_counter() - t0) * 1e3)
            if explain:
                self.obs.audit_record(
                    "prefill_share", t, self.obs_replica, explain,
                    explain.get("share", decision.chunk_tokens),
                )

            if mixed and decision.chunk_tokens > 0 and active:
                # quantize the priced share down to the bucket table (the
                # mixed analogue of the paper's prefill levels — stable
                # round compositions; sub-bucket shares round up to the
                # smallest bucket so small candidates still make progress)
                fitting = [
                    b for b in cfg.mixed_token_buckets
                    if b <= decision.chunk_tokens
                ]
                share = fitting[-1] if fitting else cfg.mixed_token_buckets[0]
                plan, admitted = self._plan_mixed_round(pairs, share)
                if admitted:
                    new_pairs = [(c, r) for c, r, _ in admitted]
                    self._commit_pairs(new_pairs)
                    sv.bin_index += 1
                    self._start_chunked_batch(new_pairs, sv.bin_index, t)
                    for c, _, n in admitted:
                        st = self._chunking[c.cid]
                        # a prefix-cache hit shrinks the first chunk below
                        # the planned grant — clamp to what actually remains
                        plan.append((st, min(n, st.remaining)))
                if cfg.page_reserve != "upfront":
                    # fund every decode lane's next-round KV write, evicting
                    # victims if the pool exhausts — an evicted mid-chunk
                    # prefill drops out of this round's plan
                    self._ensure_decode_capacity(1)
                    plan = [
                        (st, n) for st, n in plan
                        if self._chunking.get(st.slot) is st
                    ]
                    if not self.slots.active_slots:
                        continue   # every decode lane was evicted — re-plan
                (
                    dt, fin_decode, decode_tok, chunk_tok, fin_chunks,
                    busy, busy_partial,
                ) = self._run_mixed_stage(plan)
                trace.stages.append(
                    StageRecord(
                        kind=StageKind.MIXED,
                        t_start=t, t_end=t + dt,
                        bin_index=max(sv.bin_index, 0),
                        busy=busy, busy_partial=busy_partial,
                        tokens=decode_tok + chunk_tok,
                        chunk_tokens=chunk_tok, rounds=1, burst=True,
                        prefilled={
                            s: self.slots.request_of[s].rid for s in fin_chunks
                        },
                    )
                )
                sv.t = t + dt
                if self.obs is not None:
                    self.obs.capacity(
                        self.obs_replica, t, sv.t,
                        self._capacity_classes(busy, busy_partial, dt),
                    )
                self._finish_prefills(fin_chunks, clients, sv.t)
                for slot in fin_decode:
                    req = self.slots.release(slot)
                    req.t_done = sv.t
                    clients[slot].current = None
                    if self.obs is not None:
                        self._obs_complete(req, sv.t, slot)
            elif (
                candidate and paged
                and (do_prefill or (mixed and decision.chunk_tokens > 0))
            ):
                # no decoders are running, so a "mixed" round would only
                # carry dead decode lanes — run the plain chunk round (same
                # per-row math and jit shapes, honest prefill timing for
                # the cost model and straggler predictor)
                if pairs:
                    self._commit_pairs(pairs)
                    sv.bin_index += 1
                    self._start_chunked_batch(pairs, sv.bin_index, t)
                dt, tok, finished, busy, busy_partial = self._run_chunk_round()
                if active:
                    # decoders froze for the whole preempting chunk round
                    self.prefill_stall_time += dt
                trace.stages.append(
                    StageRecord(
                        kind=StageKind.PREFILL,
                        t_start=t, t_end=t + dt,
                        bin_index=max(sv.bin_index, 0),
                        busy=busy, busy_partial=busy_partial, tokens=tok,
                        level=self.profiler.cost_model.level_for(
                            min(tok, max_cap)
                        ).index,
                    )
                )
                sv.t = t + dt
                if self.obs is not None:
                    self.obs.capacity(
                        self.obs_replica, t, sv.t,
                        self._capacity_classes(busy, busy_partial, dt),
                    )
                self._finish_prefills(finished, clients, sv.t)
            elif do_prefill and candidate:
                self._commit_pairs(pairs)
                sv.bin_index += 1
                dt, tok = self._run_prefill_stage(pairs)
                if active:
                    self.prefill_stall_time += dt
                busy = {}
                for client, req in pairs:
                    req.client = client.cid
                    req.prefill_bin = sv.bin_index
                    req.t_prefill_start = t
                    req.t_prefill_end = t + dt
                    req.decoded = 1
                    if self.obs is not None:
                        self._obs_admit(req, t, client.cid, False, 0)
                    self._note_first_token(req, t + dt)
                    busy[client.cid] = req.rid
                trace.stages.append(
                    StageRecord(
                        kind=StageKind.PREFILL,
                        t_start=t, t_end=t + dt,
                        bin_index=sv.bin_index, busy=busy, tokens=tok,
                        level=self.profiler.cost_model.level_for(
                            min(tok, max_cap)
                        ).index,
                    )
                )
                sv.t = t + dt
                if self.obs is not None:
                    self.obs.capacity(
                        self.obs_replica, t, sv.t,
                        self._capacity_classes(busy, {}, dt),
                    )
                # requests with n_decode == 1 finish at prefill
                for client, req in pairs:
                    if self.cfg.eos_id is None and req.n_decode <= 1:
                        req.t_done = sv.t
                        self.slots.release(client.cid)
                        client.current = None
                        if self.obs is not None:
                            self._obs_complete(req, sv.t, client.cid)
            elif active:
                k = self._choose_horizon(decision.horizon)
                if paged and cfg.page_reserve != "upfront":
                    # a pinned decode_horizon must run the K it asked for, so
                    # only policy-driven horizons may shrink before evicting
                    k = self._ensure_decode_capacity(
                        k, allow_shrink=cfg.decode_horizon is None
                    )
                    if not self.slots.active_slots:
                        continue   # every decode lane was evicted — re-plan
                dt, finished, tokens = self._run_decode_stage(k)
                # the stage right after a preempting prefill carries the
                # stall in its first-token gap — it belongs to the burst
                if trace.stages and trace.stages[-1].kind is StageKind.PREFILL:
                    burst = True
                busy = {
                    c.cid: c.current.rid for c in active if c.current is not None
                }
                trace.stages.append(
                    StageRecord(
                        kind=StageKind.DECODE,
                        t_start=t, t_end=t + dt,
                        bin_index=max(sv.bin_index, 0), busy=busy,
                        tokens=tokens, rounds=k, burst=burst,
                    )
                )
                sv.t = t + dt
                if self.obs is not None:
                    self.obs.capacity(
                        self.obs_replica, t, sv.t,
                        self._capacity_classes(busy, {}, dt),
                    )
                for slot in finished:
                    req = self.slots.release(slot)
                    req.t_done = sv.t
                    clients[slot].current = None
                    if self.obs is not None:
                        self._obs_complete(req, sv.t, slot)
            else:
                if candidate:
                    continue  # policy refused but nothing to decode: retry
                nxt = getattr(request_scheduler, "next_arrival", None)
                arrival = nxt() if callable(nxt) else None
                if arrival is not None and arrival > t:
                    sv.t = arrival    # idle gap: fast-forward to the arrival
                    return "ran"      # clock progress counts as progress
                return "idle"
            sv.stages_run += 1
            if self.debug_invariants:
                self._check_invariants()
            return "ran"
        raise RuntimeError(
            "engine livelock: policy kept refusing the only runnable stage"
        )

    def finish_serve(self, validate: bool = True) -> ScheduleTrace:
        """Close the session: merge executor counters into the trace and
        (by default) check the trace invariants. Fleet resume paths skip
        validation — a restored replica's trace only covers post-restore
        stages, so 'every request prefilled exactly once' cannot hold."""
        trace = self._sv.trace
        trace.meta.update(
            mixed_rounds=self.mixed_rounds,
            prefill_stall_time_s=round(self.prefill_stall_time, 6),
            decode_dispatches=self.decode_dispatches,
            preemption_events=self.preemption_events,
            peak_concurrency=self.peak_concurrency,
            offline_deferrals=self.offline_deferrals,
            recomputed_tokens=self.recomputed_tokens,
            migrations_in=self.migrations_in,
            migrations_out=self.migrations_out,
            cached_prefill_tokens=self.cache_hit_tokens,
        )
        if self.cfg.kv_layout == "paged":
            trace.meta.update(
                shared_pages_peak=self.slots.shared_pages_peak,
                cow_copies=self.slots.cow_copies,
            )
        if self.obs is not None:
            self._obs_finish(trace)
        if validate:
            trace.validate()
        return trace

    def serve(
        self,
        requests: Sequence[Request],
        clients: List[ClientState],
        request_scheduler: RequestScheduler,
        iteration_policy: IterationPolicy,
        policy_name: str = "",
    ) -> ScheduleTrace:
        """Serve a request set to completion; returns the execution trace."""
        self.begin_serve(
            requests, clients, request_scheduler, iteration_policy,
            policy_name=policy_name,
        )
        while True:
            status = self.serve_step()
            if status == "done":
                break
            if status == "idle":
                raise RuntimeError("engine deadlock: pending but no candidate")
        return self.finish_serve()

    # ------------------------------------------------------------------ #
    # Checkpoint / restore (fault tolerance)                              #
    # ------------------------------------------------------------------ #
    def state_dict(self) -> Dict[str, Any]:
        # in-flight chunked prefills, as fixed-shape per-slot arrays so the
        # checkpoint tree structure is stable across saves (a mid-chunk slot
        # holds pages but is not yet bound — without this a restore would
        # strand its pages and forget the half-prefilled request)
        chunk_rid = np.full(self.cfg.n_slots, -1, np.int32)
        chunk_done = np.zeros(self.cfg.n_slots, np.int32)
        chunk_resume = np.zeros(self.cfg.n_slots, np.int32)
        chunk_pending = np.full(self.cfg.n_slots, -1, np.int32)
        chunk_cached = np.zeros(self.cfg.n_slots, np.int32)
        for slot, st in self._chunking.items():
            chunk_rid[slot] = st.req.rid
            chunk_done[slot] = st.done
            chunk_resume[slot] = st.resume_emitted
            chunk_pending[slot] = st.resume_pending
            chunk_cached[slot] = st.cached
        return {
            "cache": jax.tree_util.tree_map(np.asarray, self.slots.cache),
            "request_of": [
                (r.rid if r is not None else -1) for r in self.slots.request_of
            ],
            "emitted": list(self.slots.emitted),
            "pending_token": self.pending_token.copy(),
            # straggler-mitigation state: a restored engine must remember it
            # was throttling, or one slow node re-eats the full blast radius
            "budget_shift": self._budget_shift,
            "straggler_events": self.straggler_events,
            "chunk_rid": chunk_rid,
            "chunk_done": chunk_done,
            "chunk_resume": chunk_resume,
            "chunk_pending": chunk_pending,
            "chunk_cached": chunk_cached,
            # preempted-and-requeued rids awaiting recompute (their prefixes
            # live in ``generated``, which the fleet checkpoints separately)
            "resume_rids": np.asarray(sorted(self._resume_rids), np.int32),
        }

    def load_state_dict(self, state: Dict[str, Any], requests_by_rid) -> None:
        """Restore engine state. To *resume* serving afterwards, pass the
        request scheduler only the requests that had not yet started —
        restored in-flight work (bound slots, mid-chunk prefills) continues
        from engine state, and re-queueing it would prefill it twice."""
        self.slots.cache = jax.tree_util.tree_map(
            jnp.asarray, state["cache"]
        )
        # rids arrive as arrays from the checkpoint reader — int() them
        # before hashing (a bound slot used to crash the restore here)
        self.slots.request_of = [
            (requests_by_rid[int(rid)] if int(rid) >= 0 else None)
            for rid in state["request_of"]
        ]
        self.slots.emitted = [int(e) for e in state["emitted"]]
        # np.array (not asarray): checkpoint leaves can be read-only views,
        # and the engine writes pending tokens in place every decode stage
        self.pending_token = np.array(state["pending_token"], dtype=np.int32)
        self._dev_pending = None          # rebuild from the restored host copy
        self._budget_shift = int(state.get("budget_shift", 0))
        self.straggler_events = int(state.get("straggler_events", 0))
        self._resume_rids = {
            int(r) for r in np.asarray(state.get("resume_rids", [])).ravel()
        }
        self._chunking = {}
        chunk_rid = np.asarray(state.get("chunk_rid", []))
        chunk_done = np.asarray(state.get("chunk_done", []))
        chunk_resume = np.asarray(state.get("chunk_resume", []))
        chunk_pending = np.asarray(state.get("chunk_pending", []))
        chunk_cached = np.asarray(state.get("chunk_cached", []))
        for slot, rid in enumerate(chunk_rid):
            if rid >= 0:
                req = requests_by_rid[int(rid)]
                prompt = self._prompt_tokens(req)
                re_cnt = int(chunk_resume[slot]) if chunk_resume.size else 0
                re_pend = int(chunk_pending[slot]) if chunk_pending.size else -1
                if re_cnt > 1:
                    # recompute prompt includes the generated prefix — the
                    # caller must restore ``generated`` before engine state
                    # (the fleet does)
                    prefix = list(self.generated.get(int(rid), ()))[:re_cnt]
                    prompt = np.concatenate(
                        [prompt, np.asarray(prefix[:-1], np.int32)]
                    )
                self._chunking[slot] = _ChunkState(
                    slot=slot, req=req, prompt=prompt,
                    done=int(chunk_done[slot]),
                    resume_emitted=re_cnt, resume_pending=re_pend,
                    cached=int(chunk_cached[slot]) if chunk_cached.size else 0,
                )
        if self.cfg.kv_layout == "paged":
            # the device block table is the durable page-ownership record
            self.slots.sync_from_device()
