from .engine import Engine, EngineConfig
from .kv_slots import BlockAllocator, PagedSlotManager, SlotManager
from .profiler import OnlineProfiler
from .sampler import greedy, sample_top_p
