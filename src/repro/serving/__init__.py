from ..core.hetero import ReplicaSpec
from .engine import Engine, EngineConfig
from .fleet import (
    DISPATCH_POLICIES,
    Fleet,
    FleetConfig,
    LeastLoadDispatch,
    ReplicaDispatchPolicy,
    RoundRobinDispatch,
)
from .kv_slots import BlockAllocator, PagedSlotManager, SlotManager
from .profiler import OnlineProfiler
from .sampler import (
    GreedySampler,
    Sampler,
    TopPSampler,
    fold_row_keys,
    greedy,
    sample_top_p,
)
