from ..core.hetero import ReplicaSpec
from .engine import Engine, EngineConfig, SlotCheckpoint
from .fleet import (
    DISPATCH_POLICIES,
    FaultPlan,
    Fleet,
    FleetConfig,
    LeastLoadDispatch,
    ReplicaDispatchPolicy,
    ReplicaFault,
    RoundRobinDispatch,
)
from .health import (
    ALIVE,
    CONDEMNED,
    SUSPECT,
    HealthConfig,
    ReplicaHealthMonitor,
)
from .kv_slots import (
    BlockAllocator,
    PageIntegrityError,
    PagedSlotManager,
    SlotManager,
)
from .overload import OverloadPolicy, SLOAwareOverloadPolicy
from .profiler import OnlineProfiler
from .sampler import (
    GreedySampler,
    Sampler,
    TopPSampler,
    fold_row_keys,
    greedy,
    sample_top_p,
)
