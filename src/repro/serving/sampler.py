"""Token samplers (jit-friendly)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    """(B, V) → (B,) argmax tokens."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_top_p(
    logits: jax.Array, key: jax.Array, top_p: float = 0.9, temperature: float = 1.0
) -> jax.Array:
    """Nucleus sampling. (B, V) → (B,)."""
    logits = logits / jnp.maximum(temperature, 1e-6)
    sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
    sorted_probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(sorted_probs, axis=-1)
    cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
    cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
    filtered = jnp.where(logits >= cutoff, logits, -jnp.inf)
    return jax.random.categorical(key, filtered, axis=-1).astype(jnp.int32)
