"""Token samplers — jit-composable objects usable both *inside* the fused
on-device decode loop (``models.transformer.decode_steps``) and standalone
from host code.

Design:
  * A ``Sampler`` is a frozen dataclass (hashable → safe to close over in a
    jitted function, or to pass as a static argument) mapping per-row logits
    to token ids.
  * Stochastic samplers consume one typed PRNG key **per batch row**
    (``keys: (B,)``). The engine derives row keys by folding a base key with
    the request id and the token index, so a request's token stream is a pure
    function of ``(seed, rid, token_index)`` — independent of how decode
    iterations are grouped into fused horizons, which slot the request lands
    in, or what else is in the batch. That is what makes fused-vs-unfused
    (and dense-vs-paged) streams exactly reproducible.
  * ``greedy`` stays importable as a module-level default (a callable
    ``GreedySampler`` instance), and ``sample_top_p`` keeps its original
    single-key functional form for existing callers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


def fold_row_keys(base_key: jax.Array, rids: jax.Array, steps: jax.Array) -> jax.Array:
    """Per-row sampling keys: fold the engine's base key with each row's
    request id and token index. ``rids``/``steps`` are (B,) int32 (traced
    values are fine — this runs inside the fused decode loop)."""
    return jax.vmap(
        lambda r, s: jax.random.fold_in(jax.random.fold_in(base_key, r), s)
    )(rids, steps)


def _top_p_filter(logits: jax.Array, top_p: float, temperature: float) -> jax.Array:
    """(B, V) logits → (B, V) logits with the nucleus tail set to -inf."""
    logits = logits / jnp.maximum(temperature, 1e-6)
    sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
    sorted_probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(sorted_probs, axis=-1)
    cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
    cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
    return jnp.where(logits >= cutoff, logits, -jnp.inf)


@dataclasses.dataclass(frozen=True)
class Sampler:
    """Base sampler: (B, V) logits → (B,) int32 tokens."""

    #: whether ``keys`` must be provided (drives engine seed requirements)
    stochastic = False

    def __call__(
        self, logits: jax.Array, keys: Optional[jax.Array] = None
    ) -> jax.Array:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class GreedySampler(Sampler):
    def __call__(
        self, logits: jax.Array, keys: Optional[jax.Array] = None
    ) -> jax.Array:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class TopPSampler(Sampler):
    """Nucleus sampling with per-row key threading."""

    top_p: float = 0.9
    temperature: float = 1.0
    stochastic = True

    def __call__(
        self, logits: jax.Array, keys: Optional[jax.Array] = None
    ) -> jax.Array:
        if keys is None:
            raise ValueError(
                "TopPSampler needs per-row PRNG keys; pass keys=(B,) "
                "(the engine threads them from its seed)"
            )
        filtered = _top_p_filter(logits, self.top_p, self.temperature)
        return jax.vmap(
            lambda k, row: jax.random.categorical(k, row, axis=-1)
        )(keys, filtered).astype(jnp.int32)


#: module-level default — callable exactly like the old ``greedy`` function
greedy = GreedySampler()


def sample_top_p(
    logits: jax.Array, key: jax.Array, top_p: float = 0.9, temperature: float = 1.0
) -> jax.Array:
    """Nucleus sampling with one key for the whole batch (legacy form).
    (B, V) → (B,)."""
    filtered = _top_p_filter(logits, top_p, temperature)
    return jax.random.categorical(key, filtered, axis=-1).astype(jnp.int32)
