"""Online profiler — the paper's calibration loop, live.

Measures every executed stage ((tokens, seconds) pairs for prefill stages;
(active clients, fused rounds, seconds) triples for decode stages) and
refits the linear ``CostModel`` the iteration policy consumes. This is how
the scheduler adapts to whatever hardware it actually runs on (the paper fit
400 groups offline; we fit continuously with the same least-squares model) —
and how the per-dispatch cost that prices the fused decode horizon becomes
identifiable, once stages of differing horizons have been observed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.cost_model import CostModel


class OnlineProfiler:
    def __init__(
        self,
        initial: Optional[CostModel] = None,
        refit_every: int = 20,
        max_samples: int = 2000,
    ):
        self.cost_model = initial or CostModel()
        self.prefill_samples: List[Tuple[int, float]] = []
        # (n_active, rounds, seconds) per decode stage
        self.decode_samples: List[Tuple[int, int, float]] = []
        # (n_decode_rows, n_prefill_tokens, seconds) per mixed stage — the
        # separable mixed-batch model t(n_d, n_p) the share-pricing rule
        # consumes (see CostModel.mixed_round_time)
        self.mixed_samples: List[Tuple[int, int, float]] = []
        self.refit_every = refit_every
        self.max_samples = max_samples
        self._since_fit = 0
        self.fits = 0
        # Full prefill+decode refits only — the mixed-constants-only
        # fallback below bumps ``fits`` but leaves the prefill/decode
        # constants at the prior, so cross-replica pricing must not treat
        # it as "this replica has measured itself" (see
        # ``Fleet.pricing_cost_models``).
        self.full_fits = 0

    def record_prefill(self, total_tokens: int, seconds: float) -> None:
        self.prefill_samples.append((total_tokens, seconds))
        self._tick()

    def record_decode(self, n_active: int, seconds: float, rounds: int = 1) -> None:
        """One decode *stage*: ``rounds`` fused iterations over ``n_active``
        clients in ``seconds``. Mixed horizons are what lets the fit separate
        per-dispatch cost from per-round compute (see ``CostModel.fit``)."""
        self.decode_samples.append((n_active, rounds, seconds))
        self._tick()

    def record_mixed(
        self, n_decode: int, n_prefill_tokens: int, seconds: float
    ) -> None:
        """One mixed-step stage: ``n_decode`` decode rows co-dispatched with
        ``n_prefill_tokens`` prefill-chunk tokens in ``seconds``. Variation
        in both counts identifies the per-decode-row and per-prefill-token
        slopes the ``prefill_share`` pricing adapts to."""
        self.mixed_samples.append((n_decode, n_prefill_tokens, seconds))
        self._tick()

    def _tick(self) -> None:
        self._since_fit += 1
        if len(self.prefill_samples) > self.max_samples:
            self.prefill_samples = self.prefill_samples[-self.max_samples :]
        if len(self.decode_samples) > self.max_samples:
            self.decode_samples = self.decode_samples[-self.max_samples :]
        if len(self.mixed_samples) > self.max_samples:
            self.mixed_samples = self.mixed_samples[-self.max_samples :]
        if self._since_fit < self.refit_every:
            return
        if (
            len(set(s[0] for s in self.prefill_samples)) >= 2
            and len(set(s[0] for s in self.decode_samples)) >= 2
        ):
            try:
                self.cost_model = CostModel.fit(
                    self.prefill_samples,
                    self.decode_samples,
                    level_caps=self.cost_model.level_caps,
                    decode_dispatch=self.cost_model.decode_dispatch,
                    mixed_samples=self.mixed_samples,
                )
                self.fits += 1
                self.full_fits += 1
            except Exception:  # noqa: BLE001 — keep serving on a bad fit
                pass
            self._since_fit = 0
            return
        # The full refit needs variation in the prefill AND decode stage
        # samples, which a steady mixed-schedule serve may never produce
        # (nearly every stage feeds record_mixed) — refit just the mixed
        # constants so the share pricing still adapts online.
        params = CostModel.fit_mixed_params(self.mixed_samples)
        if params is not None:
            self.cost_model = dataclasses.replace(
                self.cost_model,
                mixed_overhead=params[0],
                mixed_decode_per_row=params[1],
                mixed_prefill_per_token=params[2],
            )
            self.fits += 1
            self._since_fit = 0

    # ------------------------------------------------------------------ #
    # Checkpoint / restore (per-replica fleet state)                     #
    # ------------------------------------------------------------------ #
    def state_dict(self) -> Dict[str, Any]:
        """The profiler's durable state as fixed-dtype numpy leaves: sample
        windows, fit counters, and the fitted cost-model constants. A
        restored heterogeneous fleet must resume each replica's *own* fit —
        reseeding from the construction prior would forget everything the
        replica learned about its hardware. Optional mixed constants encode
        as NaN (checkpoint leaves must be arrayable)."""
        cm = self.cost_model

        def opt(x: Optional[float]) -> float:
            return float("nan") if x is None else float(x)

        return {
            "prefill_samples": np.asarray(
                self.prefill_samples, dtype=np.float64
            ).reshape(-1, 2),
            "decode_samples": np.asarray(
                self.decode_samples, dtype=np.float64
            ).reshape(-1, 3),
            "mixed_samples": np.asarray(
                self.mixed_samples, dtype=np.float64
            ).reshape(-1, 3),
            "fits": self.fits,
            "full_fits": self.full_fits,
            "since_fit": self._since_fit,
            "cost_model": np.asarray(
                [
                    cm.prefill_per_token,
                    cm.prefill_overhead,
                    cm.decode_per_token,
                    cm.decode_overhead,
                    cm.decode_dispatch,
                    opt(cm.mixed_overhead),
                    opt(cm.mixed_decode_per_row),
                    opt(cm.mixed_prefill_per_token),
                ],
                dtype=np.float64,
            ),
            "level_caps": np.asarray(cm.level_caps, dtype=np.int64),
        }

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        c = np.asarray(state["cost_model"], dtype=np.float64)

        def opt(x: float) -> Optional[float]:
            return None if np.isnan(x) else float(x)

        self.cost_model = CostModel(
            prefill_per_token=float(c[0]),
            prefill_overhead=float(c[1]),
            decode_per_token=float(c[2]),
            decode_overhead=float(c[3]),
            decode_dispatch=float(c[4]),
            mixed_overhead=opt(c[5]),
            mixed_decode_per_row=opt(c[6]),
            mixed_prefill_per_token=opt(c[7]),
            level_caps=tuple(
                int(x) for x in np.asarray(state["level_caps"])
            ),
        )
        self.prefill_samples = [
            (int(t), float(s))
            for t, s in np.asarray(state["prefill_samples"]).reshape(-1, 2)
        ]
        self.decode_samples = [
            (int(n), int(k), float(s))
            for n, k, s in np.asarray(state["decode_samples"]).reshape(-1, 3)
        ]
        self.mixed_samples = [
            (int(n), int(p), float(s))
            for n, p, s in np.asarray(state["mixed_samples"]).reshape(-1, 3)
        ]
        self.fits = int(state["fits"])
        # older checkpoints predate the counter split; treat every recorded
        # fit as full (the conservative reading would permanently hold the
        # fleet on priors instead)
        self.full_fits = int(state.get("full_fits", state["fits"]))
        self._since_fit = int(state["since_fit"])
