"""Online profiler — the paper's calibration loop, live.

Measures every executed stage ((tokens, seconds) pairs for prefill stages;
(active clients, fused rounds, seconds) triples for decode stages) and
refits the linear ``CostModel`` the iteration policy consumes. This is how
the scheduler adapts to whatever hardware it actually runs on (the paper fit
400 groups offline; we fit continuously with the same least-squares model) —
and how the per-dispatch cost that prices the fused decode horizon becomes
identifiable, once stages of differing horizons have been observed.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from ..core.cost_model import CostModel


class OnlineProfiler:
    def __init__(
        self,
        initial: Optional[CostModel] = None,
        refit_every: int = 20,
        max_samples: int = 2000,
    ):
        self.cost_model = initial or CostModel()
        self.prefill_samples: List[Tuple[int, float]] = []
        # (n_active, rounds, seconds) per decode stage
        self.decode_samples: List[Tuple[int, int, float]] = []
        # (n_decode_rows, n_prefill_tokens, seconds) per mixed stage — the
        # separable mixed-batch model t(n_d, n_p) the share-pricing rule
        # consumes (see CostModel.mixed_round_time)
        self.mixed_samples: List[Tuple[int, int, float]] = []
        self.refit_every = refit_every
        self.max_samples = max_samples
        self._since_fit = 0
        self.fits = 0

    def record_prefill(self, total_tokens: int, seconds: float) -> None:
        self.prefill_samples.append((total_tokens, seconds))
        self._tick()

    def record_decode(self, n_active: int, seconds: float, rounds: int = 1) -> None:
        """One decode *stage*: ``rounds`` fused iterations over ``n_active``
        clients in ``seconds``. Mixed horizons are what lets the fit separate
        per-dispatch cost from per-round compute (see ``CostModel.fit``)."""
        self.decode_samples.append((n_active, rounds, seconds))
        self._tick()

    def record_mixed(
        self, n_decode: int, n_prefill_tokens: int, seconds: float
    ) -> None:
        """One mixed-step stage: ``n_decode`` decode rows co-dispatched with
        ``n_prefill_tokens`` prefill-chunk tokens in ``seconds``. Variation
        in both counts identifies the per-decode-row and per-prefill-token
        slopes the ``prefill_share`` pricing adapts to."""
        self.mixed_samples.append((n_decode, n_prefill_tokens, seconds))
        self._tick()

    def _tick(self) -> None:
        self._since_fit += 1
        if len(self.prefill_samples) > self.max_samples:
            self.prefill_samples = self.prefill_samples[-self.max_samples :]
        if len(self.decode_samples) > self.max_samples:
            self.decode_samples = self.decode_samples[-self.max_samples :]
        if len(self.mixed_samples) > self.max_samples:
            self.mixed_samples = self.mixed_samples[-self.max_samples :]
        if self._since_fit < self.refit_every:
            return
        if (
            len(set(s[0] for s in self.prefill_samples)) >= 2
            and len(set(s[0] for s in self.decode_samples)) >= 2
        ):
            try:
                self.cost_model = CostModel.fit(
                    self.prefill_samples,
                    self.decode_samples,
                    level_caps=self.cost_model.level_caps,
                    decode_dispatch=self.cost_model.decode_dispatch,
                    mixed_samples=self.mixed_samples,
                )
                self.fits += 1
            except Exception:  # noqa: BLE001 — keep serving on a bad fit
                pass
            self._since_fit = 0
            return
        # The full refit needs variation in the prefill AND decode stage
        # samples, which a steady mixed-schedule serve may never produce
        # (nearly every stage feeds record_mixed) — refit just the mixed
        # constants so the share pricing still adapts online.
        params = CostModel.fit_mixed_params(self.mixed_samples)
        if params is not None:
            self.cost_model = dataclasses.replace(
                self.cost_model,
                mixed_overhead=params[0],
                mixed_decode_per_row=params[1],
                mixed_prefill_per_token=params[2],
            )
            self.fits += 1
            self._since_fit = 0
