"""Fleet-scale serving: N ``Engine`` replicas under the paper's hybrid
offline-online scheduler, lifted one level up.

The paper's hybrid assigns an offline backlog across *clients* (Minimizing
Makespan Bin Packing, Eqs. 26–30) and then runs online sorting/preemptive
scheduling per client. In this repo the offline layer had only ever driven
the event-driven simulator while the real engine stayed a single replica;
the ``Fleet`` closes that gap by applying the same two ideas at replica
granularity:

  * **offline** — ``solve_offline`` (LPT + local search) partitions the
    backlog across replicas, treating each replica as one of the paper's
    "clients" (``round_robin_assign`` is the unbalanced baseline ablation,
    Fig. 6 at fleet scale). Each replica then serves its partition
    longest-first (Algorithm 1's sort).
  * **online** — arrivals route through a pluggable
    ``ReplicaDispatchPolicy``: least-estimated-load priced through each
    replica's *live fitted* cost model (HyGen-style replica-level
    dispatch), or round-robin. When a replica drains early it *steals* the
    longest not-yet-started request from the most-loaded replica's queue —
    Algorithm 1's request-level straggler mitigation, applied across
    replicas so one straggler cannot set the fleet makespan. A steal is
    only taken when the R||Cmax-priced finish time improves: the candidate
    is priced through the thief's AND the donor's own cost models before
    it moves.

**Heterogeneous fleets** (``core.hetero``): each replica owns its own
``CostModel`` + ``OnlineProfiler`` — seeded from a per-replica prior
(``ReplicaSpec.speed_factor`` scaling the base model, or an explicit
per-replica model) and refit from that replica's own stage timings. A
replica's ``speed_factor`` also scales its virtual-time stage durations,
so a mixed-generation fleet is emulatable and deterministically testable
on one host. When replicas differ, the offline partition solves R||Cmax
(``solve_hetero``: speed-scaled LPT + local search re-priced through each
replica's model) and the fleet floor is
``hetero_theoretical_lower_bound`` — both recover the paper's P||Cmax
forms exactly in the homogeneous case.

Execution model: all replicas share one set of model weights (the same
``params`` device buffers) but own independent KV pools / slot managers.
One process executes every stage, interleaved in *virtual time*: the fleet
always steps the replica whose session clock is lowest, so cross-replica
decisions (arrival routing, stealing) are made at a consistent fleet-wide
"now" even though stages run sequentially. Each replica's trace clock
starts at 0 — "replicas run in parallel" — so the fleet makespan is the
max replica makespan, and fleet utilization divides the summed busy
client-time by makespan × total slots. ``FleetReport`` compares that
makespan against ``theoretical_lower_bound`` evaluated on the whole fleet
as one flat pool of N·slots clients (Eqs. 31–32), the floor no partitioned
execution can beat.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from ..core.cost_model import CostModel
from ..core.hetero import (
    ReplicaSpec,
    evaluate_hetero_assignment,
    hetero_theoretical_lower_bound,
    replica_request_weight,
    replica_resume_weight,
    solve_hetero,
)
from ..core.iteration import IterationPolicy, LagrangianPolicy
from ..core.offline import (
    evaluate_assignment,
    round_robin_assign,
    solve_offline,
    split_requests,
    theoretical_lower_bound,
)
from ..core.online import GlobalQueueScheduler, build_clients
from ..core.types import FleetReport, Request, StageKind
from .engine import Engine, EngineConfig
from .health import (
    ALIVE,
    CONDEMNED,
    SUSPECT,
    HealthConfig,
    ReplicaHealthMonitor,
)
from .kv_slots import PageIntegrityError
from .profiler import OnlineProfiler
from .sampler import greedy

Tree = Any

# Weight-column multiplier pricing a SUSPECT replica out of the offline
# R||Cmax solve: large enough that any trusted replica wins every
# assignment comparison, finite so a degenerate all-suspect fleet still
# partitions instead of dividing by infinity.
HEALTH_SUSPECT_PENALTY = 1024.0


# --------------------------------------------------------------------------- #
# Online replica dispatch                                                     #
# --------------------------------------------------------------------------- #
class ReplicaDispatchPolicy:
    """Chooses the replica an online arrival is admitted to."""

    name = "base"

    def choose(self, fleet: "Fleet", req: Request) -> int:
        raise NotImplementedError


class LeastLoadDispatch(ReplicaDispatchPolicy):
    """Route to the replica with the least estimated outstanding work
    (queued + in-flight, priced by each replica's *current fitted* cost
    model — so a replica whose profiler has learned it is slow prices its
    own queue accordingly) — the replica-level analogue of LPT's
    least-loaded-client rule, made speed-aware. SUSPECT replicas are
    priced out entirely (``dispatchable_replicas``): new work never lands
    on a replica the health monitor distrusts."""

    name = "least_load"

    def choose(self, fleet: "Fleet", req: Request) -> int:
        return min(
            fleet.dispatchable_replicas,
            key=lambda i: (fleet.estimated_load_s(i), i),
        )


class RoundRobinDispatch(ReplicaDispatchPolicy):
    """FCFS round-robin across replicas — the unbalanced baseline.

    The cursor is part of serve state: ``Fleet.begin_serve`` resets it and
    checkpoints carry it, so arrival routing is reproducible across serves
    and across a checkpoint/restore."""

    name = "round_robin"

    def __init__(self) -> None:
        self.cursor = 0

    def reset(self) -> None:
        self.cursor = 0

    def choose(self, fleet: "Fleet", req: Request) -> int:
        ok = set(fleet.dispatchable_replicas)
        for _ in range(fleet.n_replicas):
            i = self.cursor % fleet.n_replicas
            self.cursor += 1
            if i in ok:
                return i
        raise RuntimeError("no alive replica to dispatch to")


DISPATCH_POLICIES = {
    "least_load": LeastLoadDispatch,
    "round_robin": RoundRobinDispatch,
}


# --------------------------------------------------------------------------- #
# Fault injection                                                             #
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ReplicaFault:
    """One fault event at a virtual-time instant.

    ``kind="kill"`` removes the replica from the fleet at ``at_s``: its
    queued AND in-flight requests are recovered onto survivors (see
    ``Fleet._kill_replica``); work it had already *completed* stays
    completed — recovery is exactly-once, never re-serving a finished
    request. ``kind="slow"`` multiplies the replica's ``speed_factor`` by
    ``speed_factor`` (< 1 degrades it — e.g. thermal throttling, a noisy
    neighbor), which both stretches its virtual-time stages and, through
    its profiler's refits, repels future dispatch and invites stealing.

    ``kind="drain"`` gracefully decommissions the replica at ``at_s``
    (rolling restart): dispatch stops, its in-flight slots live-migrate to
    survivors by KV page-copy, its queued work is re-placed through the
    R||Cmax pricing, and the replica retires with zero dropped or
    recomputed tokens. ``pool_readable=True`` on a kill marks a soft
    failure (process exit, host and KV pool still reachable): recovery
    then prefers the same page-copy path, falling back to
    recompute-on-resume only when no survivor can host the pages; a hard
    kill (the default) always recomputes — the pool died with the
    replica.

    **Undeclared faults** — the failure modes the oracle never announces,
    which only the health monitor (``serving.health``) can catch:

      * ``kind="hang"`` stops the replica's progress at ``at_s`` and
        silently resumes it at ``until_s``. The fleet is NOT told: no
        ``fault_log`` entry fires, no recovery is triggered by the plan.
        The replica simply stops heartbeating; detection, condemnation,
        and evacuation are entirely the monitor's job. If it is condemned
        before ``until_s``, the wake-up is a *zombie*: its stale
        completions arrive carrying a fenced epoch and are discarded.
      * ``kind="degrade"`` multiplies the replica's ``speed_factor``
        silently (gray failure: ``speed_factor=0.25`` makes it ×4-slow but
        still progressing), restoring the original speed at ``until_s``
        when given. Unlike ``kind="slow"`` — the declared ablation —
        nothing is logged at apply time; the monitor must notice the
        observed/predicted stage-duration ratio departing from the
        replica's own baseline."""

    replica: int
    at_s: float
    kind: str = "kill"        # "kill" | "slow" | "drain" | "hang" | "degrade"
    speed_factor: float = 0.5             # for kind="slow" / "degrade"
    pool_readable: bool = False           # for kind="kill" only
    until_s: Optional[float] = None       # hang resume / degrade restore time

    def __post_init__(self):
        if self.kind not in ("kill", "slow", "drain", "hang", "degrade"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.at_s < 0:
            raise ValueError("fault time must be >= 0")
        if self.kind in ("slow", "degrade") and self.speed_factor <= 0:
            raise ValueError(f"{self.kind} fault needs a positive speed_factor")
        if self.kind == "hang":
            if self.until_s is None or self.until_s <= self.at_s:
                raise ValueError("hang fault needs until_s > at_s")
        if self.kind == "degrade" and self.until_s is not None:
            if self.until_s <= self.at_s:
                raise ValueError("degrade restore needs until_s > at_s")


@dataclasses.dataclass
class FaultPlan:
    """A deterministic schedule of replica faults, applied as the fleet's
    virtual clock crosses each ``at_s``. Determinism is the point: the same
    plan against the same workload yields the same recovery decisions, so
    fault tolerance is regression-testable (token streams must match the
    no-fault serve bit for bit)."""

    faults: List[ReplicaFault] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.faults = sorted(self.faults, key=lambda f: (f.at_s, f.replica))


@dataclasses.dataclass
class FleetConfig:
    """Fleet shape + scheduling knobs.

    ``assign`` picks the offline backlog partitioner:

      * "lpt"        — the full hybrid: ``solve_offline`` (P||Cmax LPT +
                       local search) on a homogeneous fleet, upgrading to
                       ``solve_hetero`` (R||Cmax, priced through each
                       replica's own live cost model) when replicas differ;
      * "lpt_blind"  — always the P||Cmax solve on the shared base model,
                       ignoring replica speed — the speed-blind ablation a
                       heterogeneous fleet is benchmarked against;
      * "round_robin" — the unbalanced baseline ablation.

    ``dispatch`` picks the online arrival router. Work stealing moves
    queued (not-yet-started) requests from loaded to drained replicas,
    gated on the R||Cmax-priced finish time actually improving; token
    streams are unaffected (prompts and sampling are pure functions of
    (seed, rid), independent of which replica runs them).
    """

    n_replicas: int = 2
    assign: str = "lpt"                  # "lpt" | "lpt_blind" | "round_robin"
    dispatch: str = "least_load"         # key into DISPATCH_POLICIES
    work_stealing: bool = True
    local_search_rounds: int = 200
    # In-flight rebalancing: when a starving replica finds no profitable
    # QUEUED steal, allow it to live-migrate the longest-remaining RUNNING
    # request off the most-loaded donor by KV page-copy — same double-gated
    # R||Cmax makespan check as queued stealing, but priced decode-only
    # (``replica_resume_weight``: the import skips the prefill entirely).
    # Off by default: queued-only stealing is the paper's Algorithm 1
    # baseline; ``benchmarks/chaos.py`` gates that this flag strictly
    # improves fleet makespan on the straggler-tail workload.
    steal_running: bool = False
    # Oracle-free failure detection (serving.health): when set, the fleet
    # stamps per-replica heartbeats at every stage boundary, scores silence
    # through the configured detector, prices SUSPECT replicas out of
    # dispatch/stealing, and condemns + epoch-fences + evacuates replicas
    # the monitor gives up on. None (the default) keeps the PR-7 behavior:
    # only declared faults (the plan / drain_replica calls) trigger
    # recovery.
    health: Optional[HealthConfig] = None


class Fleet:
    def __init__(
        self,
        model,
        params: Tree,
        engine_config: EngineConfig,
        fleet_config: Optional[FleetConfig] = None,
        cost_model: Optional[CostModel] = None,
        sampler: Callable = greedy,
        profiler_factory: Optional[Callable[[], OnlineProfiler]] = None,
        replica_specs: Optional[Sequence[ReplicaSpec]] = None,
    ):
        self.cfg = fleet_config or FleetConfig()
        if self.cfg.n_replicas <= 0:
            raise ValueError("n_replicas must be positive")
        if self.cfg.assign not in ("lpt", "lpt_blind", "round_robin"):
            raise ValueError(f"unknown assign method {self.cfg.assign!r}")
        if self.cfg.dispatch not in DISPATCH_POLICIES:
            raise ValueError(
                f"unknown dispatch policy {self.cfg.dispatch!r}; "
                f"have {sorted(DISPATCH_POLICIES)}"
            )
        self.engine_cfg = engine_config
        # the shared *base* CostModel: the speed-1.0 prior every per-replica
        # model derives from, and what the speed-blind paths price with
        self.cost_model = cost_model or CostModel()
        if replica_specs is None:
            replica_specs = [ReplicaSpec() for _ in range(self.cfg.n_replicas)]
        self.specs: List[ReplicaSpec] = list(replica_specs)
        if len(self.specs) != self.cfg.n_replicas:
            raise ValueError(
                f"replica_specs has {len(self.specs)} entries for "
                f"{self.cfg.n_replicas} replicas"
            )
        # N replicas over ONE set of weights: `params` is passed by
        # reference, so every replica jit-calls the same device buffers.
        # Each Engine owns its KV pool / slot manager AND its own profiler,
        # seeded from its replica's prior cost model — per-replica fits are
        # what make dispatch, stealing, and the R||Cmax solve speed-aware.
        self.engines = [
            Engine(
                model, params, engine_config,
                profiler=(
                    profiler_factory()
                    if profiler_factory is not None
                    else OnlineProfiler(
                        initial=spec.resolve_cost_model(self.cost_model)
                    )
                ),
                sampler=sampler,
                speed_factor=spec.speed_factor,
            )
            for spec in self.specs
        ]
        # shared observability sink (repro.obs.Observation), threaded from
        # EngineConfig.observe: ONE instance across all replicas, so a
        # request's span chain stays causal as it migrates between them
        self.obs = engine_config.observe
        for i, eng in enumerate(self.engines):
            eng.obs_replica = i
            if engine_config.kv_layout == "paged":
                eng.slots.obs_replica = i
        self.dispatcher: ReplicaDispatchPolicy = (
            DISPATCH_POLICIES[self.cfg.dispatch]()
        )
        self.steal_events = 0
        self.steal_log: List[Dict[str, int]] = []
        self._central: List[Request] = []     # future arrivals, sorted
        self._all_requests: List[Request] = []
        self._offline_result = None
        self._resumed = False
        # fault-injection state (per serve; see begin_serve / ReplicaFault)
        self._dead: set = set()
        self._drained: set = set()
        self._pending_faults: List[ReplicaFault] = []
        self.fault_log: List[Dict[str, Any]] = []
        self.recovered_requests = 0
        self._lost_preemptions = 0
        # live-migration accounting (drain / rebalancing / soft-kill paths)
        self.migration_events = 0
        self.migrated_pages = 0
        self.migration_log: List[Dict[str, Any]] = []
        # fault/drain events whose displaced requests are not all re-admitted
        # yet: entries {"entry": fault_log row, "t0": s, "pending": {rid: req}}
        # — drained when ``_note_recoveries`` sees every displaced request
        # bound/chunking on a survivor (or finished), stamping the event's
        # ``recover_s`` (time-to-recover)
        self._recovery_watch: List[Dict[str, Any]] = []
        # pricing_cost_models memo (invalidated by refits/restores via key)
        self._pricing_key: Optional[tuple] = None
        self._pricing_models: List[CostModel] = []
        # --- oracle-free health monitoring + epoch fencing (PR 8) ------- #
        self.monitor: Optional[ReplicaHealthMonitor] = (
            ReplicaHealthMonitor(self.cfg.n_replicas, self.cfg.health)
            if self.cfg.health is not None else None
        )
        if self.monitor is not None:
            self.monitor.obs = self.obs
        # per-serve frozen prediction models for the gray-failure signal:
        # the live profiler keeps refitting to *measured* stages, so a ×4
        # slowdown would be normalized into the very model it is judged
        # against within one refit cycle — predictions for health come from
        # the model as-of-serve-start instead (None until first full fit)
        self._health_cms: List[Optional[CostModel]] = (
            [None] * self.cfg.n_replicas
        )
        # per-replica fencing epoch: bumped BEFORE any evacuation moves
        # state, so every lease granted under the old epoch is dead the
        # instant recovery begins — a zombie's late completions/exports
        # carry a stale epoch and are discarded, never double-served
        self.epochs: List[int] = [0] * self.cfg.n_replicas
        # rid -> (replica, epoch): which replica may complete each request
        self._leases: Dict[int, tuple] = {}
        self.fenced_completions = 0
        self.fenced_exports = 0
        self.fenced_log: List[Dict[str, Any]] = []
        self.redispatch_events = 0
        self.redispatch_log: List[Dict[str, Any]] = []
        self.integrity_rejections = 0
        # undeclared-fault injection state (the monitor NEVER reads these)
        self._hangs: Dict[int, ReplicaFault] = {}
        self._restores: List[Dict[str, Any]] = []
        self._ghosts: Dict[int, Dict[str, Any]] = {}
        self.injected_log: List[Dict[str, Any]] = []

    @property
    def n_replicas(self) -> int:
        return self.cfg.n_replicas

    @property
    def alive_replicas(self) -> List[int]:
        """Replica indices still serving (killed ones are excluded from
        dispatch, stealing, and the step loop; their traces survive)."""
        return [i for i in range(self.cfg.n_replicas) if i not in self._dead]

    @property
    def alive_set(self) -> set:
        return set(range(self.cfg.n_replicas)) - self._dead

    @property
    def healthy_replicas(self) -> List[int]:
        """Alive replicas the health monitor currently trusts (ALIVE, not
        SUSPECT). Falls back to all alive replicas when the monitor
        distrusts everyone — work has to land somewhere."""
        alive = self.alive_replicas
        if self.monitor is None:
            return alive
        ok = [i for i in alive if self.monitor.is_healthy(i)]
        return ok or alive

    @property
    def dispatchable_replicas(self) -> List[int]:
        """Where new work may be routed: healthy replicas, which prices
        SUSPECT replicas out of dispatch entirely (they keep serving what
        they already hold until cleared or condemned)."""
        return self.healthy_replicas

    @property
    def dispatchable_set(self) -> set:
        return set(self.dispatchable_replicas)

    def health_penalties(self) -> Optional[List[float]]:
        """Per-replica weight-column multipliers for the R||Cmax solve
        (``core.hetero.hetero_weights``): 1.0 for trusted replicas, a
        large penalty for SUSPECT ones so the offline partition only
        assigns them work when capacity leaves no alternative."""
        if self.monitor is None:
            return None
        return [
            1.0 if self.monitor.is_healthy(i) else HEALTH_SUSPECT_PENALTY
            for i in range(self.cfg.n_replicas)
        ]

    @property
    def heterogeneous(self) -> bool:
        """True when any replica's construction spec differs from the
        speed-1.0 shared-model default — the trigger for the R||Cmax solver
        and lower bound. (Dispatch and stealing price through
        ``pricing_cost_models`` regardless, homogeneous or not: even
        nominally identical replicas drift apart as their profilers
        refit.)"""
        return any(
            s.speed_factor != 1.0 or s.cost_model is not None
            for s in self.specs
        )

    def pricing_cost_models(self) -> List[CostModel]:
        """The per-replica cost models every cross-replica comparison
        (dispatch load, steal gate, R||Cmax solve, fleet lower bound)
        prices through: each replica's *current fitted* model once every
        replica has FULLY refit (prefill + decode constants) from its own
        measured stages, the per-replica priors until then. The gate
        matters: paper-prior constants can sit orders of magnitude above a
        model fitted to this host, so a fleet where only SOME replicas have
        refit would compare incommensurate scales — the fitted
        (cheap-looking) replicas would absorb the whole backlog and the
        still-on-prior replicas would be starved out of ever collecting
        enough samples to fit. ``full_fits`` (not ``fits``) is the gate: a
        mixed-constants-only refit leaves the prefill/decode constants at
        the prior, which is exactly the half-measured state the gate
        exists to exclude."""
        # memoized per (full-fit counters, live model identities): dispatch
        # and stealing call this once per replica per decision, and the
        # prior branch would otherwise re-construct R scaled models each
        # time — O(R²) allocations per arrival
        key = tuple(
            (eng.profiler.fits, eng.profiler.full_fits,
             id(eng.profiler.cost_model))
            for eng in self.engines
        )
        if key == self._pricing_key:
            return self._pricing_models
        fitted = [eng.profiler.full_fits > 0 for eng in self.engines]
        if any(fitted) and not all(fitted):
            models = [
                spec.resolve_cost_model(self.cost_model) for spec in self.specs
            ]
        else:
            # all fully fitted (live, commensurate: measured on this host)
            # — or none, where each profiler still holds exactly its prior
            models = [eng.profiler.cost_model for eng in self.engines]
        self._pricing_key = key
        self._pricing_models = models
        return models

    def replica_cost_model(self, i: int) -> CostModel:
        """Replica ``i``'s current pricing model (see
        ``pricing_cost_models`` for the live-fit-vs-prior gate)."""
        return self.pricing_cost_models()[i]

    # ------------------------------------------------------------------ #
    # Load estimation (per-replica live-cost-model pricing)              #
    # ------------------------------------------------------------------ #
    def _request_weight_s(
        self, req: Request, remaining_decode: int, cm: CostModel
    ) -> float:
        # the ONE per-request pricing rule, shared with the offline weight
        # matrix (core.hetero) so solve and dispatch can never diverge
        return replica_request_weight(
            req, cm, self.engine_cfg.n_slots, remaining_decode=remaining_decode
        )

    def estimated_load_s(self, i: int) -> float:
        """Estimated seconds of outstanding work per slot on replica ``i``:
        queued requests (full weight), in-flight chunked prefills, and the
        remaining decode of every bound slot, spread over the slot count —
        the replica-level ``remain_token`` of Algorithm 1, in seconds,
        priced through replica ``i``'s own fitted cost model (a slow
        replica's queue is worth more seconds than the same queue on a
        fast one)."""
        eng = self.engines[i]
        cm = self.replica_cost_model(i)
        total = 0.0
        for r in eng._sv.scheduler.queued:
            total += self._request_weight_s(
                r, int(r.n_decode_est or r.n_decode), cm
            )
        for st in eng._chunking.values():
            total += self._request_weight_s(
                st.req, int(st.req.n_decode_est or st.req.n_decode), cm
            )
        for slot in eng.slots.active_slots:
            req = eng.slots.request_of[slot]
            rem = int(req.n_decode_est or req.n_decode) - eng.slots.emitted[slot]
            total += cm.estimated_decode_completion(
                max(rem, 0), eng.cfg.n_slots
            )
        return total / eng.cfg.n_slots

    # ------------------------------------------------------------------ #
    # Serve lifecycle                                                    #
    # ------------------------------------------------------------------ #
    def begin_serve(
        self,
        requests: Sequence[Request],
        iteration_policy_factory: Callable[[], IterationPolicy] = LagrangianPolicy,
        policy_name: str = "",
        fault_plan: Optional[FaultPlan] = None,
    ) -> None:
        """Partition the offline backlog, open every replica's serve
        session, and queue online arrivals for dispatch-on-arrival.
        ``fault_plan`` schedules replica kill/slow events against the
        fleet's virtual clock (see ``ReplicaFault``)."""
        for r in requests:
            r.reset()
        self._all_requests = list(requests)
        self.steal_events = 0
        self.steal_log = []
        self._resumed = False
        self._dead = set()
        self._pending_faults = list(fault_plan.faults) if fault_plan else []
        for f in self._pending_faults:
            if not 0 <= f.replica < self.cfg.n_replicas:
                raise ValueError(
                    f"fault targets replica {f.replica} of a "
                    f"{self.cfg.n_replicas}-replica fleet"
                )
        if len({f.replica for f in self._pending_faults
                if f.kind in ("kill", "drain")}) >= self.cfg.n_replicas:
            raise ValueError("fault plan kills or drains every replica")
        self.fault_log = []
        self.recovered_requests = 0
        self._lost_preemptions = 0
        self._drained = set()
        self.migration_events = 0
        self.migrated_pages = 0
        self.migration_log = []
        self._recovery_watch = []
        # health/fencing state is per serve: replica clocks restart at 0,
        # so heartbeat cursors and epochs from an earlier serve would be in
        # a different timebase (checkpoint restore — load_state_dict —
        # keeps them instead, which is satellite-tested)
        if self.monitor is not None:
            self.monitor.reset()
            # reset() re-runs __init__, which drops the obs attribute
            self.monitor.obs = self.obs
        self._health_cms = [
            eng.profiler.cost_model if eng.profiler.full_fits > 0 else None
            for eng in self.engines
        ]
        self.epochs = [0] * self.cfg.n_replicas
        self._leases = {}
        self.fenced_completions = 0
        self.fenced_exports = 0
        self.fenced_log = []
        self.redispatch_events = 0
        self.redispatch_log = []
        self.integrity_rejections = 0
        self._hangs = {}
        self._restores = []
        self._ghosts = {}
        self.injected_log = []
        if hasattr(self.dispatcher, "reset"):
            self.dispatcher.reset()
        offline = [r for r in requests if r.arrival <= 0.0]
        online = sorted(
            (r for r in requests if r.arrival > 0.0),
            key=lambda r: (r.arrival, r.rid),
        )
        n = self.cfg.n_replicas
        slots = self.engine_cfg.n_slots
        live_cms = self.pricing_cost_models()
        if self.cfg.assign == "lpt" and self.heterogeneous:
            # R||Cmax: the partition prices each request through every
            # replica's OWN live fit (speed-scaled LPT + local search)
            self._offline_result = solve_hetero(
                offline, live_cms, slots,
                local_search_rounds=self.cfg.local_search_rounds,
                replica_penalties=self.health_penalties(),
            )
        elif self.cfg.assign in ("lpt", "lpt_blind"):
            blind = solve_offline(
                offline, n, self.cost_model,
                local_search_rounds=self.cfg.local_search_rounds,
            )
            if self.heterogeneous:
                # speed-blind ablation on a mixed fleet: keep the P||Cmax
                # partition but report honest per-replica loads and the
                # R||Cmax bound, so blind-vs-aware runs compare like for like
                self._offline_result = evaluate_hetero_assignment(
                    offline, blind.assignment, live_cms, slots,
                    solver="lpt_blind",
                )
            else:
                self._offline_result = blind
        else:
            rr = round_robin_assign(offline, n)
            if self.heterogeneous:
                self._offline_result = evaluate_hetero_assignment(
                    offline, rr, live_cms, slots, solver="round_robin",
                )
            else:
                self._offline_result = evaluate_assignment(
                    offline, rr, n, self.cost_model, solver="round_robin",
                )
        parts = split_requests(offline, self._offline_result.assignment)
        self._central = online
        base = policy_name or f"fleet/{self.cfg.assign}"
        for i, eng in enumerate(self.engines):
            clients = build_clients(eng.cfg.n_slots, [], None)
            # per-replica FCFS queue over the partition, longest-first
            # (Algorithm 1's sort); fleet dispatch/stealing push into it
            sched = GlobalQueueScheduler(parts[i], sort_longest_first=True)
            for r in parts[i]:
                self._grant_lease(r.rid, i)
            eng.begin_serve(
                [], clients, sched, iteration_policy_factory(),
                policy_name=f"{base}/r{i}", track_requests=True,
            )

    def _grant_lease(self, rid: int, replica: int) -> None:
        """Record that ``replica`` (at its CURRENT epoch) owns ``rid``.
        Every ownership transfer — offline partition, dispatch, steal,
        migration, recovery placement, redispatch — re-grants, so exactly
        one ``(replica, epoch)`` pair may ever complete the request."""
        self._leases[rid] = (replica, self.epochs[replica])

    def _route_arrivals(self, now: float) -> None:
        """Admit every central request whose arrival has passed, each to the
        replica the dispatch policy picks *at this moment* (load changes as
        earlier arrivals land, so routing is one-at-a-time)."""
        while self._central and self._central[0].arrival <= now:
            req = self._central.pop(0)
            i = self.dispatcher.choose(self, req)
            if self.obs is not None:
                # the priced inputs the dispatcher chose over: every
                # candidate's estimated outstanding work at this instant
                self.obs.audit_record(
                    "dispatch", now, i,
                    {
                        "rid": req.rid,
                        "arrival": round(req.arrival, 6),
                        "policy": self.dispatcher.name,
                        "loads_s": {
                            str(j): round(self.estimated_load_s(j), 6)
                            for j in self.dispatchable_replicas
                        },
                    },
                    i,
                )
            self._grant_lease(req.rid, i)
            self.engines[i]._sv.scheduler.push(req)

    def _earliest_slot_free_s(self, j: int) -> float:
        """Cost-model estimate of the absolute fleet time at which replica
        ``j`` next frees a slot: its clock plus the smallest remaining
        per-slot work (decode rounds left, or chunk tokens + decode for a
        mid-prefill slot), priced through replica ``j``'s own fitted model.
        The steal gate compares this against the thief's clock — measured
        clocks alone are not comparable when one replica's stages carried
        one-off costs (e.g. first-hit compiles)."""
        eng = self.engines[j]
        cm = self.replica_cost_model(j)
        waits = []
        for slot in eng.slots.active_slots:
            req = eng.slots.request_of[slot]
            rem = int(req.n_decode_est or req.n_decode) - eng.slots.emitted[slot]
            waits.append(
                cm.estimated_decode_completion(max(rem, 0), eng.cfg.n_slots)
            )
        for st in eng._chunking.values():
            waits.append(
                cm.prefill_time(st.remaining)
                + cm.estimated_decode_completion(
                    int(st.req.n_decode_est or st.req.n_decode), eng.cfg.n_slots
                )
            )
        return eng.clock + (min(waits) if waits else 0.0)

    def _steal_improves(
        self, thief: int, donor: int, victim: Request,
        explain: Optional[dict] = None,
    ) -> bool:
        """The R||Cmax steal gate: the move is taken only when BOTH

          * the victim's estimated finish time improves — the thief starts
            it now (its own clock) and runs it at its own speed, versus
            waiting for the donor's earliest freed slot and running at the
            donor's speed; and
          * the pair's estimated *completion* makespan improves — moving
            work onto a slower starving replica can finish the victim
            sooner yet make the thief the fleet's new straggler, which is
            exactly the regression R||Cmax pricing exists to prevent.

        Every term is priced through that replica's own fitted cost model,
        so a fast drained replica readily steals from a slow loaded one
        while the reverse steal prices itself out unless the donor's queue
        is deep enough that the move helps even at the thief's speed."""
        cms = self.pricing_cost_models()
        est = int(victim.n_decode_est or victim.n_decode)
        w_thief = self._request_weight_s(victim, est, cms[thief])
        w_donor = self._request_weight_s(victim, est, cms[donor])
        thief_finish = self.engines[thief].clock + w_thief
        donor_finish = self._earliest_slot_free_s(donor) + w_donor
        if explain is not None:
            explain.update(
                rid=victim.rid, thief=thief, donor=donor,
                thief_finish_s=round(thief_finish, 6),
                donor_finish_s=round(donor_finish, 6),
            )
        if thief_finish >= donor_finish:
            if explain is not None:
                explain["rejected_by"] = "finish_time"
            return False
        n = self.engine_cfg.n_slots
        thief_done = self.engines[thief].clock + self.estimated_load_s(thief)
        donor_done = self.engines[donor].clock + self.estimated_load_s(donor)
        before = max(thief_done, donor_done)
        after = max(thief_done + w_thief / n, donor_done - w_donor / n)
        ok = after < before - 1e-12
        if explain is not None:
            explain.update(
                makespan_before_s=round(before, 6),
                makespan_after_s=round(after, 6),
            )
            if not ok:
                explain["rejected_by"] = "pair_makespan"
        return ok

    def _migration_improves(
        self, thief: int, donor: int, victim: Request, remaining: int,
        explain: Optional[dict] = None,
    ) -> bool:
        """The in-flight analogue of ``_steal_improves``, priced decode-only
        (``replica_resume_weight`` — a page-copy import re-pays no prefill).
        The victim is RUNNING on the donor right now, so its status-quo
        finish is the donor's clock plus its remaining decode at the donor's
        speed (no slot wait); both the finish-time gate and the pair-makespan
        gate must still improve for the migration to commit — on a
        homogeneous pair neither can, which is exactly right: moving a
        running request between equal machines buys nothing."""
        cms = self.pricing_cost_models()
        n = self.engine_cfg.n_slots
        w_thief = replica_resume_weight(victim, cms[thief], n, remaining)
        w_donor = replica_resume_weight(victim, cms[donor], n, remaining)
        thief_finish = self.engines[thief].clock + w_thief
        donor_finish = self.engines[donor].clock + w_donor
        if explain is not None:
            explain.update(
                rid=victim.rid, thief=thief, donor=donor,
                remaining_decode=remaining,
                thief_finish_s=round(thief_finish, 6),
                donor_finish_s=round(donor_finish, 6),
            )
        if thief_finish >= donor_finish:
            if explain is not None:
                explain["rejected_by"] = "finish_time"
            return False
        thief_done = self.engines[thief].clock + self.estimated_load_s(thief)
        donor_done = self.engines[donor].clock + self.estimated_load_s(donor)
        before = max(thief_done, donor_done)
        after = max(thief_done + w_thief / n, donor_done - w_donor / n)
        ok = after < before - 1e-12
        if explain is not None:
            explain.update(
                makespan_before_s=round(before, 6),
                makespan_after_s=round(after, 6),
            )
            if not ok:
                explain["rejected_by"] = "pair_makespan"
        return ok

    def _try_steal_running(self, thief: int) -> bool:
        """In-flight rebalancing (``FleetConfig.steal_running``): migrate
        the longest-remaining RUNNING request off the most-loaded donor onto
        the starving thief by KV page-copy, when the double-gated makespan
        check approves. This is the straggler-tail case queued-only stealing
        structurally cannot touch: once every queue is empty, the only work
        left to rebalance is already bound to a slot. Donors must be
        healthy: a page-copy export is exactly the operation a replica the
        monitor distrusts should not be performing."""
        for j in sorted(
            (
                k for k in self.healthy_replicas
                if k != thief and k not in self._hangs
            ),
            key=lambda k: (-self.estimated_load_s(k), k),
        ):
            donor = self.engines[j]
            best: Optional[tuple] = None     # (remaining, slot, req)
            for slot in donor.slots.active_slots:
                req = donor.slots.request_of[slot]
                rem = (
                    int(req.n_decode_est or req.n_decode)
                    - donor.slots.emitted[slot]
                )
                if rem <= 1:
                    continue                 # nothing meaningful left to move
                if best is None or rem > best[0]:
                    best = (rem, slot, req)
            if best is None:
                continue
            rem, slot, req = best
            now = self.engines[thief].clock
            explain = {} if self.obs is not None else None
            improved = self._migration_improves(thief, j, req, rem, explain)
            if explain is not None:
                self.obs.audit_record(
                    "migration_gate", now, thief, explain,
                    "migrate" if improved else "reject",
                )
            if not improved:
                continue
            if not self.migrate_slot(j, slot, thief):
                continue
            self.steal_log.append(
                {"rid": req.rid, "from": j, "to": thief, "running": 1}
            )
            if self.obs is not None:
                self.obs.instant(
                    "steal", now, replica=thief, rid=req.rid,
                    donor=j, running=1,
                )
            return True
        return False

    def _try_steal(self) -> None:
        """Move the longest queued request from the most-loaded replica to
        each starving one (idle slot, empty queue). Queued work cannot start
        on its owner (all donor slots busy — otherwise it would not be
        queued); the steal commits only when the R||Cmax-priced finish time
        improves (``_steal_improves``). With ``steal_running`` on, a thief
        that finds no profitable queued steal escalates to migrating a
        running slot (``_try_steal_running``).

        A thief must be healthy (stealing INTO a SUSPECT replica would pile
        work onto a machine the monitor distrusts) and not hung (a stalled
        process cannot execute its steal loop). Queued-steal *donors* may be
        SUSPECT — draining a distrusted replica's queue is desirable."""
        for i, eng in enumerate(self.engines):
            if i in self._dead or i in self._hangs:
                continue
            if self.monitor is not None and not self.monitor.is_healthy(i):
                continue
            sched = eng._sv.scheduler
            idle_slots = [
                s for s in eng.slots.free_slots if s not in eng._chunking
            ]
            if sched.queued or not idle_slots:
                continue
            donors = [
                j for j, other in enumerate(self.engines)
                if j != i and j not in self._dead
                and other._sv.scheduler.queued
                # a donor with a genuinely free slot runs its own queue next
                # step — only steal from replicas whose slots are all busy
                and all(
                    s in other._chunking for s in other.slots.free_slots
                )
            ]
            stole = False
            # most-loaded donors first (Algorithm 1's argmax remain_token)
            for j in sorted(
                donors, key=lambda k: (-self.estimated_load_s(k), k)
            ):
                donor_sched = self.engines[j]._sv.scheduler
                victim = donor_sched.peek_longest()
                if victim is None:
                    continue
                explain = {} if self.obs is not None else None
                improved = self._steal_improves(i, j, victim, explain)
                if explain is not None:
                    self.obs.audit_record(
                        "steal_gate", self.engines[i].clock, i, explain,
                        "steal" if improved else "reject",
                    )
                if not improved:
                    continue
                stolen = donor_sched.steal_longest()
                assert stolen is victim
                sched.push(stolen)
                self._grant_lease(stolen.rid, i)
                self.steal_events += 1
                self.steal_log.append({"rid": stolen.rid, "from": j, "to": i})
                if self.obs is not None:
                    self.obs.instant(
                        "steal", self.engines[i].clock, replica=i,
                        rid=stolen.rid, donor=j, running=0,
                    )
                stole = True
                break
            if not stole and self.cfg.steal_running:
                self._try_steal_running(i)

    # ------------------------------------------------------------------ #
    # Fault injection / recovery                                          #
    # ------------------------------------------------------------------ #
    def _apply_due_faults(self, now: float) -> int:
        """Fire every pending fault whose instant the fleet clock has
        reached. Returns how many fired (the step loop re-derives its
        worker set when membership changed).

        Declared kinds (kill/slow/drain) tell the fleet — they append to
        ``fault_log`` and trigger recovery directly. Undeclared kinds
        (hang/degrade) only mutate the injection layer (``_hangs``, the
        engine's ``speed_factor``) and the chaos harness's ground-truth
        ``injected_log``; the fleet's scheduling/recovery code and the
        health monitor learn of them solely through missing or slowed
        heartbeats."""
        fired = 0
        while self._pending_faults and self._pending_faults[0].at_s <= now:
            f = self._pending_faults.pop(0)
            if f.replica in self._dead:
                continue                      # already gone; fault is moot
            if f.kind in ("kill", "drain"):
                if len(self._dead) + 1 >= self.cfg.n_replicas:
                    raise RuntimeError(
                        "fault plan killed or drained every replica"
                    )
                if f.kind == "drain":
                    self._evacuate_replica(
                        f.replica, now, pool_readable=True, kind="drain"
                    )
                else:
                    self._kill_replica(
                        f.replica, now, pool_readable=f.pool_readable
                    )
            elif f.kind == "hang":
                self._hangs[f.replica] = f
                self.injected_log.append({
                    "kind": "hang", "replica": f.replica, "at_s": f.at_s,
                    "applied_at_s": now, "until_s": f.until_s,
                })
                if self.obs is not None:
                    self.obs.instant(
                        "injected_fault", now, replica=f.replica,
                        fault="hang", until_s=f.until_s,
                    )
            elif f.kind == "degrade":
                eng = self.engines[f.replica]
                prev = eng.speed_factor
                eng.speed_factor = prev * f.speed_factor
                if f.until_s is not None:
                    self._restores.append({
                        "at_s": f.until_s, "replica": f.replica,
                        "speed_factor": prev,
                    })
                self.injected_log.append({
                    "kind": "degrade", "replica": f.replica, "at_s": f.at_s,
                    "applied_at_s": now, "speed_factor": eng.speed_factor,
                    "until_s": f.until_s,
                })
                if self.obs is not None:
                    self.obs.instant(
                        "injected_fault", now, replica=f.replica,
                        fault="degrade", speed_factor=eng.speed_factor,
                    )
            else:
                eng = self.engines[f.replica]
                eng.speed_factor = eng.speed_factor * f.speed_factor
                self.fault_log.append({
                    "kind": "slow", "replica": f.replica, "at_s": f.at_s,
                    "applied_at_s": now, "speed_factor": eng.speed_factor,
                })
                if self.obs is not None:
                    self.obs.instant(
                        "fault", now, replica=f.replica, fault="slow",
                        speed_factor=eng.speed_factor,
                    )
            fired += 1
        return fired

    def _apply_due_injections(self, now: float) -> int:
        """Advance the undeclared-fault injection layer to ``now``: restore
        degraded speeds whose window ended, and wake hung replicas whose
        ``until_s`` has passed. A wake-up of a replica that was condemned
        while hung fires its ghost — the zombie replays its stale in-flight
        completions, which ``deliver_completion`` must fence."""
        fired = 0
        still: List[Dict[str, Any]] = []
        for r in self._restores:
            if r["at_s"] <= now:
                if r["replica"] not in self._dead:
                    self.engines[r["replica"]].speed_factor = r["speed_factor"]
                self.injected_log.append({
                    "kind": "degrade_end", "replica": r["replica"],
                    "at_s": r["at_s"], "applied_at_s": now,
                })
                fired += 1
            else:
                still.append(r)
        self._restores = still
        for i, f in list(self._hangs.items()):
            if f.until_s is not None and f.until_s <= now:
                del self._hangs[i]
                self.injected_log.append({
                    "kind": "hang_end", "replica": i,
                    "at_s": f.until_s, "applied_at_s": now,
                })
                self._fire_ghost(i, now)
                fired += 1
        return fired

    def _fire_ghost(self, i: int, now: float) -> None:
        """Replay replica ``i``'s ghost: the in-flight work it held at
        condemnation, delivered now that the 'dead' process woke up. Every
        delivery carries the pre-condemnation epoch, so the fence discards
        them all — the hard acceptance gate is zero double-served tokens."""
        g = self._ghosts.pop(i, None)
        if g is None:
            return
        for rid, tokens in g["work"]:
            self.deliver_completion(i, g["epoch"], rid, tokens, now)

    def deliver_completion(
        self, replica: int, epoch: int, rid: int, tokens: List[int], now: float
    ) -> bool:
        """The fleet's single completion-acceptance gate: replica
        ``replica`` claims (under lease epoch ``epoch``) to have produced
        ``tokens`` for ``rid``. Accepted only when the epoch is the
        replica's CURRENT epoch, the request's lease names exactly this
        ``(replica, epoch)``, and the replica is not dead — otherwise the
        delivery is a zombie's and is fenced: counted, logged, discarded.

        In-process engines write their tokens directly (their lease is
        implicit in where the fleet queued the request); this explicit path
        exists for late/out-of-band deliveries — ghosts of condemned
        replicas replaying what they held. If the fence ever failed open,
        the stale write would land in a second engine's ``generated`` and
        the ``Fleet.generated`` merge would raise — a tripwire, not a
        handler."""
        reason = None
        if replica in self._dead:
            reason = "replica dead"
        elif epoch != self.epochs[replica]:
            reason = f"stale epoch {epoch} (current {self.epochs[replica]})"
        elif self._leases.get(rid) != (replica, epoch):
            reason = f"lease mismatch (held {self._leases.get(rid)})"
        if reason is not None:
            self.fenced_completions += 1
            self.fenced_log.append({
                "kind": "completion", "replica": replica, "epoch": epoch,
                "rid": rid, "n_tokens": len(tokens), "at_s": now,
                "reason": reason,
            })
            if self.obs is not None:
                self.obs.instant(
                    "fenced", now, replica=replica, rid=rid,
                    epoch=epoch, reason=reason,
                )
            return False
        self.engines[replica].generated[rid] = list(tokens)
        return True

    def _condemn_replica(self, i: int, now: float, reason: str) -> None:
        """Act on the monitor's verdict: fence replica ``i`` (epoch bump
        happens inside ``_evacuate_replica``, before any state moves) and
        evacuate its work onto survivors. Pool-readable page-copy is
        attempted first — condemnation is a *suspicion*, the host may well
        be reachable — with recompute-on-resume as the fallback.

        Before evacuating, the replica's in-flight work is snapshotted as a
        ghost under the pre-condemnation epoch: if the replica was merely
        stalled and later wakes, it replays those completions and the fence
        must discard every one.

        Refuses to condemn the last alive replica (a fleet that beheads
        itself on suspicion is worse than one that waits): the monitor's
        verdict is demoted back to SUSPECT and re-evaluated as the gap
        evidence accumulates."""
        if len(self._dead) + 1 >= self.cfg.n_replicas:
            self.monitor._transition(
                i, SUSPECT, now, "condemn refused: last alive"
            )
            return
        eng = self.engines[i]
        old_epoch = self.epochs[i]
        ghost_work: List[tuple] = []
        for slot in list(eng.slots.active_slots):
            rid = eng.slots.request_of[slot].rid
            ghost_work.append((rid, list(eng.generated.get(rid, []))))
        for st in eng._chunking.values():
            rid = st.req.rid
            ghost_work.append((rid, list(eng.generated.get(rid, []))))
        # queued work too: a stalled-but-not-dead process still holds its
        # queue and would serve it on wake — every one of those deliveries
        # must hit the fence
        for req in eng._sv.scheduler.queued:
            ghost_work.append((req.rid, list(eng.generated.get(req.rid, []))))
        self._ghosts[i] = {"epoch": old_epoch, "work": ghost_work}
        if self.obs is not None:
            self.obs.instant(
                "condemn", now, replica=i, reason=reason,
                fenced_epoch=old_epoch,
            )
            self.obs.audit_record(
                "condemn", now, i,
                {
                    "reason": reason,
                    "fenced_epoch": old_epoch,
                    "ghost_work": len(ghost_work),
                },
                "evacuate",
            )
        entry = self._evacuate_replica(i, now, pool_readable=True, kind="condemn")
        entry["reason"] = reason
        entry["fenced_epoch"] = old_epoch

    def _placement_cost(self, j: int, req: Request, in_flight: bool) -> float:
        """Estimated absolute fleet time at which survivor ``j`` would
        finish a displaced request: its clock, plus its outstanding load,
        plus the request's own service time — decode-only for an in-flight
        page-copy (no prefill is re-paid), full weight for queued work.
        Every term prices through replica ``j``'s own fitted cost model:
        drain and recovery placement are R||Cmax decisions like any other."""
        cm = self.replica_cost_model(j)
        est = int(req.n_decode_est or req.n_decode)
        if in_flight:
            w = replica_resume_weight(
                req, cm, self.engine_cfg.n_slots, max(est - req.decoded, 0)
            )
        else:
            w = self._request_weight_s(req, est, cm)
        return self.engines[j].clock + self.estimated_load_s(j) + w

    def _choose_placement(
        self,
        candidates: Sequence[int],
        req: Request,
        in_flight: bool,
        now: float,
        context: str,
    ) -> int:
        """Pick the cheapest-completion survivor for a displaced request
        and, when observing, audit the full comparison — every candidate's
        priced completion time next to the one chosen."""
        costs = {j: self._placement_cost(j, req, in_flight) for j in candidates}
        chosen = min(candidates, key=lambda j: (costs[j], j))
        if self.obs is not None:
            self.obs.audit_record(
                "placement", now, chosen,
                {
                    "rid": req.rid,
                    "context": context,
                    "in_flight": bool(in_flight),
                    "costs_s": {
                        str(j): round(costs[j], 6) for j in sorted(costs)
                    },
                },
                chosen,
            )
        return chosen

    def migrate_slot(
        self, src: int, slot: int, dst: int, src_epoch: Optional[int] = None
    ):
        """Live-migrate one in-flight slot from replica ``src`` to ``dst``
        by KV page-copy: export the slot checkpoint (pages + pending token
        + sampler cursor), import it into freshly allocated pages on the
        destination, zero recomputed tokens, bit-identical stream.

        Returns ``"page_copy"`` on the clean path; ``"recompute"`` when the
        payload failed its integrity check at import (the corrupted pages
        are rejected and the request falls back to recompute-on-resume from
        its trusted generated prefix — stream still bit-identical); False —
        with no state changed — when ``dst`` cannot host it, or when
        ``src_epoch`` is given and stale (the exporter was fenced
        mid-flight: the export is discarded, never imported). Both success
        strings are truthy, so boolean callers keep working."""
        if src == dst:
            raise ValueError("migration source and destination coincide")
        if src_epoch is not None and src_epoch != self.epochs[src]:
            self.fenced_exports += 1
            self.fenced_log.append({
                "kind": "export", "replica": src, "epoch": src_epoch,
                "slot": slot, "to": dst,
                "reason": f"stale epoch {src_epoch} "
                          f"(current {self.epochs[src]})",
            })
            return False
        src_eng, dst_eng = self.engines[src], self.engines[dst]
        if not dst_eng.can_import(src_eng.slot_pages(slot)):
            return False
        ckpt = src_eng.export_slot(slot)
        ckpt.src_replica = src
        ckpt.src_epoch = self.epochs[src]
        req = ckpt.req
        try:
            dst_eng.import_slot(ckpt)
        except PageIntegrityError:
            # the export already consumed the source slot, so the pages are
            # unrecoverable — but the generated prefix in the checkpoint is
            # host memory, not KV payload, and stays trusted: recompute it
            # on the destination (the PR-6 recovery path)
            self.integrity_rejections += 1
            self._lost_preemptions += req.preemptions
            req.preemptions = 0
            req.client = None
            self._grant_lease(req.rid, dst)
            if ckpt.prefix:
                dst_eng.adopt_resume(req, ckpt.prefix)
            else:
                dst_eng._sv.scheduler.push(req)
            self.migration_log.append({
                "rid": req.rid, "from": src, "to": dst,
                "pages": 0, "kind": ckpt.kind, "integrity_rejected": 1,
            })
            return "recompute"
        self._grant_lease(req.rid, dst)
        self.migration_events += 1
        self.migrated_pages += ckpt.n_pages
        self.migration_log.append({
            "rid": req.rid, "from": src, "to": dst,
            "pages": ckpt.n_pages, "kind": ckpt.kind,
        })
        if self.obs is not None:
            self.obs.instant(
                "migration", self.engines[dst].clock, replica=dst,
                rid=req.rid, src=src, pages=ckpt.n_pages, state=ckpt.kind,
            )
        return "page_copy"

    def drain_replica(self, i: int, now: Optional[float] = None) -> Dict[str, Any]:
        """Gracefully retire replica ``i`` mid-serve (rolling restart):
        stop dispatching to it, live-migrate its in-flight slots to
        survivors by page-copy, re-place its queued work through the
        R||Cmax pricing, and mark it retired — zero dropped requests and
        (pool headroom permitting) zero recomputed tokens. Returns the
        fault-log entry recording what moved and how."""
        if i in self._dead:
            raise ValueError(f"replica {i} is already retired")
        if len(self._dead) + 1 >= self.cfg.n_replicas:
            raise RuntimeError("cannot drain the last alive replica")
        if now is None:
            now = self.engines[i].clock
        return self._evacuate_replica(i, now, pool_readable=True, kind="drain")

    def _kill_replica(
        self, i: int, now: float, pool_readable: bool = False
    ) -> None:
        """Remove replica ``i`` from the fleet and recover its outstanding
        work onto survivors, exactly-once. A hard kill (the default) lost
        its KV pool with the process: in-flight requests recompute their
        generated prefix on a survivor. With ``pool_readable=True`` (soft
        failure) recovery prefers page-copy migration — see
        ``_evacuate_replica``."""
        self._evacuate_replica(i, now, pool_readable=pool_readable, kind="kill")

    def _evacuate_replica(
        self, i: int, now: float, pool_readable: bool, kind: str
    ) -> Dict[str, Any]:
        """Move every piece of replica ``i``'s outstanding work onto
        survivors and retire it, exactly-once:

          * **finished** requests stay finished — their tokens remain in
            the dead engine's ``generated`` record and their trace rows in
            its (kept) trace; they are never re-served;
          * **in-flight** requests (bound decode slots, mid-chunk prefills)
            live-migrate by KV page-copy when the source pool is readable
            (drain / soft kill) and a survivor can host the pages — zero
            recomputed tokens, the stream just continues; otherwise they
            fall back to PR-6-style recompute-on-resume (generated prefix
            re-prefilled on the survivor, stream still bit-identical);
          * **queued** requests move to the cheapest-completion survivor
            (``_placement_cost``, the R||Cmax pricing).

        Recompute-recovered requests restart their trace life on the
        survivor: rows the dead replica recorded for them are stripped from
        its trace and their preemption counters reset (preserved in the
        report meta as ``lost_preemptions``). Page-copied requests instead
        carry their full prefill history with them via the checkpoint's
        prefill credit — nothing resets, the destination trace simply
        credits the completions that happened elsewhere."""
        eng = self.engines[i]
        sv = eng._sv
        # retire FIRST so placement/pricing never targets the victim, and
        # fence BEFORE any state moves: every lease granted to this replica
        # dies here, so nothing it later claims (a zombie waking from a
        # hang) can be mistaken for current work
        self._dead.add(i)
        self.epochs[i] += 1
        if kind == "drain":
            self._drained.add(i)
        self._pricing_key = None              # membership changed
        page_copied = 0
        integrity_fb = 0                      # corrupted-payload fallbacks
        recompute: List[tuple] = []           # (request, prefix tokens)
        displaced: Dict[int, Request] = {}
        # in-flight work: page-copy where possible, recompute otherwise
        in_flight = [(s, True) for s in list(eng.slots.active_slots)]
        in_flight += [(s, False) for s in list(eng._chunking)]
        for slot, bound in in_flight:
            req = (
                eng.slots.request_of[slot] if bound
                else eng._chunking[slot].req
            )
            displaced[req.rid] = req
            if pool_readable:
                n_pages = eng.slot_pages(slot)
                cands = [
                    j for j in self.healthy_replicas
                    if self.engines[j].can_import(n_pages)
                ]
                if cands:
                    dst = self._choose_placement(
                        cands, req, bound, now, f"evacuate:{kind}"
                    )
                    res = self.migrate_slot(i, slot, dst)
                    if res == "page_copy":
                        page_copied += 1
                    else:                     # integrity-rejected payload
                        integrity_fb += 1
                    continue
            # hard kill, or no survivor can host the pages right now
            if bound:
                prefix = eng.generated.pop(req.rid, [])
                eng.slots.release(slot)
                sv.clients[slot].current = None
            else:
                st = eng._chunking.pop(slot)
                eng.slots.free_pages_of(slot)
                prefix = eng.generated.pop(st.req.rid, [])
            recompute.append((req, prefix))
        n_recompute = len(recompute)          # in-flight fallbacks only
        # queued: never started here — but an earlier preemptee waiting to
        # resume still owns its prefix
        moved_queued = 0
        for req in list(sv.scheduler.queued):
            sv.scheduler.commit(None, req)    # remove from the dead queue
            displaced[req.rid] = req
            prefix = eng.generated.pop(req.rid, [])
            recompute.append((req, prefix))
            moved_queued += 1
        eng._resume_rids.clear()
        # the dead trace keeps only work it *finished*; unfinished rows move
        # with their requests to the survivor's trace
        sv.trace.requests = [r for r in sv.trace.requests if r.t_done is not None]
        for req, prefix in recompute:
            self._lost_preemptions += req.preemptions
            req.preemptions = 0
            req.client = None
            if kind in ("drain", "condemn"):
                tgt_i = self._choose_placement(
                    self.healthy_replicas, req, False, now,
                    f"evacuate:{kind}",
                )
            else:
                tgt_i = self.dispatcher.choose(self, req)
            self._grant_lease(req.rid, tgt_i)
            tgt = self.engines[tgt_i]
            if prefix:
                tgt.adopt_resume(req, prefix)
            else:
                tgt._sv.scheduler.push(req)
        self.recovered_requests += len(displaced)
        entry: Dict[str, Any] = {
            "kind": kind, "replica": i, "at_s": now, "applied_at_s": now,
            "recovered": len(displaced),
            "page_copy": page_copied,
            "recompute": n_recompute + integrity_fb,
            "moved_queued": moved_queued,
        }
        self.fault_log.append(entry)
        if self.obs is not None:
            self.obs.instant(
                "fault", now, replica=i, fault=kind,
                recovered=len(displaced), page_copy=page_copied,
                recompute=n_recompute + integrity_fb,
            )
        if displaced:
            self._recovery_watch.append(
                {"entry": entry, "t0": now, "pending": dict(displaced)}
            )
            # page-copied work is re-admitted within the event itself
            self._note_recoveries(now)
        return entry

    def _request_admitted(self, req: Request) -> bool:
        """A displaced request counts as re-admitted once it is in flight
        (bound slot or mid-chunk prefill) on an alive replica — or done."""
        if req.t_done is not None:
            return True
        for j in self.alive_replicas:
            eng = self.engines[j]
            for slot in eng.slots.active_slots:
                if eng.slots.request_of[slot].rid == req.rid:
                    return True
            for st in eng._chunking.values():
                if st.req.rid == req.rid:
                    return True
        return False

    def _note_recoveries(self, now: float) -> None:
        """Stamp time-to-recover on fault/drain events: the span from the
        event to the instant ALL its displaced requests are re-admitted
        somewhere alive. Page-copy evacuations recover at the event itself
        (recover_s = 0); recompute paths pay queueing plus the re-prefill."""
        if not self._recovery_watch:
            return
        remaining = []
        for w in self._recovery_watch:
            w["pending"] = {
                rid: req for rid, req in w["pending"].items()
                if not self._request_admitted(req)
            }
            if w["pending"]:
                remaining.append(w)
            else:
                w["entry"]["recover_s"] = max(now - w["t0"], 0.0)
        self._recovery_watch = remaining

    def step(self) -> bool:
        """Advance the fleet by one stage on the lowest-clock alive replica
        with work. Returns False once every alive replica is drained and no
        arrivals remain (the serve is complete)."""
        while True:
            alive = self.alive_replicas
            workers = [i for i in alive if self.engines[i].has_work()]
            if not workers:
                if not self._central:
                    return False
                # fleet-wide idle gap: survivors fast-forward to the arrival
                nxt = self._central[0].arrival
                if self._apply_due_faults(nxt) or self._apply_due_injections(nxt):
                    continue
                for i in alive:
                    self.engines[i].advance_clock(nxt)
                self._route_arrivals(nxt)
                continue
            now = min(self.engines[i].clock for i in workers)
            if self._apply_due_faults(now) or self._apply_due_injections(now):
                continue                      # membership/queues changed
            # replicas without work have been idling in parallel — their
            # clocks track fleet time so routed arrivals start at "now";
            # the clock advance doubles as their passive liveness beat
            # (hung replicas excluded: a stalled process stamps nothing)
            for i in alive:
                if i not in workers:
                    self.engines[i].advance_clock(now)
                    if self.monitor is not None and i not in self._hangs:
                        self.monitor.beat(i, self.engines[i].clock)
            self._route_arrivals(now)
            if self.cfg.work_stealing:
                self._try_steal()
            workers = [i for i in alive if self.engines[i].has_work()]
            i = min(workers, key=lambda j: (self.engines[j].clock, j))
            if i in self._hangs:
                # the hung replica would be next: it silently makes no
                # progress, so fleet virtual time flows around it — jump its
                # clock to the wake-up instant and let the other replicas'
                # stages carry the clock (and the monitor's evidence)
                # forward. No heartbeat is stamped: that IS the failure.
                self.engines[i].advance_clock(self._hangs[i].until_s)
                continue
            n_stages = len(self.engines[i]._sv.trace.stages)
            status = self.engines[i].serve_step()
            if status == "idle":
                raise RuntimeError(
                    f"replica {i} idle with pending work — fleet routing bug"
                )
            if self.monitor is not None:
                self._health_beat(i, n_stages)
                self._health_evaluate(now)
            self._note_recoveries(self.engines[i].clock)
            return True

    # ------------------------------------------------------------------ #
    # Health monitoring (heartbeats → suspicion → condemnation)          #
    # ------------------------------------------------------------------ #
    def _predicted_stage_s(self, i: int, st) -> Optional[float]:
        """What replica ``i``'s OWN fitted cost model predicted the just-run
        stage should have taken — the denominator of the gray-failure
        slowdown signal. The model is the one FROZEN at serve start, not
        the live profiler fit: the live fit keeps learning from measured
        stages, so after one refit cycle it predicts the degraded speed and
        the ratio collapses back to 1. None until the replica had fully
        refit before the serve began: prior constants are paper-scale,
        orders of magnitude off measured milliseconds, and a ratio against
        them would flag every healthy replica as degraded (or mask a real
        one)."""
        cm = self._health_cms[i]
        if cm is None:
            return None
        if st.kind is StageKind.PREFILL:
            pred = cm.prefill_time(st.tokens)
        elif st.kind is StageKind.DECODE:
            pred = cm.fused_decode_time(len(st.busy), max(st.rounds, 1))
        elif st.kind is StageKind.MIXED:
            pred = cm.mixed_round_time(
                max(st.tokens - st.chunk_tokens, 0), st.chunk_tokens
            )
        else:
            return None
        return pred if pred > 0 else None

    def _health_beat(self, i: int, n_stages_before: int) -> None:
        """Stamp replica ``i``'s heartbeat after a ``serve_step``. A stage
        boundary carries the stage's measured duration + the cost-model
        prediction (feeding degraded detection); a step that only advanced
        the clock (idle fast-forward) beats bare — liveness without
        polluting the duration statistics."""
        eng = self.engines[i]
        stages = eng._sv.trace.stages
        if len(stages) > n_stages_before:
            st = stages[-1]
            self.monitor.beat(
                i, eng.clock,
                duration_s=st.t_end - st.t_start,
                predicted_s=self._predicted_stage_s(i, st),
                # predictions come from the per-serve frozen model, so the
                # version is constant for the whole serve (the monitor's
                # rebaseline-on-version-change still guards unit callers
                # that feed it a live, refitting model)
                model_version=0,
            )
        else:
            self.monitor.beat(i, eng.clock)

    def _health_evaluate(self, now: float) -> None:
        """Run the monitor's state machine at fleet time ``now``: condemn
        (fence + evacuate) replicas it gives up on, then re-place work
        queued on replicas it merely suspects."""
        newly = self.monitor.evaluate(now, replicas=self.alive_replicas)
        for i in newly:
            self._condemn_replica(
                i, now, reason=self.monitor.replicas[i].suspect_reason
                or "silence"
            )
        self._redispatch_suspect_queues(now)

    def _redispatch_suspect_queues(self, now: float) -> None:
        """Per-request redispatch with deadline-aware backoff: work queued
        (not yet started) on a SUSPECT replica is re-placed onto the
        cheapest-completion healthy replica once the suspicion has stood
        for ``redispatch_backoff_s`` — grace for a false suspicion to clear
        without churning the queue — or immediately when waiting out the
        backoff would already blow the request's TTFT deadline. In-flight
        slots stay: they move (page-copy first) only at condemnation."""
        hcfg = self.monitor.cfg
        for i in self.alive_replicas:
            if self.monitor.state(i) != SUSPECT:
                continue
            sched = self.engines[i]._sv.scheduler
            if not sched.queued:
                continue
            since = self.monitor.replicas[i].suspect_since
            if since is None:
                since = now
            targets = [
                j for j in self.alive_replicas
                if j != i and self.monitor.is_healthy(j)
            ]
            if not targets:
                continue                      # nowhere trustworthy to go
            for req in list(sched.queued):
                waited_out = now >= since + hcfg.redispatch_backoff_s
                deadline_pressed = (
                    req.ttft_slo_s is not None
                    and now + hcfg.redispatch_backoff_s
                    >= req.arrival + req.ttft_slo_s - hcfg.deadline_slack_s
                )
                if not (waited_out or deadline_pressed):
                    continue
                sched.commit(None, req)      # pop from the suspect queue
                j = self._choose_placement(
                    targets, req, False, now, "redispatch"
                )
                self.engines[j]._sv.scheduler.push(req)
                self._grant_lease(req.rid, j)
                req.redispatches += 1
                self.redispatch_events += 1
                self.redispatch_log.append({
                    "rid": req.rid, "from": i, "to": j, "at_s": now,
                    "deadline": bool(deadline_pressed and not waited_out),
                })

    def finish_serve(self) -> FleetReport:
        end = max(
            (self.engines[i].clock for i in self.alive_replicas),
            default=0.0,
        )
        if self._recovery_watch:
            self._note_recoveries(end)
        # ghosts that never woke mid-serve (hang outlasted the workload, or
        # the condemned replica was never hung at all) still replay at
        # teardown: a zombie's timing must not decide whether the fence is
        # exercised
        for i in sorted(self._ghosts):
            self._fire_ghost(i, end)
        traces = [
            eng.finish_serve(validate=not self._resumed)
            for eng in self.engines
        ]
        served = [r for t in traces for r in t.requests]
        lb_requests = served if served else self._all_requests
        if self.heterogeneous:
            # R||Cmax floor through the live per-replica fits; recovers the
            # flat-pool P||Cmax bound exactly when the fits coincide
            lb = hetero_theoretical_lower_bound(
                lb_requests,
                self.pricing_cost_models(),
                self.engine_cfg.n_slots,
            )
        else:
            lb = theoretical_lower_bound(
                lb_requests,
                self.cfg.n_replicas * self.engine_cfg.n_slots,
                self.cost_model,
            )
        report = FleetReport(
            policy_name=(
                f"fleet/{self.cfg.assign}+{self.dispatcher.name}"
                f"{'+steal' if self.cfg.work_stealing else ''}"
            ),
            n_replicas=self.cfg.n_replicas,
            slots_per_replica=self.engine_cfg.n_slots,
            traces=traces,
            lower_bound_s=lb.total,
            speed_factors=[s.speed_factor for s in self.specs],
            steal_events=self.steal_events,
            # a resumed fleet has no offline solve of its own (the partition
            # happened before the checkpoint)
            offline_solver=(
                self._offline_result.solver if self._offline_result else "resumed"
            ),
            offline_gap=(
                self._offline_result.gap if self._offline_result else 0.0
            ),
        )
        report.meta["recomputed_tokens"] = float(
            sum(eng.recomputed_tokens for eng in self.engines)
        )
        if self.migration_events:
            report.meta["migration_events"] = float(self.migration_events)
            report.meta["migrated_pages"] = float(self.migrated_pages)
        if self.fault_log:
            report.meta["fault_events"] = float(len(self.fault_log))
            report.meta["dead_replicas"] = float(len(self._dead))
            report.meta["drained_replicas"] = float(len(self._drained))
            report.meta["recovered_requests"] = float(self.recovered_requests)
            report.meta["lost_preemptions"] = float(self._lost_preemptions)
            report.meta["recovered_page_copy"] = float(
                sum(e.get("page_copy", 0) for e in self.fault_log)
            )
            report.meta["recovered_recompute"] = float(
                sum(e.get("recompute", 0) for e in self.fault_log)
            )
            report.meta["time_to_recover_s"] = float(
                max(
                    (e["recover_s"] for e in self.fault_log if "recover_s" in e),
                    default=0.0,
                )
            )
        if self.monitor is not None:
            report.meta["suspect_events"] = float(self.monitor.suspect_events)
            report.meta["false_suspicions"] = float(
                self.monitor.false_suspicions
            )
            report.meta["condemned_replicas"] = float(
                self.monitor.condemned_events
            )
            report.meta["degraded_events"] = float(
                self.monitor.degraded_events
            )
            report.meta["redispatch_events"] = float(self.redispatch_events)
        if self.fenced_completions or self.fenced_exports:
            report.meta["fenced_stale_completions"] = float(
                self.fenced_completions
            )
            report.meta["fenced_stale_exports"] = float(self.fenced_exports)
        if self.integrity_rejections:
            report.meta["integrity_rejections"] = float(
                self.integrity_rejections
            )
        if self.obs is not None:
            obs = self.obs
            # fleet counters join the typed registry next to the per-engine
            # meta counters `_obs_finish` already recorded
            for name, value, help_ in (
                ("steal_events", self.steal_events,
                 "queued steals + running migrations committed"),
                ("migration_events", self.migration_events,
                 "live page-copy slot migrations"),
                ("fenced_stale_completions", self.fenced_completions,
                 "zombie completions discarded by the epoch fence"),
                ("fenced_stale_exports", self.fenced_exports,
                 "stale-epoch slot exports discarded"),
                ("recovered_requests", self.recovered_requests,
                 "requests displaced by faults and re-admitted"),
            ):
                obs.declare(name, "counter", help=help_)
                obs.inc(name, float(value))
            # structured logs ride the typed side-channel, never summary()
            obs.set_log("fault_log", self.fault_log)
            obs.set_log("fenced_log", self.fenced_log)
            obs.set_log("steal_log", self.steal_log)
            obs.set_log("migration_log", self.migration_log)
            obs.set_log("redispatch_log", self.redispatch_log)
            obs.set_log("injected_log", self.injected_log)
            if self.monitor is not None:
                obs.set_log(
                    "health_transitions", list(self.monitor.transitions)
                )
        if not self._resumed:
            report.validate()
        return report

    def serve(
        self,
        requests: Sequence[Request],
        iteration_policy_factory: Callable[[], IterationPolicy] = LagrangianPolicy,
        policy_name: str = "",
        fault_plan: Optional[FaultPlan] = None,
    ) -> FleetReport:
        """Serve a request set to completion across all replicas."""
        self.begin_serve(
            requests, iteration_policy_factory, policy_name,
            fault_plan=fault_plan,
        )
        while self.step():
            pass
        return self.finish_serve()

    # ------------------------------------------------------------------ #
    # Aggregate output (parity checks / detokenized streaming)           #
    # ------------------------------------------------------------------ #
    @property
    def generated(self) -> Dict[int, List[int]]:
        """rid → sampled tokens, merged across replicas. Each request runs
        on exactly one replica, so the merge is collision-free (checked)."""
        out: Dict[int, List[int]] = {}
        for eng in self.engines:
            for rid, toks in eng.generated.items():
                if rid in out:
                    raise RuntimeError(f"request {rid} decoded on two replicas")
                out[rid] = toks
        return out

    def warm_serving_shapes(self) -> None:
        for eng in self.engines:
            eng.warm_serving_shapes()

    # ------------------------------------------------------------------ #
    # Checkpoint / restore (all replicas + fleet dispatcher state)        #
    # ------------------------------------------------------------------ #
    def state_dict(self) -> Dict[str, Any]:
        """Mid-serve fleet snapshot: every replica's engine state plus the
        queue composition and session clocks the fleet needs to resume."""
        queues = [
            np.asarray(
                [r.rid for r in eng._sv.scheduler.queued], dtype=np.int32
            )
            for eng in self.engines
        ]
        return {
            "engines": [eng.state_dict() for eng in self.engines],
            # per-replica profiler + fitted-cost-model state: a restored
            # heterogeneous fleet must keep pricing dispatch/stealing/solves
            # through what each replica had LEARNED, not its cold prior
            "profilers": [eng.profiler.state_dict() for eng in self.engines],
            # construction-time speeds, so a restore into a fleet built
            # with different specs fails loudly instead of silently
            # dropping the emulated speed asymmetry
            "speed_factors": np.asarray(
                [s.speed_factor for s in self.specs], dtype=np.float64
            ),
            "clocks": np.asarray(
                [eng.clock for eng in self.engines], dtype=np.float64
            ),
            "queues": queues,
            "central": np.asarray(
                [r.rid for r in self._central], dtype=np.int32
            ),
            "steal_events": self.steal_events,
            "dispatch_cursor": int(getattr(self.dispatcher, "cursor", 0)),
            # fault/recovery state: a fleet restored after a mid-serve kill
            # or drain must keep pricing/dispatch away from dead replicas
            # and keep its accounting (lost preemptions, fault events)
            "dead": np.asarray(sorted(self._dead), dtype=np.int32),
            "drained": np.asarray(sorted(self._drained), dtype=np.int32),
            "lost_preemptions": int(self._lost_preemptions),
            "recovered_requests": int(self.recovered_requests),
            # JSON string: survives np.asarray round-trips that flatten
            # checkpoint leaves (a list of dicts would not)
            "fault_log": json.dumps(self.fault_log),
            # health + fencing state: a restored fleet must keep distrusting
            # what it distrusted (SUSPECT must not wake up ALIVE) and keep
            # fencing what it fenced (epochs, leases, the fenced-event log)
            "epochs": np.asarray(self.epochs, dtype=np.int64),
            "fenced_completions": int(self.fenced_completions),
            "fenced_exports": int(self.fenced_exports),
            "redispatch_events": int(self.redispatch_events),
            "integrity_rejections": int(self.integrity_rejections),
            "fenced_log": json.dumps(self.fenced_log),
            "leases": json.dumps(
                {str(rid): list(lease) for rid, lease in self._leases.items()}
            ),
            "health": (
                self.monitor.state_dict() if self.monitor is not None else ""
            ),
            # observability state rides the checkpoint the same way: one
            # JSON-string leaf, so span chains survive a restore mid-serve
            "obs": self.obs.state_dict() if self.obs is not None else "",
        }

    def load_state_dict(
        self,
        state: Dict[str, Any],
        requests_by_rid: Dict[int, Request],
        iteration_policy_factory: Callable[[], IterationPolicy] = LagrangianPolicy,
        policy_name: str = "",
    ) -> None:
        """Restore a mid-serve fleet. Queued requests rebuild each replica's
        scheduler; bound/mid-chunk slots resume from engine state (their
        earlier tokens live in the pre-checkpoint output record, so the
        restored fleet's traces cover only post-restore work and
        ``finish_serve`` skips full-coverage validation)."""
        if "speed_factors" in state:
            saved = [float(s) for s in np.asarray(state["speed_factors"])]
            mine = [s.speed_factor for s in self.specs]
            if saved != mine:
                raise ValueError(
                    f"checkpoint was written by a fleet with speed_factors "
                    f"{saved}, but this fleet has {mine} — construct the "
                    f"restoring Fleet with the same replica_specs"
                )
        self._resumed = True
        self.steal_events = int(state.get("steal_events", 0))
        self._dead = {int(i) for i in np.asarray(state.get("dead", []))}
        self._drained = {int(i) for i in np.asarray(state.get("drained", []))}
        self._lost_preemptions = int(state.get("lost_preemptions", 0))
        self.recovered_requests = int(state.get("recovered_requests", 0))
        raw_log = state.get("fault_log", "[]")
        if not isinstance(raw_log, str):      # np.str_ after tree_map
            raw_log = str(np.asarray(raw_log))
        self.fault_log = json.loads(raw_log)
        self._recovery_watch = []             # recover_s already stamped
        self._pricing_key = None
        # health + fencing state (absent in pre-PR-8 checkpoints → defaults)
        self.epochs = [
            int(e)
            for e in np.asarray(
                state.get("epochs", [0] * self.cfg.n_replicas)
            )
        ]
        self.fenced_completions = int(state.get("fenced_completions", 0))
        self.fenced_exports = int(state.get("fenced_exports", 0))
        self.redispatch_events = int(state.get("redispatch_events", 0))
        self.integrity_rejections = int(state.get("integrity_rejections", 0))
        raw_fenced = state.get("fenced_log", "[]")
        if not isinstance(raw_fenced, str):
            raw_fenced = str(np.asarray(raw_fenced))
        self.fenced_log = json.loads(raw_fenced)
        raw_leases = state.get("leases", "{}")
        if not isinstance(raw_leases, str):
            raw_leases = str(np.asarray(raw_leases))
        self._leases = {
            int(rid): tuple(lease)
            for rid, lease in json.loads(raw_leases).items()
        }
        raw_health = state.get("health", "")
        if not isinstance(raw_health, str):
            raw_health = str(np.asarray(raw_health))
        if raw_health:
            if self.monitor is None:
                raise ValueError(
                    "checkpoint carries health-monitor state but this fleet "
                    "was built without FleetConfig.health — construct the "
                    "restoring Fleet with the same health config"
                )
            self.monitor.load_state_dict(raw_health)
            self.monitor.obs = self.obs
        raw_obs = state.get("obs", "")
        if not isinstance(raw_obs, str):
            raw_obs = str(np.asarray(raw_obs))
        if raw_obs and self.obs is not None:
            self.obs.load_state_dict(raw_obs)
        # undeclared-injection state is per serve (like _pending_faults, it
        # is not checkpointed): a restored fleet starts with a clean layer
        self._hangs = {}
        self._restores = []
        self._ghosts = {}
        self.injected_log = []
        self.redispatch_log = []
        # steal_log entries are not checkpointed (steal_events is), and any
        # offline solve belongs to the pre-checkpoint serve — clear both so
        # a reused Fleet object cannot report stale metadata
        self.steal_log = []
        self._offline_result = None
        if hasattr(self.dispatcher, "cursor"):
            self.dispatcher.cursor = int(state.get("dispatch_cursor", 0))
        self._central = [
            requests_by_rid[int(rid)] for rid in np.asarray(state["central"])
        ]
        self._all_requests = list(requests_by_rid.values())
        base = policy_name or f"fleet/{self.cfg.assign}"
        clocks = np.asarray(state["clocks"], dtype=np.float64)
        for i, eng in enumerate(self.engines):
            clients = build_clients(eng.cfg.n_slots, [], None)
            sched = GlobalQueueScheduler(
                [requests_by_rid[int(r)] for r in np.asarray(state["queues"][i])]
            )
            eng.begin_serve(
                [], clients, sched, iteration_policy_factory(),
                policy_name=f"{base}/r{i}(resumed)", track_requests=True,
            )
            eng.load_state_dict(state["engines"][i], requests_by_rid)
            if "profilers" in state:
                eng.profiler.load_state_dict(state["profilers"][i])
            # re-attach bound requests to their clients (mid-chunk slots
            # stay current=None — _chunking owns them until the final chunk)
            for slot, req in enumerate(eng.slots.request_of):
                if req is not None:
                    clients[slot].current = req
                    req.decoded = eng.slots.emitted[slot]
            eng.advance_clock(float(clocks[i]))
        # freeze health-prediction models off the restored profiler fits
        # (same rule as begin_serve: the resumed serve judges slowdowns
        # against the model as-of-resume, never the live refitting one)
        self._health_cms = [
            eng.profiler.cost_model if eng.profiler.full_fits > 0 else None
            for eng in self.engines
        ]
