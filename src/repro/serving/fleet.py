"""Fleet-scale serving: N ``Engine`` replicas under the paper's hybrid
offline-online scheduler, lifted one level up.

The paper's hybrid assigns an offline backlog across *clients* (Minimizing
Makespan Bin Packing, Eqs. 26–30) and then runs online sorting/preemptive
scheduling per client. In this repo the offline layer had only ever driven
the event-driven simulator while the real engine stayed a single replica;
the ``Fleet`` closes that gap by applying the same two ideas at replica
granularity:

  * **offline** — ``solve_offline`` (LPT + local search) partitions the
    backlog across replicas, treating each replica as one of the paper's
    "clients" (``round_robin_assign`` is the unbalanced baseline ablation,
    Fig. 6 at fleet scale). Each replica then serves its partition
    longest-first (Algorithm 1's sort).
  * **online** — arrivals route through a pluggable
    ``ReplicaDispatchPolicy``: least-estimated-load using the shared
    ``CostModel`` (HyGen-style replica-level dispatch), or round-robin.
    When a replica drains early it *steals* the longest not-yet-started
    request from the most-loaded replica's queue — Algorithm 1's
    request-level straggler mitigation, applied across replicas so one
    straggler cannot set the fleet makespan.

Execution model: all replicas share one set of model weights (the same
``params`` device buffers) but own independent KV pools / slot managers.
One process executes every stage, interleaved in *virtual time*: the fleet
always steps the replica whose session clock is lowest, so cross-replica
decisions (arrival routing, stealing) are made at a consistent fleet-wide
"now" even though stages run sequentially. Each replica's trace clock
starts at 0 — "replicas run in parallel" — so the fleet makespan is the
max replica makespan, and fleet utilization divides the summed busy
client-time by makespan × total slots. ``FleetReport`` compares that
makespan against ``theoretical_lower_bound`` evaluated on the whole fleet
as one flat pool of N·slots clients (Eqs. 31–32), the floor no partitioned
execution can beat.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from ..core.cost_model import CostModel
from ..core.iteration import IterationPolicy, LagrangianPolicy
from ..core.offline import (
    evaluate_assignment,
    round_robin_assign,
    solve_offline,
    split_requests,
    theoretical_lower_bound,
)
from ..core.online import GlobalQueueScheduler, build_clients
from ..core.types import FleetReport, Request
from .engine import Engine, EngineConfig
from .profiler import OnlineProfiler
from .sampler import greedy

Tree = Any


# --------------------------------------------------------------------------- #
# Online replica dispatch                                                     #
# --------------------------------------------------------------------------- #
class ReplicaDispatchPolicy:
    """Chooses the replica an online arrival is admitted to."""

    name = "base"

    def choose(self, fleet: "Fleet", req: Request) -> int:
        raise NotImplementedError


class LeastLoadDispatch(ReplicaDispatchPolicy):
    """Route to the replica with the least estimated outstanding work
    (queued + in-flight, priced by the shared ``CostModel``) — the
    replica-level analogue of LPT's least-loaded-client rule."""

    name = "least_load"

    def choose(self, fleet: "Fleet", req: Request) -> int:
        return min(
            range(fleet.n_replicas),
            key=lambda i: (fleet.estimated_load_s(i), i),
        )


class RoundRobinDispatch(ReplicaDispatchPolicy):
    """FCFS round-robin across replicas — the unbalanced baseline.

    The cursor is part of serve state: ``Fleet.begin_serve`` resets it and
    checkpoints carry it, so arrival routing is reproducible across serves
    and across a checkpoint/restore."""

    name = "round_robin"

    def __init__(self) -> None:
        self.cursor = 0

    def reset(self) -> None:
        self.cursor = 0

    def choose(self, fleet: "Fleet", req: Request) -> int:
        i = self.cursor % fleet.n_replicas
        self.cursor += 1
        return i


DISPATCH_POLICIES = {
    "least_load": LeastLoadDispatch,
    "round_robin": RoundRobinDispatch,
}


@dataclasses.dataclass
class FleetConfig:
    """Fleet shape + scheduling knobs.

    ``assign`` picks the offline backlog partitioner ("lpt" =
    ``solve_offline``'s LPT + local search; "round_robin" = the baseline
    ablation). ``dispatch`` picks the online arrival router. Work stealing
    moves queued (not-yet-started) requests from loaded to drained
    replicas; token streams are unaffected (prompts and sampling are pure
    functions of (seed, rid), independent of which replica runs them).
    """

    n_replicas: int = 2
    assign: str = "lpt"                  # "lpt" | "round_robin"
    dispatch: str = "least_load"         # key into DISPATCH_POLICIES
    work_stealing: bool = True
    local_search_rounds: int = 200


class Fleet:
    def __init__(
        self,
        model,
        params: Tree,
        engine_config: EngineConfig,
        fleet_config: Optional[FleetConfig] = None,
        cost_model: Optional[CostModel] = None,
        sampler: Callable = greedy,
        profiler_factory: Optional[Callable[[], OnlineProfiler]] = None,
    ):
        self.cfg = fleet_config or FleetConfig()
        if self.cfg.n_replicas <= 0:
            raise ValueError("n_replicas must be positive")
        if self.cfg.assign not in ("lpt", "round_robin"):
            raise ValueError(f"unknown assign method {self.cfg.assign!r}")
        if self.cfg.dispatch not in DISPATCH_POLICIES:
            raise ValueError(
                f"unknown dispatch policy {self.cfg.dispatch!r}; "
                f"have {sorted(DISPATCH_POLICIES)}"
            )
        self.engine_cfg = engine_config
        # the shared CostModel: offline partitioning, dispatch-load pricing,
        # and the fleet lower bound all price work through this one model
        self.cost_model = cost_model or CostModel()
        # N replicas over ONE set of weights: `params` is passed by
        # reference, so every replica jit-calls the same device buffers;
        # each Engine builds its own KV pool / slot manager / profiler
        self.engines = [
            Engine(
                model, params, engine_config,
                profiler=(
                    profiler_factory()
                    if profiler_factory is not None
                    else OnlineProfiler(initial=self.cost_model)
                ),
                sampler=sampler,
            )
            for _ in range(self.cfg.n_replicas)
        ]
        self.dispatcher: ReplicaDispatchPolicy = (
            DISPATCH_POLICIES[self.cfg.dispatch]()
        )
        self.steal_events = 0
        self.steal_log: List[Dict[str, int]] = []
        self._central: List[Request] = []     # future arrivals, sorted
        self._all_requests: List[Request] = []
        self._offline_result = None
        self._resumed = False

    @property
    def n_replicas(self) -> int:
        return self.cfg.n_replicas

    # ------------------------------------------------------------------ #
    # Load estimation (the shared-cost-model pricing dispatch uses)       #
    # ------------------------------------------------------------------ #
    def _request_weight_s(self, req: Request, remaining_decode: int) -> float:
        cm = self.cost_model
        n = self.engine_cfg.n_slots
        return cm.prefill_time(req.n_prefill) + cm.estimated_decode_completion(
            max(remaining_decode, 0), n
        )

    def estimated_load_s(self, i: int) -> float:
        """Estimated seconds of outstanding work per slot on replica ``i``:
        queued requests (full weight), in-flight chunked prefills, and the
        remaining decode of every bound slot, spread over the slot count —
        the replica-level ``remain_token`` of Algorithm 1, in seconds."""
        eng = self.engines[i]
        total = 0.0
        for r in eng._sv.scheduler.queued:
            total += self._request_weight_s(r, int(r.n_decode_est or r.n_decode))
        for st in eng._chunking.values():
            total += self._request_weight_s(
                st.req, int(st.req.n_decode_est or st.req.n_decode)
            )
        for slot in eng.slots.active_slots:
            req = eng.slots.request_of[slot]
            rem = int(req.n_decode_est or req.n_decode) - eng.slots.emitted[slot]
            total += self.cost_model.estimated_decode_completion(
                max(rem, 0), eng.cfg.n_slots
            )
        return total / eng.cfg.n_slots

    # ------------------------------------------------------------------ #
    # Serve lifecycle                                                    #
    # ------------------------------------------------------------------ #
    def begin_serve(
        self,
        requests: Sequence[Request],
        iteration_policy_factory: Callable[[], IterationPolicy] = LagrangianPolicy,
        policy_name: str = "",
    ) -> None:
        """Partition the offline backlog, open every replica's serve
        session, and queue online arrivals for dispatch-on-arrival."""
        for r in requests:
            r.reset()
        self._all_requests = list(requests)
        self.steal_events = 0
        self.steal_log = []
        self._resumed = False
        if hasattr(self.dispatcher, "reset"):
            self.dispatcher.reset()
        offline = [r for r in requests if r.arrival <= 0.0]
        online = sorted(
            (r for r in requests if r.arrival > 0.0),
            key=lambda r: (r.arrival, r.rid),
        )
        n = self.cfg.n_replicas
        if self.cfg.assign == "lpt":
            self._offline_result = solve_offline(
                offline, n, self.cost_model,
                local_search_rounds=self.cfg.local_search_rounds,
            )
        else:
            self._offline_result = evaluate_assignment(
                offline, round_robin_assign(offline, n), n, self.cost_model,
                solver="round_robin",
            )
        parts = split_requests(offline, self._offline_result.assignment)
        self._central = online
        base = policy_name or f"fleet/{self.cfg.assign}"
        for i, eng in enumerate(self.engines):
            clients = build_clients(eng.cfg.n_slots, [], None)
            # per-replica FCFS queue over the partition, longest-first
            # (Algorithm 1's sort); fleet dispatch/stealing push into it
            sched = GlobalQueueScheduler(parts[i], sort_longest_first=True)
            eng.begin_serve(
                [], clients, sched, iteration_policy_factory(),
                policy_name=f"{base}/r{i}", track_requests=True,
            )

    def _route_arrivals(self, now: float) -> None:
        """Admit every central request whose arrival has passed, each to the
        replica the dispatch policy picks *at this moment* (load changes as
        earlier arrivals land, so routing is one-at-a-time)."""
        while self._central and self._central[0].arrival <= now:
            req = self._central.pop(0)
            i = self.dispatcher.choose(self, req)
            self.engines[i]._sv.scheduler.push(req)

    def _earliest_slot_free_s(self, j: int) -> float:
        """Cost-model estimate of the absolute fleet time at which replica
        ``j`` next frees a slot: its clock plus the smallest remaining
        per-slot work (decode rounds left, or chunk tokens + decode for a
        mid-prefill slot). The steal gate compares this against the thief's
        clock — measured clocks alone are not comparable when one replica's
        stages carried one-off costs (e.g. first-hit compiles)."""
        eng = self.engines[j]
        cm = self.cost_model
        waits = []
        for slot in eng.slots.active_slots:
            req = eng.slots.request_of[slot]
            rem = int(req.n_decode_est or req.n_decode) - eng.slots.emitted[slot]
            waits.append(
                cm.estimated_decode_completion(max(rem, 0), eng.cfg.n_slots)
            )
        for st in eng._chunking.values():
            waits.append(
                cm.prefill_time(st.remaining)
                + cm.estimated_decode_completion(
                    int(st.req.n_decode_est or st.req.n_decode), eng.cfg.n_slots
                )
            )
        return eng.clock + (min(waits) if waits else 0.0)

    def _try_steal(self) -> None:
        """Move the longest queued request from the most-loaded replica to
        each starving one (idle slot, empty queue). Queued work cannot start
        on its owner (all donor slots busy — otherwise it would not be
        queued), so a drained replica always runs it sooner."""
        for i, eng in enumerate(self.engines):
            sched = eng._sv.scheduler
            idle_slots = [
                s for s in eng.slots.free_slots if s not in eng._chunking
            ]
            if sched.queued or not idle_slots:
                continue
            donors = [
                j for j, other in enumerate(self.engines)
                if j != i and other._sv.scheduler.queued
                # a donor with a genuinely free slot runs its own queue next
                # step — only steal from replicas whose slots are all busy
                and all(
                    s in other._chunking for s in other.slots.free_slots
                )
                # the thief starts stolen work at its own clock; a donor
                # that will free a slot before then would run the request
                # sooner itself — only steal when the thief wins the race
                and self._earliest_slot_free_s(j) >= eng.clock
            ]
            if not donors:
                continue
            j = max(donors, key=lambda k: (self.estimated_load_s(k), -k))
            victim = self.engines[j]._sv.scheduler.steal_longest()
            if victim is None:
                continue
            sched.push(victim)
            self.steal_events += 1
            self.steal_log.append({"rid": victim.rid, "from": j, "to": i})

    def step(self) -> bool:
        """Advance the fleet by one stage on the lowest-clock replica with
        work. Returns False once every replica is drained and no arrivals
        remain (the serve is complete)."""
        while True:
            workers = [i for i, e in enumerate(self.engines) if e.has_work()]
            if not workers:
                if not self._central:
                    return False
                # fleet-wide idle gap: everyone fast-forwards to the arrival
                nxt = self._central[0].arrival
                for eng in self.engines:
                    eng.advance_clock(nxt)
                self._route_arrivals(nxt)
                continue
            now = min(self.engines[i].clock for i in workers)
            # replicas without work have been idling in parallel — their
            # clocks track fleet time so routed arrivals start at "now"
            for i, eng in enumerate(self.engines):
                if i not in workers:
                    eng.advance_clock(now)
            self._route_arrivals(now)
            if self.cfg.work_stealing:
                self._try_steal()
            workers = [i for i, e in enumerate(self.engines) if e.has_work()]
            i = min(workers, key=lambda j: (self.engines[j].clock, j))
            status = self.engines[i].serve_step()
            if status == "idle":
                raise RuntimeError(
                    f"replica {i} idle with pending work — fleet routing bug"
                )
            return True

    def finish_serve(self) -> FleetReport:
        traces = [
            eng.finish_serve(validate=not self._resumed)
            for eng in self.engines
        ]
        served = [r for t in traces for r in t.requests]
        lb = theoretical_lower_bound(
            served if served else self._all_requests,
            self.cfg.n_replicas * self.engine_cfg.n_slots,
            self.cost_model,
        )
        report = FleetReport(
            policy_name=(
                f"fleet/{self.cfg.assign}+{self.dispatcher.name}"
                f"{'+steal' if self.cfg.work_stealing else ''}"
            ),
            n_replicas=self.cfg.n_replicas,
            slots_per_replica=self.engine_cfg.n_slots,
            traces=traces,
            lower_bound_s=lb.total,
            steal_events=self.steal_events,
            # a resumed fleet has no offline solve of its own (the partition
            # happened before the checkpoint)
            offline_solver=(
                self._offline_result.solver if self._offline_result else "resumed"
            ),
            offline_gap=(
                self._offline_result.gap if self._offline_result else 0.0
            ),
        )
        if not self._resumed:
            report.validate()
        return report

    def serve(
        self,
        requests: Sequence[Request],
        iteration_policy_factory: Callable[[], IterationPolicy] = LagrangianPolicy,
        policy_name: str = "",
    ) -> FleetReport:
        """Serve a request set to completion across all replicas."""
        self.begin_serve(requests, iteration_policy_factory, policy_name)
        while self.step():
            pass
        return self.finish_serve()

    # ------------------------------------------------------------------ #
    # Aggregate output (parity checks / detokenized streaming)           #
    # ------------------------------------------------------------------ #
    @property
    def generated(self) -> Dict[int, List[int]]:
        """rid → sampled tokens, merged across replicas. Each request runs
        on exactly one replica, so the merge is collision-free (checked)."""
        out: Dict[int, List[int]] = {}
        for eng in self.engines:
            for rid, toks in eng.generated.items():
                if rid in out:
                    raise RuntimeError(f"request {rid} decoded on two replicas")
                out[rid] = toks
        return out

    def warm_serving_shapes(self) -> None:
        for eng in self.engines:
            eng.warm_serving_shapes()

    # ------------------------------------------------------------------ #
    # Checkpoint / restore (all replicas + fleet dispatcher state)        #
    # ------------------------------------------------------------------ #
    def state_dict(self) -> Dict[str, Any]:
        """Mid-serve fleet snapshot: every replica's engine state plus the
        queue composition and session clocks the fleet needs to resume."""
        queues = [
            np.asarray(
                [r.rid for r in eng._sv.scheduler.queued], dtype=np.int32
            )
            for eng in self.engines
        ]
        return {
            "engines": [eng.state_dict() for eng in self.engines],
            "clocks": np.asarray(
                [eng.clock for eng in self.engines], dtype=np.float64
            ),
            "queues": queues,
            "central": np.asarray(
                [r.rid for r in self._central], dtype=np.int32
            ),
            "steal_events": self.steal_events,
            "dispatch_cursor": int(getattr(self.dispatcher, "cursor", 0)),
        }

    def load_state_dict(
        self,
        state: Dict[str, Any],
        requests_by_rid: Dict[int, Request],
        iteration_policy_factory: Callable[[], IterationPolicy] = LagrangianPolicy,
        policy_name: str = "",
    ) -> None:
        """Restore a mid-serve fleet. Queued requests rebuild each replica's
        scheduler; bound/mid-chunk slots resume from engine state (their
        earlier tokens live in the pre-checkpoint output record, so the
        restored fleet's traces cover only post-restore work and
        ``finish_serve`` skips full-coverage validation)."""
        self._resumed = True
        self.steal_events = int(state.get("steal_events", 0))
        # steal_log entries are not checkpointed (steal_events is), and any
        # offline solve belongs to the pre-checkpoint serve — clear both so
        # a reused Fleet object cannot report stale metadata
        self.steal_log = []
        self._offline_result = None
        if hasattr(self.dispatcher, "cursor"):
            self.dispatcher.cursor = int(state.get("dispatch_cursor", 0))
        self._central = [
            requests_by_rid[int(rid)] for rid in np.asarray(state["central"])
        ]
        self._all_requests = list(requests_by_rid.values())
        base = policy_name or f"fleet/{self.cfg.assign}"
        clocks = np.asarray(state["clocks"], dtype=np.float64)
        for i, eng in enumerate(self.engines):
            clients = build_clients(eng.cfg.n_slots, [], None)
            sched = GlobalQueueScheduler(
                [requests_by_rid[int(r)] for r in np.asarray(state["queues"][i])]
            )
            eng.begin_serve(
                [], clients, sched, iteration_policy_factory(),
                policy_name=f"{base}/r{i}(resumed)", track_requests=True,
            )
            eng.load_state_dict(state["engines"][i], requests_by_rid)
            # re-attach bound requests to their clients (mid-chunk slots
            # stay current=None — _chunking owns them until the final chunk)
            for slot, req in enumerate(eng.slots.request_of):
                if req is not None:
                    clients[slot].current = req
                    req.decoded = eng.slots.emitted[slot]
            eng.advance_clock(float(clocks[i]))
