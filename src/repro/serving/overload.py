"""Overload control: throttle *offline* backlog admission under SLO pressure.

The hybrid serve co-locates an offline backlog (arrival <= 0, no deadline
pressure) with latency-sensitive online arrivals. Under KV-pool or arrival
overload the right degradation is to defer offline work — it has no deadline
to miss — rather than let it occupy slots and pages that online requests need
to hit their TTFT SLOs (HyGen, arXiv 2501.14808: goodput, not throughput, is
the objective once SLOs exist).

An ``OverloadPolicy`` sits on the engine's admission path: every admission
round the engine offers it the list of (client, request) pairs it is about to
start, and the policy may defer some of them back to the queue. The base
class is a pass-through (SLO-blind ablation); ``SLOAwareOverloadPolicy``
defers *offline* pairs whenever recent online TTFT attainment is close to the
SLO boundary, or an already-queued online request has waited long enough that
admitting more offline work would push it over its deadline.

Only offline requests are ever deferred — online admission is never throttled
here (shedding online load is a policy decision this repo leaves to the
caller), so the policy can only improve online TTFT at the cost of offline
completion time.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, List, Tuple

from ..core.types import Request

# (client, request) admission pair as the engine builds it; typed loosely so
# this module does not import the engine.
AdmissionPair = Tuple[object, Request]


def is_offline(req: Request) -> bool:
    """Offline backlog = present at t=0 with no TTFT deadline attached."""
    return req.arrival <= 0.0 and req.ttft_slo_s is None


class OverloadPolicy:
    """Admission filter. Base class admits everything (SLO-blind)."""

    name = "none"

    def filter_admissions(
        self, pairs: List[AdmissionPair], now: float, engine
    ) -> List[AdmissionPair]:
        """Return the subset of ``pairs`` to admit this round (order
        preserved). Deferred pairs stay queued and are re-offered next
        round — deferral is never a drop."""
        return pairs

    def record_ttft(self, ttft: float, slo: float) -> None:
        """Engine callback at each first-token completion of an SLO-carrying
        request."""


class SLOAwareOverloadPolicy(OverloadPolicy):
    """Defer offline admission when online TTFT nears its SLO.

    Two triggers, either one defers all offline pairs in the round:

      * **Attainment pressure** — the p95 of the last ``window`` observed
        online TTFT/SLO ratios is at or above ``headroom`` (deadlines are
        within (1 - headroom) of being missed on the recent record).
      * **Queue pressure** — some arrived, still-queued online request has
        already waited ``headroom`` of its TTFT budget; giving a slot to
        offline work now would likely push it over. Before any TTFT has
        been observed, an arrived waiting online request triggers this
        unconditionally (cold-start conservatism: with no evidence the
        SLO is being met, the policy does not gamble the first arrival).

    Offline requests are only deferred while pressure persists; once online
    TTFTs recover the backlog drains normally, so every request still
    completes (graceful degradation, not load shedding).
    """

    name = "slo_aware"

    def __init__(self, headroom: float = 0.85, window: int = 32):
        if not 0.0 < headroom <= 1.0:
            raise ValueError("headroom must be in (0, 1]")
        self.headroom = headroom
        self.window = window
        self._ratios: Deque[float] = deque(maxlen=window)
        self.deferrals = 0

    def record_ttft(self, ttft: float, slo: float) -> None:
        if slo > 0:
            self._ratios.append(ttft / slo)

    def _attainment_pressure(self) -> bool:
        if not self._ratios:
            return False
        ratios = sorted(self._ratios)
        p95 = ratios[min(len(ratios) - 1, int(0.95 * len(ratios)))]
        return p95 >= self.headroom

    def _queue_pressure(self, now: float, engine) -> bool:
        for req in engine.queued_requests():
            if req.ttft_slo_s is None or req.arrival <= 0:
                continue
            if req.arrival > now:
                continue                    # not arrived yet in virtual time
            if not self._ratios:
                # cold start: an online request is waiting and there is no
                # attainment evidence yet — defer conservatively until the
                # first measured TTFTs show the SLO is comfortably met
                # (without this the first arrival always rides blind, and
                # one guaranteed miss is exactly what the policy exists to
                # prevent)
                return True
            if now - req.arrival >= self.headroom * req.ttft_slo_s:
                return True
        return False

    def _online_still_coming(self, engine) -> bool:
        """Any online request still queued (arrived or future)? Deferral
        with nothing left to protect would only idle slots and stretch the
        makespan — once the last online request is admitted, the offline
        backlog drains at full speed regardless of past attainment."""
        return any(not is_offline(r) for r in engine.queued_requests())

    def filter_admissions(
        self, pairs: List[AdmissionPair], now: float, engine
    ) -> List[AdmissionPair]:
        if not any(is_offline(req) for _, req in pairs):
            return pairs
        if not self._online_still_coming(engine):
            return pairs
        att = self._attainment_pressure()
        queue = att or self._queue_pressure(now, engine)
        if att or queue:
            kept = [(c, r) for c, r in pairs if not is_offline(r)]
            deferred = [r.rid for _, r in pairs if is_offline(r)]
            self.deferrals += len(deferred)
            obs = getattr(engine, "obs", None)
            if obs is not None:
                obs.audit_record(
                    "overload_defer", now, getattr(engine, "obs_replica", 0),
                    {
                        "deferred_rids": deferred,
                        "attainment_pressure": bool(att),
                        "queue_pressure": bool(queue and not att),
                        "headroom": self.headroom,
                        "observed_ttft_ratios": len(self._ratios),
                    },
                    "defer_offline",
                )
            return kept
        return pairs
