"""RecurrentGemma / Griffin (arXiv:2402.19427) — RG-LRU + local attention.

Layer pattern is (recurrent, recurrent, local-attention) repeated — the
brief's "1:2". Each temporal block is followed by a gated-MLP block, both
residual. 38 layers = 12 full periods (36) + a 2-recurrent tail.

The RG-LRU recurrence (per channel):
    r_t = σ(W_r x_t);  i_t = σ(W_i x_t)
    a_t = exp(-c · softplus(Λ) · r_t)          (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Decode state is O(rnn_width) per recurrent layer plus a W-sized ring cache
per attention layer, so the family runs ``long_500k``. The width-4 temporal
conv preceding the RG-LRU is kept (it needs a 3-token buffer in the decode
state). Training/prefill use a sequential time scan for the recurrence but
full-sequence (parallel) attention/MLP — the attention blocks are NOT
scanned over time.

Adaptations (DESIGN.md): rnn_width defaults to d_model (the HF config's
lru_width); attention is MQA (kv=1) with window 2048 per the brief.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distributed.sharding import constrain_batch_dim
from .attention import attention, attention_any
from .cache import (
    ring_cache_init,
    ring_cache_shape,
    ring_cache_write_prefill,
    ring_cache_write_token,
    ring_positions_prefill,
    ring_positions_write_token,
)
from .layers import (
    ParamDef,
    apply_norm,
    apply_rope,
    cross_entropy_loss,
    embed_defs,
    embed_tokens,
    mlp_apply,
    mlp_defs,
    norm_defs,
    unembed,
)

Params = Dict[str, Any]
_C_RGLRU = 8.0


class RecurrentGemma:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        pattern = cfg.block_pattern or ("rec", "rec", "attn")
        if pattern != ("rec", "rec", "attn"):
            raise ValueError("RecurrentGemma expects the ('rec','rec','attn') pattern")
        self.n_periods = cfg.n_layers // 3          # full (rec, rec, attn) groups
        self.n_tail_rec = cfg.n_layers - 3 * self.n_periods  # leftover rec layers
        self.rnn = cfg.rnn_width or cfg.d_model
        self.hd = cfg.resolved_head_dim
        self.window = cfg.sliding_window or 2048
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    # ------------------------------------------------------------------ #
    def _rec_defs(self, n: int) -> Params:
        cfg, dt, r = self.cfg, self.dtype, self.rnn
        d = cfg.d_model
        return {
            "norm": norm_defs(d, cfg.norm_kind, dt, layers=n),
            "w_x": ParamDef((n, d, r), ("layers", "embed", "rnn"), dt),
            "w_gate": ParamDef((n, d, r), ("layers", "embed", "rnn"), dt),
            "conv_k": ParamDef((n, cfg.conv1d_width, r), ("layers", None, "rnn"), dt),
            "w_rgate": ParamDef((n, r, r), ("layers", "rnn", None), dt),
            "w_igate": ParamDef((n, r, r), ("layers", "rnn", None), dt),
            "lam": ParamDef((n, r), ("layers", "rnn"), jnp.float32, "normal", 0.5),
            "w_out": ParamDef((n, r, d), ("layers", "rnn", "embed"), dt),
            "norm_mlp": norm_defs(d, cfg.norm_kind, dt, layers=n),
            "mlp": mlp_defs(d, cfg.d_ff, cfg.mlp_kind, dt, layers=n),
        }

    def _attn_defs(self, n: int) -> Params:
        cfg, dt, hd = self.cfg, self.dtype, self.hd
        d = cfg.d_model
        return {
            "norm": norm_defs(d, cfg.norm_kind, dt, layers=n),
            "wq": ParamDef((n, d, cfg.n_heads, hd), ("layers", "embed", "heads", "head_dim"), dt),
            "wk": ParamDef((n, d, cfg.n_kv_heads, hd), ("layers", "embed", "kv_heads", "head_dim"), dt),
            "wv": ParamDef((n, d, cfg.n_kv_heads, hd), ("layers", "embed", "kv_heads", "head_dim"), dt),
            "wo": ParamDef((n, cfg.n_heads, hd, d), ("layers", "heads", "head_dim", "embed"), dt),
            "norm_mlp": norm_defs(d, cfg.norm_kind, dt, layers=n),
            "mlp": mlp_defs(d, cfg.d_ff, cfg.mlp_kind, dt, layers=n),
        }

    def param_defs(self) -> Params:
        cfg = self.cfg
        defs = {
            "embed": embed_defs(cfg.vocab_size, cfg.d_model, self.dtype, tie=cfg.tie_embeddings),
            # Stacked (rec, rec) of each period — two separate stacks so one
            # scan covers all periods.
            "rec_a": self._rec_defs(self.n_periods),
            "rec_b": self._rec_defs(self.n_periods),
            "attn": self._attn_defs(self.n_periods),
            "norm_final": norm_defs(cfg.d_model, cfg.norm_kind, self.dtype),
        }
        if self.n_tail_rec:
            defs["rec_tail"] = self._rec_defs(self.n_tail_rec)
        return defs

    # ------------------------------------------------------------------ #
    # State                                                               #
    # ------------------------------------------------------------------ #
    def cache_shape(self, batch: int, max_len: int):
        cfg = self.cfg
        w = min(self.window, max_len) if max_len else self.window
        n_rec = 2 * self.n_periods + self.n_tail_rec
        f = jax.ShapeDtypeStruct
        out = {
            "rnn_h": f((n_rec, batch, self.rnn), jnp.float32),
            "conv_buf": f((n_rec, batch, cfg.conv1d_width - 1, self.rnn), jnp.float32),
            "attn": ring_cache_shape(self.n_periods, batch, w, cfg.n_kv_heads, self.hd, self.dtype),
            "length": f((batch,), jnp.int32),
        }
        return out

    def cache_init(self, batch: int, max_len: int):
        cfg = self.cfg
        w = min(self.window, max_len) if max_len else self.window
        n_rec = 2 * self.n_periods + self.n_tail_rec
        return {
            "rnn_h": jnp.zeros((n_rec, batch, self.rnn), jnp.float32),
            "conv_buf": jnp.zeros((n_rec, batch, cfg.conv1d_width - 1, self.rnn), jnp.float32),
            "attn": ring_cache_init(self.n_periods, batch, w, cfg.n_kv_heads, self.hd, self.dtype),
            "length": jnp.zeros((batch,), jnp.int32),
        }

    # ------------------------------------------------------------------ #
    # RG-LRU block over a full sequence (time scan inside)                #
    # ------------------------------------------------------------------ #
    def _rec_block_seq(
        self, h: jax.Array, lp: Params, h0: jax.Array, conv_buf0: jax.Array,
        len_vec=None,
    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """h: (B,S,D); h0: (B,R) initial recurrent state; conv_buf0 (B,c-1,R).
        Returns (block output (B,S,D), final state, final conv buffer)."""
        cfg = self.cfg
        x = apply_norm(h, lp["norm"], cfg.norm_kind, cfg.norm_eps)
        u = jnp.einsum("bsd,dr->bsr", x, lp["w_x"]).astype(jnp.float32)   # (B,S,R)
        gate = jax.nn.gelu(
            jnp.einsum("bsd,dr->bsr", x, lp["w_gate"]).astype(jnp.float32)
        )
        # causal temporal conv (width c): pad with the carried buffer
        cw = cfg.conv1d_width
        buf = conv_buf0.astype(jnp.float32)                               # (B,c-1,R)
        u_pad = jnp.concatenate([buf, u], axis=1)                         # (B,S+c-1,R)
        kern = lp["conv_k"].astype(jnp.float32)                           # (c,R)
        conv = sum(
            u_pad[:, i : i + u.shape[1], :] * kern[i][None, None, :] for i in range(cw)
        )                                                                 # (B,S,R)
        r_g = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", conv, lp["w_rgate"].astype(jnp.float32)))
        i_g = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", conv, lp["w_igate"].astype(jnp.float32)))
        log_a = -_C_RGLRU * jax.nn.softplus(lp["lam"].astype(jnp.float32))[None, None, :] * r_g
        a = jnp.exp(log_a)
        gated_x = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-9)) * (i_g * conv)

        def step(hprev, xs):
            a_t, gx_t, t = xs
            h_t = a_t * hprev + gx_t
            if len_vec is not None:
                # ragged prompts: freeze each slot's state past its length
                h_t = jnp.where((t < len_vec)[:, None], h_t, hprev)
            return h_t, h_t

        h_fin, ys = jax.lax.scan(
            step, h0.astype(jnp.float32),
            (jnp.swapaxes(a, 0, 1), jnp.swapaxes(gated_x, 0, 1),
             jnp.arange(u.shape[1], dtype=jnp.int32)),
        )
        rec = jnp.swapaxes(ys, 0, 1)                                      # (B,S,R)
        out = jnp.einsum("bsr,rd->bsd", (rec * gate).astype(self.dtype), lp["w_out"])
        if len_vec is None:
            new_buf = u_pad[:, u_pad.shape[1] - (cw - 1) :, :]
        else:
            # ragged prompts: the decode-time conv buffer must hold each
            # slot's last (cw-1) REAL inputs — u_pad[p + cw - 1] is u[p], so
            # gather indices len_b + i for i in [0, cw-1)
            bsz = u_pad.shape[0]
            idx = len_vec[:, None] + jnp.arange(cw - 1, dtype=jnp.int32)[None, :]
            new_buf = u_pad[jnp.arange(bsz)[:, None], idx]
        return h + out, h_fin, new_buf

    def _attn_block_seq(self, h, lp, positions, k_positions):
        cfg = self.cfg
        x = apply_norm(h, lp["norm"], cfg.norm_kind, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", x, lp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", x, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, lp["wv"])
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        out = attention_any(
            q, k, v, q_positions=positions, k_positions=k_positions,
            causal=True, window=self.window,
        )
        out = jnp.einsum("bshk,hkd->bsd", out, lp["wo"])
        return h + out, (k, v)

    def _mlp_block(self, h, norm_p, mlp_p):
        cfg = self.cfg
        x = apply_norm(h, norm_p, cfg.norm_kind, cfg.norm_eps)
        return h + mlp_apply(x, mlp_p, cfg.mlp_kind)

    # ------------------------------------------------------------------ #
    # Full-sequence forward (training / prefill share this)               #
    # ------------------------------------------------------------------ #
    def _forward_seq(self, params, tokens, cache, write_cache: bool, remat: bool,
                     lengths=None):
        cfg = self.cfg
        b, s = tokens.shape
        h = embed_tokens(tokens, params["embed"]).astype(self.dtype)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        len_vec = None if lengths is None else lengths.astype(jnp.int32)
        k_positions = (
            positions if len_vec is None
            else jnp.where(positions < len_vec[:, None], positions, -1)
        )
        n_rec = 2 * self.n_periods + self.n_tail_rec
        if cache is None:
            rnn_h0 = constrain_batch_dim(jnp.zeros((n_rec, b, self.rnn), jnp.float32), 1)
            conv0 = constrain_batch_dim(
                jnp.zeros((n_rec, b, cfg.conv1d_width - 1, self.rnn), jnp.float32), 1
            )
        else:
            rnn_h0, conv0 = cache["rnn_h"], cache["conv_buf"]
        # recurrent states are ordered: periods' A, periods' B, tail
        pa = self.n_periods

        ring_pos_map = None
        if write_cache:
            w_ring = (cache["attn"]["k"].shape[2] if cache is not None
                      else min(self.window, s))
            ring_pos_map = ring_positions_prefill(
                b, w_ring, s if len_vec is None else len_vec
            )

        def period_body(carry, xs):
            h = carry
            (ra, rb, at, h0a, c0a, h0b, c0b, kc, vc) = xs
            h, hfa, cba = self._rec_block_seq(h, ra, h0a, c0a, len_vec)
            h = self._mlp_block(h, ra["norm_mlp"], ra["mlp"])
            h, hfb, cbb = self._rec_block_seq(h, rb, h0b, c0b, len_vec)
            h = self._mlp_block(h, rb["norm_mlp"], rb["mlp"])
            h, (k_new, v_new) = self._attn_block_seq(h, at, positions, k_positions)
            if write_cache:
                kc, vc = ring_cache_write_prefill(kc, vc, k_new, v_new, ring_pos_map)
            h = self._mlp_block(h, at["norm_mlp"], at["mlp"])
            return h, (hfa, cba, hfb, cbb, kc, vc)

        if remat:
            period_body = jax.checkpoint(
                period_body, policy=jax.checkpoint_policies.nothing_saveable
            )
        kc0 = cache["attn"]["k"] if cache is not None else constrain_batch_dim(
            jnp.zeros((self.n_periods, b, self.window, cfg.n_kv_heads, self.hd), self.dtype), 1
        )
        vc0 = cache["attn"]["v"] if cache is not None else constrain_batch_dim(
            jnp.zeros_like(kc0), 1
        )
        h, (hfa, cba, hfb, cbb, k_all, v_all) = jax.lax.scan(
            period_body,
            h,
            (
                params["rec_a"], params["rec_b"], params["attn"],
                rnn_h0[:pa], conv0[:pa], rnn_h0[pa : 2 * pa], conv0[pa : 2 * pa],
                kc0, vc0,
            ),
        )
        tail_states = []
        if self.n_tail_rec:
            for t in range(self.n_tail_rec):
                lp = jax.tree_util.tree_map(lambda a: a[t], params["rec_tail"])
                h, hft, cbt = self._rec_block_seq(
                    h, lp, rnn_h0[2 * pa + t], conv0[2 * pa + t], len_vec
                )
                h = self._mlp_block(h, lp["norm_mlp"], lp["mlp"])
                tail_states.append((hft, cbt))
        h = apply_norm(h, params["norm_final"], cfg.norm_kind, cfg.norm_eps)

        new_cache = None
        if write_cache:
            rnn_h = jnp.concatenate(
                [hfa, hfb] + [st[0][None] for st in tail_states], axis=0
            )
            conv_buf = jnp.concatenate(
                [cba, cbb] + [st[1][None] for st in tail_states], axis=0
            )
            new_len = (jnp.full((b,), s, jnp.int32) if len_vec is None else len_vec)
            new_cache = {
                "rnn_h": rnn_h,
                "conv_buf": conv_buf,
                "attn": {
                    "k": k_all, "v": v_all,
                    "pos": ring_pos_map,
                    "length": new_len,
                },
                "length": new_len,
            }
        return h, new_cache

    def forward(self, params, tokens, patch_embeds=None, remat: bool = True):
        h, _ = self._forward_seq(params, tokens, None, write_cache=False, remat=remat)
        logits = unembed(h, params["embed"])
        return logits.astype(jnp.float32), jnp.zeros((), jnp.float32)

    def loss(self, params, batch, remat: bool = True):
        logits, _ = self.forward(params, batch["tokens"], remat=remat)
        return cross_entropy_loss(logits, batch["labels"], batch.get("mask"))

    def prefill(self, params, tokens, cache, patch_embeds=None, lengths=None):
        h, new_cache = self._forward_seq(
            params, tokens, cache, write_cache=True, remat=False, lengths=lengths
        )
        b = tokens.shape[0]
        if lengths is None:
            h_last = h[:, -1, :]
        else:
            h_last = h[jnp.arange(b), jnp.maximum(lengths.astype(jnp.int32) - 1, 0), :]
        logits = unembed(h_last, params["embed"]).astype(jnp.float32)
        return logits, new_cache

    # ------------------------------------------------------------------ #
    # Decode                                                              #
    # ------------------------------------------------------------------ #
    def _rec_block_tok(self, h, lp, h0, conv_buf):
        """h: (B,D) one token. Returns (out, new_state, new_conv_buf)."""
        cfg = self.cfg
        x = apply_norm(h, lp["norm"], cfg.norm_kind, cfg.norm_eps)
        u = jnp.einsum("bd,dr->br", x, lp["w_x"]).astype(jnp.float32)
        gate = jax.nn.gelu(jnp.einsum("bd,dr->br", x, lp["w_gate"]).astype(jnp.float32))
        cw = cfg.conv1d_width
        hist = jnp.concatenate([conv_buf.astype(jnp.float32), u[:, None, :]], axis=1)  # (B,c,R)
        kern = lp["conv_k"].astype(jnp.float32)
        conv = jnp.einsum("bcr,cr->br", hist, kern)
        r_g = jax.nn.sigmoid(jnp.einsum("br,rq->bq", conv, lp["w_rgate"].astype(jnp.float32)))
        i_g = jax.nn.sigmoid(jnp.einsum("br,rq->bq", conv, lp["w_igate"].astype(jnp.float32)))
        a = jnp.exp(-_C_RGLRU * jax.nn.softplus(lp["lam"].astype(jnp.float32))[None, :] * r_g)
        h_new = a * h0 + jnp.sqrt(jnp.maximum(1 - jnp.square(a), 1e-9)) * (i_g * conv)
        out = jnp.einsum("br,rd->bd", (h_new * gate).astype(self.dtype), lp["w_out"])
        return h + out, h_new, hist[:, 1:, :]

    def _mlp_block_tok(self, h, norm_p, mlp_p):
        cfg = self.cfg
        x = apply_norm(h, norm_p, cfg.norm_kind, cfg.norm_eps)
        return h + mlp_apply(x, mlp_p, cfg.mlp_kind)

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        b = tokens.shape[0]
        lengths = cache["length"]                      # (B,)
        h = embed_tokens(tokens[:, None], params["embed"])[:, 0, :].astype(self.dtype)
        positions = lengths[:, None].astype(jnp.int32)
        k_pos_now = ring_positions_write_token(cache["attn"]["pos"], lengths)
        pa = self.n_periods

        def period_body(h, xs):
            (ra, rb, at, h0a, c0a, h0b, c0b, kc, vc) = xs
            h, hfa, cba = self._rec_block_tok(h, ra, h0a, c0a)
            h = self._mlp_block_tok(h, ra["norm_mlp"], ra["mlp"])
            h, hfb, cbb = self._rec_block_tok(h, rb, h0b, c0b)
            h = self._mlp_block_tok(h, rb["norm_mlp"], rb["mlp"])
            # attention on one token
            x = apply_norm(h, at["norm"], cfg.norm_kind, cfg.norm_eps)[:, None, :]
            q = jnp.einsum("bsd,dhk->bshk", x, at["wq"])
            k = jnp.einsum("bsd,dhk->bshk", x, at["wk"])
            v = jnp.einsum("bsd,dhk->bshk", x, at["wv"])
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            kc, vc = ring_cache_write_token(kc, vc, k, v, lengths)
            out = attention(
                q, kc, vc, q_positions=positions, k_positions=k_pos_now,
                causal=True, window=self.window,
            )
            out = jnp.einsum("bshk,hkd->bsd", out, at["wo"])[:, 0, :]
            h = h + out
            h = self._mlp_block_tok(h, at["norm_mlp"], at["mlp"])
            return h, (hfa, cba, hfb, cbb, kc, vc)

        h, (hfa, cba, hfb, cbb, k_all, v_all) = jax.lax.scan(
            period_body,
            h,
            (
                params["rec_a"], params["rec_b"], params["attn"],
                cache["rnn_h"][:pa], cache["conv_buf"][:pa],
                cache["rnn_h"][pa : 2 * pa], cache["conv_buf"][pa : 2 * pa],
                cache["attn"]["k"], cache["attn"]["v"],
            ),
        )
        tails_h, tails_c = [], []
        for t in range(self.n_tail_rec):
            lp = jax.tree_util.tree_map(lambda a: a[t], params["rec_tail"])
            h, hft, cbt = self._rec_block_tok(
                h, lp, cache["rnn_h"][2 * pa + t], cache["conv_buf"][2 * pa + t]
            )
            h = self._mlp_block_tok(h, lp["norm_mlp"], lp["mlp"])
            tails_h.append(hft[None])
            tails_c.append(cbt[None])
        h = apply_norm(h, params["norm_final"], cfg.norm_kind, cfg.norm_eps)
        logits = unembed(h, params["embed"]).astype(jnp.float32)
        new_cache = {
            "rnn_h": jnp.concatenate([hfa, hfb] + tails_h, axis=0),
            "conv_buf": jnp.concatenate([cba, cbb] + tails_c, axis=0),
            "attn": {
                "k": k_all, "v": v_all,
                "pos": k_pos_now,
                "length": cache["attn"]["length"] + 1,
            },
            "length": lengths + 1,
        }
        return logits, new_cache
