"""Mixture-of-Experts block — sort-based scatter dispatch (TPU-native).

Top-k routing with *grouped scatter dispatch*: within each group (a sequence
chunk, which is also the data-parallel shard unit) the (token, choice) pairs
are ranked within their chosen expert by a stable argsort, scattered into a
per-expert capacity buffer (E, C, D), batch-matmul'd through the expert
FFNs, and gathered back with their gate weights.

Why not the GShard one-hot-einsum formulation: its dispatch/combine tensors
are O(N·E·C) *and* its einsums burn O(N·E·C·D) MXU FLOPs — for a 64-expert
top-8 arch (olmoe) that is ~100× the expert FFN FLOPs themselves and >10 GB
of one-hots per device at 1M tokens. The scatter form moves O(N·k·D) bytes
and adds no matmul FLOPs (§Perf logs the before/after). Capacity-overflow
tokens drop to the residual path (standard contract); priority is token
order, matching the cumsum-one-hot semantics.

Sharding: groups ride the data axes; expert FFN weights shard
(embed→data-FSDP, mlp→model); per-group buffers stay local so the argsort
never crosses shards.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import ParamDef


def moe_defs(
    n_layers: Optional[int],
    d_model: int,
    d_ff: int,
    n_experts: int,
    mlp_kind: str,
    dtype=jnp.bfloat16,
):
    lead = (n_layers,) if n_layers else ()
    lax = ("layers",) if n_layers else ()
    defs: Dict[str, ParamDef] = {
        "router": ParamDef(lead + (d_model, n_experts), lax + ("embed", "experts"), jnp.float32),
    }
    if mlp_kind == "swiglu":
        defs["w_gate"] = ParamDef(
            lead + (n_experts, d_model, d_ff), lax + ("experts", "embed", "mlp"), dtype
        )
    defs["w_up"] = ParamDef(
        lead + (n_experts, d_model, d_ff), lax + ("experts", "embed", "mlp"), dtype
    )
    defs["w_down"] = ParamDef(
        lead + (n_experts, d_ff, d_model), lax + ("experts", "mlp", "embed"), dtype
    )
    return defs


def _ranks_within_expert(flat_choice: jax.Array, n_experts: int) -> jax.Array:
    """flat_choice: (T,) expert ids. Returns each element's rank among
    same-expert elements (stable, token-order priority)."""
    t = flat_choice.shape[0]
    order = jnp.argsort(flat_choice, stable=True)
    sorted_e = flat_choice[order]
    idx = jnp.arange(t, dtype=jnp.int32)
    is_new = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]]
    )
    seg_start = jax.lax.cummax(jnp.where(is_new, idx, 0))
    rank_sorted = idx - seg_start
    ranks = jnp.zeros((t,), jnp.int32).at[order].set(rank_sorted)
    return ranks


def moe_apply(
    x: jax.Array,                 # (B, S, D)
    p: Dict[str, jax.Array],
    *,
    n_experts: int,
    top_k: int,
    mlp_kind: str,
    capacity_factor: float = 1.25,
    group_size: int = 512,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux load-balancing loss)."""
    from ..distributed.sharding import constrain_batch_dim

    b, s, d = x.shape
    e, k = n_experts, top_k
    g = min(group_size, s)
    if s % g != 0:
        g = s
    n_groups = s // g
    xg = constrain_batch_dim(x.reshape(b * n_groups, g, d), 0)  # (G, g, D)

    logits = jnp.einsum("Ggd,de->Gge", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                     # (G, g, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)               # (G, g, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # Load-balancing aux loss (Switch): E * Σ_e f_e · p̄_e
    me = jnp.mean(probs, axis=(0, 1))
    fe = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    aux_loss = e * jnp.sum(me * fe)

    capacity = max(1, int(capacity_factor * g * k / e))

    flat_choice = gate_idx.reshape(b * n_groups, g * k)         # (G, T)
    ranks = jax.vmap(lambda fc: _ranks_within_expert(fc, e))(flat_choice)
    within = ranks < capacity                                   # (G, T)
    pos = jnp.minimum(ranks, capacity - 1)

    # scatter tokens into per-expert capacity buffers
    xk = jnp.repeat(xg, k, axis=1)                              # (G, T, D)
    contrib = jnp.where(within[..., None], xk, 0).astype(x.dtype)

    def scatter_group(eids, poss, vals):
        return jnp.zeros((e, capacity, d), x.dtype).at[eids, poss].add(vals)

    expert_in = constrain_batch_dim(
        jax.vmap(scatter_group)(flat_choice, pos, contrib), 0
    )                                                           # (G, E, C, D)

    if mlp_kind == "swiglu":
        gate_h = jnp.einsum("GECD,EDF->GECF", expert_in, p["w_gate"])
        up_h = jnp.einsum("GECD,EDF->GECF", expert_in, p["w_up"])
        h = jax.nn.silu(gate_h.astype(jnp.float32)).astype(x.dtype) * up_h
    elif mlp_kind == "squared_relu":
        h = jnp.einsum("GECD,EDF->GECF", expert_in, p["w_up"])
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    else:
        h = jnp.einsum("GECD,EDF->GECF", expert_in, p["w_up"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    expert_out = jnp.einsum("GECF,EFD->GECD", h, p["w_down"])   # (G, E, C, D)

    # gather each choice's expert output, weight by its gate, sum over k
    def gather_group(buf, eids, poss):
        return buf[eids, poss]                                  # (T, D)

    out_k = jax.vmap(gather_group)(expert_out, flat_choice, pos)
    out_k = out_k.astype(jnp.float32) * (
        gate_vals.reshape(b * n_groups, g * k)[..., None] * within[..., None]
    )
    out = out_k.reshape(b * n_groups, g, k, d).sum(axis=2)
    return out.reshape(b, s, d).astype(x.dtype), aux_loss
