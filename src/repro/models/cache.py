"""KV / recurrent-state cache containers.

Caches are plain dict pytrees of arrays (stacked over layers) so they flow
through jit/pjit with explicit shardings and can be declared abstractly for
the dry-run. Three attention cache styles:

  * full cache  — (L, B, S_max, KV, D); write cursor = ``length``
  * ring cache  — (L, B, W, KV, D) for sliding-window attention; slot
                  ``length % W``; per-slot absolute positions are stored so
                  masking stays position-based (see models.attention)
  * paged cache — (L, KV, P, bs, D): a global pool of P pages of ``bs``
                  tokens each, indirected through a per-slot ``block_tables``
                  row ((n_slots, MB) int32; -1 = unallocated). Slots own only
                  the pages their live tokens occupy, so KV memory scales
                  with tokens-in-use instead of n_slots × max_len. The page
                  axis precedes the token axis with KV outermost so the
                  Pallas decode kernel's (bs, D) page blocks are tiled
                  contiguously (see kernels.paged_decode_attention).

Recurrent families (xLSTM, RG-LRU) keep per-layer state tensors instead; see
their modules. ``length`` is a scalar int32 shared by all layers.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def full_cache_shape(
    n_layers: int, batch: int, max_len: int, kv_heads: int, head_dim: int,
    dtype=jnp.bfloat16,
) -> Dict[str, jax.ShapeDtypeStruct]:
    f = jax.ShapeDtypeStruct
    return {
        "k": f((n_layers, batch, max_len, kv_heads, head_dim), dtype),
        "v": f((n_layers, batch, max_len, kv_heads, head_dim), dtype),
        "length": f((batch,), jnp.int32),
    }


def full_cache_init(
    n_layers: int, batch: int, max_len: int, kv_heads: int, head_dim: int,
    dtype=jnp.bfloat16,
) -> Dict[str, jax.Array]:
    return {
        "k": jnp.zeros((n_layers, batch, max_len, kv_heads, head_dim), dtype),
        "v": jnp.zeros((n_layers, batch, max_len, kv_heads, head_dim), dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def ring_cache_shape(
    n_layers: int, batch: int, window: int, kv_heads: int, head_dim: int,
    dtype=jnp.bfloat16,
) -> Dict[str, jax.ShapeDtypeStruct]:
    f = jax.ShapeDtypeStruct
    return {
        "k": f((n_layers, batch, window, kv_heads, head_dim), dtype),
        "v": f((n_layers, batch, window, kv_heads, head_dim), dtype),
        "pos": f((batch, window), jnp.int32),
        "length": f((batch,), jnp.int32),
    }


def ring_cache_init(
    n_layers: int, batch: int, window: int, kv_heads: int, head_dim: int,
    dtype=jnp.bfloat16,
) -> Dict[str, jax.Array]:
    return {
        "k": jnp.zeros((n_layers, batch, window, kv_heads, head_dim), dtype),
        "v": jnp.zeros((n_layers, batch, window, kv_heads, head_dim), dtype),
        "pos": jnp.full((batch, window), -1, jnp.int32),
        "length": jnp.zeros((batch,), jnp.int32),
    }


# --------------------------------------------------------------------------- #
# Per-layer write ops (used inside the layer scan; arrays are layer slices).  #
# --------------------------------------------------------------------------- #
def full_cache_write(
    k_layer: jax.Array,       # (B, S_max, KV, D)
    v_layer: jax.Array,
    k_new: jax.Array,         # (B, S_new, KV, D)
    v_new: jax.Array,
    start: jax.Array,         # scalar int32 — write offset
) -> Tuple[jax.Array, jax.Array]:
    k_layer = jax.lax.dynamic_update_slice(k_layer, k_new.astype(k_layer.dtype), (0, start, 0, 0))
    v_layer = jax.lax.dynamic_update_slice(v_layer, v_new.astype(v_layer.dtype), (0, start, 0, 0))
    return k_layer, v_layer


def full_cache_write_token(
    k_layer: jax.Array,       # (B, S_max, KV, D)
    v_layer: jax.Array,
    k_new: jax.Array,         # (B, 1, KV, D)
    v_new: jax.Array,
    positions: jax.Array,     # (B,) int32 — per-slot write positions
    active: Optional[jax.Array] = None,   # (B,) bool — rows allowed to write
) -> Tuple[jax.Array, jax.Array]:
    b, s_max = k_layer.shape[:2]
    rows = jnp.arange(b)
    if active is not None:
        # inactive rows write at S_max → dropped by the scatter (the fused
        # decode loop keeps finished slots as no-ops instead of early-exiting)
        positions = jnp.where(active, positions, s_max)
    k_layer = k_layer.at[rows, positions].set(
        k_new[:, 0].astype(k_layer.dtype), mode="drop"
    )
    v_layer = v_layer.at[rows, positions].set(
        v_new[:, 0].astype(v_layer.dtype), mode="drop"
    )
    return k_layer, v_layer


def ring_cache_write_token(
    k_layer: jax.Array,       # (B, W, KV, D)
    v_layer: jax.Array,
    k_new: jax.Array,         # (B, 1, KV, D)
    v_new: jax.Array,
    positions: jax.Array,     # (B,) int32 — absolute token positions
    active: Optional[jax.Array] = None,   # (B,) bool — rows allowed to write
) -> Tuple[jax.Array, jax.Array]:
    b, w = k_layer.shape[:2]
    rows = jnp.arange(b)
    slots = jnp.mod(positions, w)
    if active is not None:
        slots = jnp.where(active, slots, w)   # OOB → dropped
    k_layer = k_layer.at[rows, slots].set(
        k_new[:, 0].astype(k_layer.dtype), mode="drop"
    )
    v_layer = v_layer.at[rows, slots].set(
        v_new[:, 0].astype(v_layer.dtype), mode="drop"
    )
    return k_layer, v_layer


def ring_positions_write_token(
    pos: jax.Array, positions: jax.Array,
    active: Optional[jax.Array] = None,
) -> jax.Array:
    """Update the (B, W) slot→absolute-position map for one token per slot."""
    b, w = pos.shape
    rows = jnp.arange(b)
    slots = jnp.mod(positions, w)
    if active is not None:
        slots = jnp.where(active, slots, w)   # OOB → dropped
    return pos.at[rows, slots].set(positions.astype(pos.dtype), mode="drop")


def ring_cache_write_prefill(
    k_layer: jax.Array,       # (B, W, KV, D)
    v_layer: jax.Array,
    k_new: jax.Array,         # (B, S, KV, D) — token p at row p
    v_new: jax.Array,
    pos_map: Optional[jax.Array] = None,   # (B, W) slot→position (-1 empty)
) -> Tuple[jax.Array, jax.Array]:
    """Bulk write of a prefill into a ring cache.

    ``pos_map`` (from ``ring_positions_prefill``) names the absolute position
    each ring slot should hold — per batch row, so ragged prompts (engine
    path) fill correctly. Slots mapped to -1 are zeroed. With no map, a
    uniform full-width prefill is assumed."""
    w = k_layer.shape[1]
    s = k_new.shape[1]
    b = k_layer.shape[0]
    if pos_map is None:
        pos_map = ring_positions_prefill(b, w, s)
    rows = jnp.arange(b)[:, None]
    idx = jnp.clip(pos_map, 0, s - 1)
    valid = (pos_map >= 0)[..., None, None]
    k_layer = jnp.where(valid, k_new[rows, idx], 0).astype(k_layer.dtype)
    v_layer = jnp.where(valid, v_new[rows, idx], 0).astype(v_layer.dtype)
    return k_layer, v_layer


def paged_cache_shape(
    n_layers: int, num_pages: int, page_size: int, kv_heads: int,
    head_dim: int, n_slots: int, max_pages_per_slot: int,
    dtype=jnp.bfloat16,
) -> Dict[str, jax.ShapeDtypeStruct]:
    f = jax.ShapeDtypeStruct
    return {
        "k": f((n_layers, kv_heads, num_pages, page_size, head_dim), dtype),
        "v": f((n_layers, kv_heads, num_pages, page_size, head_dim), dtype),
        "block_tables": f((n_slots, max_pages_per_slot), jnp.int32),
        "length": f((n_slots,), jnp.int32),
    }


def paged_cache_init(
    n_layers: int, num_pages: int, page_size: int, kv_heads: int,
    head_dim: int, n_slots: int, max_pages_per_slot: int,
    dtype=jnp.bfloat16,
) -> Dict[str, jax.Array]:
    return {
        "k": jnp.zeros((n_layers, kv_heads, num_pages, page_size, head_dim), dtype),
        "v": jnp.zeros((n_layers, kv_heads, num_pages, page_size, head_dim), dtype),
        "block_tables": jnp.full((n_slots, max_pages_per_slot), -1, jnp.int32),
        "length": jnp.zeros((n_slots,), jnp.int32),
    }


def paged_cache_write(
    k_layer: jax.Array,       # (KV, P, bs, D) — one layer's page pool
    v_layer: jax.Array,
    k_new: jax.Array,         # (B, S, KV, D) — token t of row b at position
    v_new: jax.Array,         #                 starts[b] + t
    block_tables: jax.Array,  # (B, MB) int32; -1 = unallocated
    starts: jax.Array,        # (B,) int32 — first token's absolute position
    lens: jax.Array,          # (B,) int32 — valid tokens per row (≤ S)
) -> Tuple[jax.Array, jax.Array]:
    """Scatter a prefill chunk into the page pool through the block table.

    Rows may sit at different offsets (ragged chunked prefill); tokens beyond
    ``lens`` or mapping to an unallocated page are dropped, so padded batch
    rows can point at any table row without corrupting it.
    """
    kv, p, bs, d = k_layer.shape
    b, s = k_new.shape[:2]
    mb = block_tables.shape[1]
    pos = starts[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]   # (B, S)
    blk = pos // bs
    page = jnp.take_along_axis(block_tables, jnp.clip(blk, 0, mb - 1), axis=1)
    valid = (jnp.arange(s)[None, :] < lens[:, None]) & (page >= 0) & (blk < mb)
    flat = jnp.where(valid, page * bs + pos % bs, p * bs)             # OOB → drop
    flat = flat.reshape(-1)
    kf = k_layer.reshape(kv, p * bs, d)
    vf = v_layer.reshape(kv, p * bs, d)
    k_rows = k_new.astype(k_layer.dtype).transpose(2, 0, 1, 3).reshape(kv, b * s, d)
    v_rows = v_new.astype(v_layer.dtype).transpose(2, 0, 1, 3).reshape(kv, b * s, d)
    kf = kf.at[:, flat].set(k_rows, mode="drop")
    vf = vf.at[:, flat].set(v_rows, mode="drop")
    return kf.reshape(kv, p, bs, d), vf.reshape(kv, p, bs, d)


def paged_cache_write_token(
    k_layer: jax.Array,       # (KV, P, bs, D)
    v_layer: jax.Array,
    k_new: jax.Array,         # (B, 1, KV, D)
    v_new: jax.Array,
    block_tables: jax.Array,  # (B, MB)
    positions: jax.Array,     # (B,) int32 — absolute write positions
    active: jax.Array,        # (B,) bool — rows allowed to write
) -> Tuple[jax.Array, jax.Array]:
    """One-token-per-slot decode write. Unlike the dense cache (where idle
    rows absorb garbage harmlessly), paged pages are shared through the
    allocator, so inactive slots MUST NOT write — their row could alias a
    page now owned by another slot."""
    kv, p, bs, d = k_layer.shape
    b = positions.shape[0]
    mb = block_tables.shape[1]
    blk = positions // bs
    page = jnp.take_along_axis(
        block_tables, jnp.clip(blk, 0, mb - 1)[:, None], axis=1
    )[:, 0]
    valid = active & (page >= 0) & (blk < mb)
    flat = jnp.where(valid, page * bs + positions % bs, p * bs)
    kf = k_layer.reshape(kv, p * bs, d)
    vf = v_layer.reshape(kv, p * bs, d)
    kf = kf.at[:, flat].set(k_new[:, 0].transpose(1, 0, 2).astype(k_layer.dtype), mode="drop")
    vf = vf.at[:, flat].set(v_new[:, 0].transpose(1, 0, 2).astype(v_layer.dtype), mode="drop")
    return kf.reshape(kv, p, bs, d), vf.reshape(kv, p, bs, d)


def paged_gather_kv(
    k_layer: jax.Array,       # (KV, P, bs, D)
    v_layer: jax.Array,
    block_tables: jax.Array,  # (B, MB)
) -> Tuple[jax.Array, jax.Array]:
    """Assemble each slot's logical KV sequence, (B, MB·bs, KV, D), from the
    page pool — the pure-jnp realization of what the Pallas paged kernel does
    with block-table-indirected DMA. Unallocated pages read page 0; callers
    mask those positions via ``paged_key_positions``."""
    kv, p, bs, d = k_layer.shape
    b, mb = block_tables.shape
    idx = jnp.arange(mb * bs, dtype=jnp.int32)
    page = block_tables[:, idx // bs]                                  # (B, MB·bs)
    flat = jnp.where(page >= 0, page * bs + idx % bs, 0).reshape(-1)
    k_ctx = k_layer.reshape(kv, p * bs, d)[:, flat]
    v_ctx = v_layer.reshape(kv, p * bs, d)[:, flat]
    k_ctx = k_ctx.reshape(kv, b, mb * bs, d).transpose(1, 2, 0, 3)
    v_ctx = v_ctx.reshape(kv, b, mb * bs, d).transpose(1, 2, 0, 3)
    return k_ctx, v_ctx


def paged_key_positions(
    block_tables: jax.Array,  # (B, MB)
    lengths: jax.Array,       # (B,) — valid tokens per slot
    page_size: int,
) -> jax.Array:
    """(B, MB·bs) position map for gathered paged KV: index i where valid,
    -1 where past ``lengths`` or on an unallocated page (masked out by
    position-based attention, see models.attention)."""
    b, mb = block_tables.shape
    idx = jnp.arange(mb * page_size, dtype=jnp.int32)
    page = block_tables[:, idx // page_size]
    valid = (idx[None, :] < lengths[:, None]) & (page >= 0)
    return jnp.where(valid, idx[None, :], -1)


def ring_positions_prefill(batch: int, window: int, s) -> jax.Array:
    """Slot→position map after prefills of length ``s``.

    ``s`` may be a static int (uniform prefill) or a (B,) vector of
    per-slot lengths (engine path). Slot z holds the largest p < s with
    p ≡ z (mod w); slots beyond the fill level hold -1."""
    w = window
    slots = jnp.arange(w, dtype=jnp.int32)
    if isinstance(s, int):
        if s <= w:
            pos = jnp.where(slots < s, slots, -1)
        else:
            pos = s - 1 - jnp.mod((s - 1 - slots), w)
        return jnp.broadcast_to(pos[None, :], (batch, w)).astype(jnp.int32)
    sv = s.astype(jnp.int32)[:, None]                      # (B, 1)
    # largest p < s with p ≡ z (mod w); equals z itself when s <= w
    pos = sv - 1 - jnp.mod(sv - 1 - slots[None, :], w)     # (B, W)
    pos = jnp.where((slots[None, :] >= sv) & (sv <= w), -1, pos)
    return pos.astype(jnp.int32)
