"""KV / recurrent-state cache containers.

Caches are plain dict pytrees of arrays (stacked over layers) so they flow
through jit/pjit with explicit shardings and can be declared abstractly for
the dry-run. Two attention cache styles:

  * full cache  — (L, B, S_max, KV, D); write cursor = ``length``
  * ring cache  — (L, B, W, KV, D) for sliding-window attention; slot
                  ``length % W``; per-slot absolute positions are stored so
                  masking stays position-based (see models.attention)

Recurrent families (xLSTM, RG-LRU) keep per-layer state tensors instead; see
their modules. ``length`` is a scalar int32 shared by all layers.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def full_cache_shape(
    n_layers: int, batch: int, max_len: int, kv_heads: int, head_dim: int,
    dtype=jnp.bfloat16,
) -> Dict[str, jax.ShapeDtypeStruct]:
    f = jax.ShapeDtypeStruct
    return {
        "k": f((n_layers, batch, max_len, kv_heads, head_dim), dtype),
        "v": f((n_layers, batch, max_len, kv_heads, head_dim), dtype),
        "length": f((batch,), jnp.int32),
    }


def full_cache_init(
    n_layers: int, batch: int, max_len: int, kv_heads: int, head_dim: int,
    dtype=jnp.bfloat16,
) -> Dict[str, jax.Array]:
    return {
        "k": jnp.zeros((n_layers, batch, max_len, kv_heads, head_dim), dtype),
        "v": jnp.zeros((n_layers, batch, max_len, kv_heads, head_dim), dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def ring_cache_shape(
    n_layers: int, batch: int, window: int, kv_heads: int, head_dim: int,
    dtype=jnp.bfloat16,
) -> Dict[str, jax.ShapeDtypeStruct]:
    f = jax.ShapeDtypeStruct
    return {
        "k": f((n_layers, batch, window, kv_heads, head_dim), dtype),
        "v": f((n_layers, batch, window, kv_heads, head_dim), dtype),
        "pos": f((batch, window), jnp.int32),
        "length": f((batch,), jnp.int32),
    }


def ring_cache_init(
    n_layers: int, batch: int, window: int, kv_heads: int, head_dim: int,
    dtype=jnp.bfloat16,
) -> Dict[str, jax.Array]:
    return {
        "k": jnp.zeros((n_layers, batch, window, kv_heads, head_dim), dtype),
        "v": jnp.zeros((n_layers, batch, window, kv_heads, head_dim), dtype),
        "pos": jnp.full((batch, window), -1, jnp.int32),
        "length": jnp.zeros((batch,), jnp.int32),
    }


# --------------------------------------------------------------------------- #
# Per-layer write ops (used inside the layer scan; arrays are layer slices).  #
# --------------------------------------------------------------------------- #
def full_cache_write(
    k_layer: jax.Array,       # (B, S_max, KV, D)
    v_layer: jax.Array,
    k_new: jax.Array,         # (B, S_new, KV, D)
    v_new: jax.Array,
    start: jax.Array,         # scalar int32 — write offset
) -> Tuple[jax.Array, jax.Array]:
    k_layer = jax.lax.dynamic_update_slice(k_layer, k_new.astype(k_layer.dtype), (0, start, 0, 0))
    v_layer = jax.lax.dynamic_update_slice(v_layer, v_new.astype(v_layer.dtype), (0, start, 0, 0))
    return k_layer, v_layer


def full_cache_write_token(
    k_layer: jax.Array,       # (B, S_max, KV, D)
    v_layer: jax.Array,
    k_new: jax.Array,         # (B, 1, KV, D)
    v_new: jax.Array,
    positions: jax.Array,     # (B,) int32 — per-slot write positions
) -> Tuple[jax.Array, jax.Array]:
    b = k_layer.shape[0]
    rows = jnp.arange(b)
    k_layer = k_layer.at[rows, positions].set(k_new[:, 0].astype(k_layer.dtype))
    v_layer = v_layer.at[rows, positions].set(v_new[:, 0].astype(v_layer.dtype))
    return k_layer, v_layer


def ring_cache_write_token(
    k_layer: jax.Array,       # (B, W, KV, D)
    v_layer: jax.Array,
    k_new: jax.Array,         # (B, 1, KV, D)
    v_new: jax.Array,
    positions: jax.Array,     # (B,) int32 — absolute token positions
) -> Tuple[jax.Array, jax.Array]:
    b, w = k_layer.shape[:2]
    rows = jnp.arange(b)
    slots = jnp.mod(positions, w)
    k_layer = k_layer.at[rows, slots].set(k_new[:, 0].astype(k_layer.dtype))
    v_layer = v_layer.at[rows, slots].set(v_new[:, 0].astype(v_layer.dtype))
    return k_layer, v_layer


def ring_positions_write_token(pos: jax.Array, positions: jax.Array) -> jax.Array:
    """Update the (B, W) slot→absolute-position map for one token per slot."""
    b, w = pos.shape
    rows = jnp.arange(b)
    slots = jnp.mod(positions, w)
    return pos.at[rows, slots].set(positions.astype(pos.dtype))


def ring_cache_write_prefill(
    k_layer: jax.Array,       # (B, W, KV, D)
    v_layer: jax.Array,
    k_new: jax.Array,         # (B, S, KV, D) — token p at row p
    v_new: jax.Array,
    pos_map: Optional[jax.Array] = None,   # (B, W) slot→position (-1 empty)
) -> Tuple[jax.Array, jax.Array]:
    """Bulk write of a prefill into a ring cache.

    ``pos_map`` (from ``ring_positions_prefill``) names the absolute position
    each ring slot should hold — per batch row, so ragged prompts (engine
    path) fill correctly. Slots mapped to -1 are zeroed. With no map, a
    uniform full-width prefill is assumed."""
    w = k_layer.shape[1]
    s = k_new.shape[1]
    b = k_layer.shape[0]
    if pos_map is None:
        pos_map = ring_positions_prefill(b, w, s)
    rows = jnp.arange(b)[:, None]
    idx = jnp.clip(pos_map, 0, s - 1)
    valid = (pos_map >= 0)[..., None, None]
    k_layer = jnp.where(valid, k_new[rows, idx], 0).astype(k_layer.dtype)
    v_layer = jnp.where(valid, v_new[rows, idx], 0).astype(v_layer.dtype)
    return k_layer, v_layer


def ring_positions_prefill(batch: int, window: int, s) -> jax.Array:
    """Slot→position map after prefills of length ``s``.

    ``s`` may be a static int (uniform prefill) or a (B,) vector of
    per-slot lengths (engine path). Slot z holds the largest p < s with
    p ≡ z (mod w); slots beyond the fill level hold -1."""
    w = window
    slots = jnp.arange(w, dtype=jnp.int32)
    if isinstance(s, int):
        if s <= w:
            pos = jnp.where(slots < s, slots, -1)
        else:
            pos = s - 1 - jnp.mod((s - 1 - slots), w)
        return jnp.broadcast_to(pos[None, :], (batch, w)).astype(jnp.int32)
    sv = s.astype(jnp.int32)[:, None]                      # (B, 1)
    # largest p < s with p ≡ z (mod w); equals z itself when s <= w
    pos = sv - 1 - jnp.mod(sv - 1 - slots[None, :], w)     # (B, W)
    pos = jnp.where((slots[None, :] >= sv) & (sv <= w), -1, pos)
    return pos.astype(jnp.int32)
