"""xLSTM (arXiv:2405.04517) — alternating mLSTM / sLSTM blocks.

Recurrent decode state replaces the KV cache entirely, so per-token decode
cost is constant in context length — this family runs the ``long_500k`` cell
natively. Implementation notes (documented adaptations, see DESIGN.md):

  * Exponential gating with the paper's max-stabilizer ``m`` (both cells).
  * mLSTM: per-head matrix memory C ∈ R^{hd×hd}, normalizer n, scalar gates.
  * sLSTM: per-head vector memory with block-diagonal recurrent weights
    (one hd×hd recurrence per head, the paper's head-wise mixing).
  * The width-4 causal convs of the reference blocks are omitted (they are
    a local-mixing detail orthogonal to the recurrence; noted in DESIGN.md).
  * Training runs the same per-token step function under ``lax.scan`` over
    time (sequential form). The chunkwise-parallel training form is a
    kernel-level optimization we document but do not need for the dry-run.

The per-token step function is shared verbatim between training, prefill
and decode, so serve/train consistency is structural.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distributed.sharding import constrain_batch_dim
from .layers import (
    ParamDef,
    apply_norm,
    cross_entropy_loss,
    embed_defs,
    embed_tokens,
    norm_defs,
    unembed,
)

Params = Dict[str, Any]


class XLSTM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        if cfg.n_layers % 2 != 0:
            raise ValueError("XLSTM expects an even layer count (mLSTM/sLSTM pairs)")
        self.n_pairs = cfg.n_layers // 2
        self.hd = cfg.resolved_head_dim or cfg.d_model // cfg.n_heads
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    # ------------------------------------------------------------------ #
    def param_defs(self) -> Params:
        cfg, hd, dt = self.cfg, self.hd, self.dtype
        P, H, d = self.n_pairs, cfg.n_heads, cfg.d_model

        def proj(*shape_axes):
            shape, axes = zip(*shape_axes)
            return ParamDef((P,) + tuple(shape), ("layers",) + tuple(axes), dt)

        mlstm = {
            "norm": norm_defs(d, cfg.norm_kind, dt, layers=P),
            "wq": proj((d, "embed"), (H, "heads"), (hd, "head_dim")),
            "wk": proj((d, "embed"), (H, "heads"), (hd, "head_dim")),
            "wv": proj((d, "embed"), (H, "heads"), (hd, "head_dim")),
            "wif": proj((d, "embed"), (H, "heads"), (2, None)),   # i/f gate preacts
            "wgate": proj((d, "embed"), (d, "rnn")),
            "wout": proj((H, "heads"), (hd, "head_dim"), (d, "embed")),
        }
        slstm = {
            "norm": norm_defs(d, cfg.norm_kind, dt, layers=P),
            "wz": proj((d, "embed"), (H, "heads"), (hd, "head_dim")),
            "wi": proj((d, "embed"), (H, "heads"), (hd, "head_dim")),
            "wf": proj((d, "embed"), (H, "heads"), (hd, "head_dim")),
            "wo": proj((d, "embed"), (H, "heads"), (hd, "head_dim")),
            "rz": proj((H, "heads"), (hd, "head_dim"), (hd, None)),
            "ri": proj((H, "heads"), (hd, "head_dim"), (hd, None)),
            "rf": proj((H, "heads"), (hd, "head_dim"), (hd, None)),
            "ro": proj((H, "heads"), (hd, "head_dim"), (hd, None)),
            "wout": proj((H, "heads"), (hd, "head_dim"), (d, "embed")),
        }
        return {
            "embed": embed_defs(cfg.vocab_size, d, dt, tie=cfg.tie_embeddings),
            "pairs": {"mlstm": mlstm, "slstm": slstm},
            "norm_final": norm_defs(d, cfg.norm_kind, dt),
        }

    # ------------------------------------------------------------------ #
    # State (the "cache")                                                 #
    # ------------------------------------------------------------------ #
    def cache_shape(self, batch: int, max_len: int = 0):
        cfg, hd, P, H = self.cfg, self.hd, self.n_pairs, self.cfg.n_heads
        f = jax.ShapeDtypeStruct
        return {
            "m_C": f((P, batch, H, hd, hd), jnp.float32),
            "m_n": f((P, batch, H, hd), jnp.float32),
            "m_m": f((P, batch, H), jnp.float32),
            "s_c": f((P, batch, H, hd), jnp.float32),
            "s_n": f((P, batch, H, hd), jnp.float32),
            "s_h": f((P, batch, H, hd), jnp.float32),
            "s_m": f((P, batch, H), jnp.float32),
            "length": f((batch,), jnp.int32),
        }

    def cache_init(self, batch: int, max_len: int = 0):
        # Batch-shard the zero-init states when a mesh is ambient: GSPMD
        # leaves internally-created intermediates replicated otherwise,
        # multiplying the BPTT carry footprint by the mesh size.
        return jax.tree_util.tree_map(
            lambda s: constrain_batch_dim(jnp.zeros(s.shape, s.dtype), 1),
            self.cache_shape(batch, max_len),
        )

    # ------------------------------------------------------------------ #
    # Cells                                                               #
    # ------------------------------------------------------------------ #
    @staticmethod
    def _mlstm_cell(state, q, k, v, i_pre, f_pre):
        """state: (C (B,H,hd,hd), n (B,H,hd), m (B,H)); q,k,v (B,H,hd)."""
        C, n, m = state
        m_new = jnp.maximum(f_pre + m, i_pre)                     # (B,H)
        i_g = jnp.exp(i_pre - m_new)[..., None]                   # (B,H,1)
        f_g = jnp.exp(f_pre + m - m_new)[..., None]
        outer = v[..., :, None] * k[..., None, :]                 # (B,H,hd,hd)
        C = f_g[..., None] * C + i_g[..., None] * outer
        n = f_g * n + i_g * k
        num = jnp.einsum("bhij,bhj->bhi", C, q)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhi,bhi->bh", n, q)), 1.0)[..., None]
        h = num / den
        return (C, n, m_new), h

    @staticmethod
    def _slstm_cell(state, z_pre, i_pre, f_pre, o_pre):
        """state: (c, n, h, m) each (B,H,hd); gate preacts (B,H,hd)."""
        c, n, h, m = state
        # Head-wise scalar stabilizer from the max gate preactivation.
        m_new = jnp.maximum(f_pre.max(-1) + m, i_pre.max(-1))
        i_g = jnp.exp(i_pre - m_new[..., None])
        f_g = jnp.exp(f_pre + m[..., None] - m_new[..., None])
        z = jnp.tanh(z_pre)
        c = f_g * c + i_g * z
        n = f_g * n + i_g
        h = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    # ------------------------------------------------------------------ #
    # One full-depth step for one token                                   #
    # ------------------------------------------------------------------ #
    def _token_step(self, params: Params, cache: Dict[str, jax.Array], x: jax.Array):
        """x: (B, D) one token's hidden; returns (new_cache, y (B, D))."""
        cfg = self.cfg

        def pair_body(h, xs):
            (mp, sp, mC, mn, mm, sc, sn, sh, sm) = xs
            # --- mLSTM block ------------------------------------------ #
            xn = apply_norm(h, mp["norm"], cfg.norm_kind, cfg.norm_eps).astype(jnp.float32)
            q = jnp.einsum("bd,dhk->bhk", xn, mp["wq"].astype(jnp.float32))
            k = jnp.einsum("bd,dhk->bhk", xn, mp["wk"].astype(jnp.float32)) / (self.hd ** 0.5)
            v = jnp.einsum("bd,dhk->bhk", xn, mp["wv"].astype(jnp.float32))
            gates = jnp.einsum("bd,dhg->bhg", xn, mp["wif"].astype(jnp.float32))
            (mC, mn, mm), hm = self._mlstm_cell((mC, mn, mm), q, k, v, gates[..., 0], gates[..., 1])
            gate = jax.nn.silu(jnp.einsum("bd,de->be", xn, mp["wgate"].astype(jnp.float32)))
            out = jnp.einsum("bhk,hkd->bd", hm, mp["wout"].astype(jnp.float32)) * gate
            h = h + out.astype(h.dtype)
            # --- sLSTM block ------------------------------------------ #
            xn = apply_norm(h, sp["norm"], cfg.norm_kind, cfg.norm_eps).astype(jnp.float32)
            hprev = sh  # (B,H,hd) recurrent input
            def pre(w, r):
                return jnp.einsum("bd,dhk->bhk", xn, w.astype(jnp.float32)) + jnp.einsum(
                    "bhk,hkj->bhj", hprev, r.astype(jnp.float32)
                )
            (sc, sn, sh, sm), hs = self._slstm_cell(
                (sc, sn, sh, sm),
                pre(sp["wz"], sp["rz"]),
                pre(sp["wi"], sp["ri"]),
                pre(sp["wf"], sp["rf"]),
                pre(sp["wo"], sp["ro"]),
            )
            out = jnp.einsum("bhk,hkd->bd", hs, sp["wout"].astype(jnp.float32))
            h = h + out.astype(h.dtype)
            return h, (mC, mn, mm, sc, sn, sh, sm)

        h, new_states = jax.lax.scan(
            pair_body,
            x,
            (
                params["pairs"]["mlstm"],
                params["pairs"]["slstm"],
                cache["m_C"], cache["m_n"], cache["m_m"],
                cache["s_c"], cache["s_n"], cache["s_h"], cache["s_m"],
            ),
        )
        new_cache = {
            "m_C": new_states[0], "m_n": new_states[1], "m_m": new_states[2],
            "s_c": new_states[3], "s_n": new_states[4], "s_h": new_states[5],
            "s_m": new_states[6],
            "length": cache["length"] + 1,
        }
        return new_cache, h

    # ------------------------------------------------------------------ #
    # Public API (mirrors TransformerLM)                                  #
    # ------------------------------------------------------------------ #
    def forward(
        self, params: Params, tokens: jax.Array,
        patch_embeds: Optional[jax.Array] = None, remat: bool = True,
        time_chunk: int = 64,
    ) -> Tuple[jax.Array, jax.Array]:
        """Training forward.

        Memory note: a flat time scan would store the full recurrent state at
        *every* step for the backward pass (states × seq_len — terabytes at
        4k × 256). We scan over time *chunks* and rematerialize inside each
        chunk, so the stored carries are states × (seq/chunk) and the
        backward recomputes one chunk at a time (standard BPTT
        checkpointing; chunk ≈ √seq balances storage vs recompute).
        """
        cfg = self.cfg
        b, s = tokens.shape
        emb = embed_tokens(tokens, params["embed"]).astype(self.dtype)  # (B,S,D)
        cache0 = self.cache_init(b)

        chunk = min(time_chunk, s)
        if s % chunk != 0:
            chunk = s  # fall back to one chunk
        n_chunks = s // chunk
        emb_t = jnp.swapaxes(emb, 0, 1).reshape(n_chunks, chunk, b, cfg.d_model)

        def chunk_body(cache, x_chunk):
            def t_body(c, x_t):
                c, y = self._token_step(params, c, x_t)
                return c, y

            cache, ys = jax.lax.scan(t_body, cache, x_chunk)
            return cache, ys

        if remat:
            chunk_body = jax.checkpoint(
                chunk_body, policy=jax.checkpoint_policies.nothing_saveable
            )
        _, ys = jax.lax.scan(chunk_body, cache0, emb_t)       # (n_chunks, chunk, B, D)
        h = jnp.swapaxes(ys.reshape(s, b, cfg.d_model), 0, 1)  # (B,S,D)
        h = apply_norm(h, params["norm_final"], cfg.norm_kind, cfg.norm_eps)
        logits = unembed(h, params["embed"])
        return logits.astype(jnp.float32), jnp.zeros((), jnp.float32)

    def loss(self, params: Params, batch: Dict[str, jax.Array], remat: bool = True) -> jax.Array:
        logits, _ = self.forward(params, batch["tokens"], remat=remat)
        return cross_entropy_loss(logits, batch["labels"], batch.get("mask"))

    def prefill(
        self, params: Params, tokens: jax.Array, cache: Dict[str, jax.Array],
        patch_embeds: Optional[jax.Array] = None,
        lengths: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Ragged prompts: per-slot state updates freeze once t ≥ lengths[b]
        (right-padding never touches a slot's recurrent state), and logits
        are taken at each slot's last real token."""
        cfg = self.cfg
        b, s = tokens.shape
        emb = embed_tokens(tokens, params["embed"]).astype(self.dtype)
        len_vec = (
            jnp.full((b,), s, jnp.int32) if lengths is None
            else lengths.astype(jnp.int32)
        )

        def time_body(carry, xs):
            c, h_keep = carry
            x_t, t = xs
            c_new, y = self._token_step(params, c, x_t)
            live = t < len_vec                                     # (B,)

            def freeze(new, old):
                if new.ndim == 0 or new.shape[0] != c["m_C"].shape[0]:
                    return new  # "length" counter etc.
                mask = live.reshape((1, b) + (1,) * (new.ndim - 2))
                return jnp.where(mask, new, old)

            c_out = {
                k: (freeze(c_new[k], c[k]) if k != "length" else c_new[k])
                for k in c_new
            }
            is_last = (t == len_vec - 1)[:, None]
            h_keep = jnp.where(is_last, y, h_keep)
            return (c_out, h_keep), None

        h0 = jnp.zeros((b, cfg.d_model), self.dtype)
        (cache, h_last), _ = jax.lax.scan(
            time_body, (cache, h0),
            (jnp.swapaxes(emb, 0, 1), jnp.arange(s, dtype=jnp.int32)),
        )
        cache = dict(cache)
        cache["length"] = jnp.zeros((b,), jnp.int32) + len_vec
        h_last = apply_norm(h_last, params["norm_final"], cfg.norm_kind, cfg.norm_eps)
        logits = unembed(h_last, params["embed"]).astype(jnp.float32)
        return logits, cache

    def decode_step(
        self, params: Params, tokens: jax.Array, cache: Dict[str, jax.Array],
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        x = embed_tokens(tokens[:, None], params["embed"])[:, 0, :].astype(self.dtype)
        cache, h = self._token_step(params, cache, x)
        h = apply_norm(h, params["norm_final"], cfg.norm_kind, cfg.norm_eps)
        logits = unembed(h, params["embed"]).astype(jnp.float32)
        return logits, cache
