"""Whisper (arXiv:2212.04356) — encoder-decoder backbone, conv frontend stub.

The audio frontend (log-mel + 2×conv) is a STUB per the brief: inputs are
precomputed frame embeddings (B, S_enc, d_model). The backbone is faithful:
  * encoder: bidirectional self-attention + GELU MLP, LayerNorm w/ bias
  * decoder: causal self-attention + cross-attention + GELU MLP
  * tied embedding / unembedding (whisper ties them)

Adaptations (DESIGN.md): sinusoidal positions on both stacks (whisper's
decoder uses a learned table capped at 448 positions; the assigned
``decode_32k`` cell needs arbitrary positions, so we use the sinusoid
everywhere — a positional-encoding detail, not a structural one).

Serving semantics: "prefill" = encoder pass + cross-KV build + decoder
prompt prefill; "decode" = one decoder token (self-KV append, cross-KV
reused). The paper's scheduler treats encoder+prompt work as the prefill
phase cost N_i^p — see DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import attention, attention_any, attention_cross
from .cache import full_cache_init, full_cache_shape, full_cache_write, full_cache_write_token
from .layers import (
    ParamDef,
    apply_norm,
    cross_entropy_loss,
    embed_defs,
    embed_tokens,
    mlp_apply,
    mlp_defs,
    norm_defs,
    sinusoidal_positions,
    unembed,
)

Params = Dict[str, Any]


class Whisper:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        if not cfg.is_encoder_decoder or cfg.encoder_layers <= 0:
            raise ValueError("Whisper requires is_encoder_decoder and encoder_layers")
        self.hd = cfg.resolved_head_dim
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    # ------------------------------------------------------------------ #
    def _attn_defs(self, n: int, kv_heads: int) -> Params:
        cfg, dt, hd = self.cfg, self.dtype, self.hd
        d = cfg.d_model
        return {
            "wq": ParamDef((n, d, cfg.n_heads, hd), ("layers", "embed", "heads", "head_dim"), dt),
            "wk": ParamDef((n, d, kv_heads, hd), ("layers", "embed", "kv_heads", "head_dim"), dt),
            "wv": ParamDef((n, d, kv_heads, hd), ("layers", "embed", "kv_heads", "head_dim"), dt),
            "wo": ParamDef((n, cfg.n_heads, hd, d), ("layers", "heads", "head_dim", "embed"), dt),
            "bq": ParamDef((n, cfg.n_heads, hd), ("layers", "heads", "head_dim"), dt, "zeros"),
            "bv": ParamDef((n, kv_heads, hd), ("layers", "kv_heads", "head_dim"), dt, "zeros"),
            "bo": ParamDef((n, d), ("layers", "embed"), dt, "zeros"),
        }

    def param_defs(self) -> Params:
        cfg, dt = self.cfg, self.dtype
        d, ne, nd = cfg.d_model, cfg.encoder_layers, cfg.n_layers
        enc = {
            "norm_attn": norm_defs(d, "layernorm", dt, layers=ne),
            "attn": self._attn_defs(ne, cfg.n_kv_heads),
            "norm_mlp": norm_defs(d, "layernorm", dt, layers=ne),
            "mlp": mlp_defs(d, cfg.d_ff, "gelu", dt, layers=ne, use_bias=True),
        }
        dec = {
            "norm_self": norm_defs(d, "layernorm", dt, layers=nd),
            "self_attn": self._attn_defs(nd, cfg.n_kv_heads),
            "norm_cross": norm_defs(d, "layernorm", dt, layers=nd),
            "cross_attn": self._attn_defs(nd, cfg.n_kv_heads),
            "norm_mlp": norm_defs(d, "layernorm", dt, layers=nd),
            "mlp": mlp_defs(d, cfg.d_ff, "gelu", dt, layers=nd, use_bias=True),
        }
        return {
            "embed": embed_defs(cfg.vocab_size, d, dt, tie=True),
            "encoder": enc,
            "decoder": dec,
            "norm_enc_final": norm_defs(d, "layernorm", dt),
            "norm_dec_final": norm_defs(d, "layernorm", dt),
        }

    # ------------------------------------------------------------------ #
    def _mha(self, x_q, x_kv, lp, *, causal, q_positions=None, k_positions=None):
        q = jnp.einsum("bsd,dhk->bshk", x_q, lp["wq"]) + lp["bq"]
        k = jnp.einsum("bsd,dhk->bshk", x_kv, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x_kv, lp["wv"]) + lp["bv"]
        b = q.shape[0]
        if q_positions is None:
            q_positions = jnp.broadcast_to(
                jnp.arange(q.shape[1], dtype=jnp.int32)[None], (b, q.shape[1])
            )
        if k_positions is None:
            k_positions = jnp.broadcast_to(
                jnp.arange(k.shape[1], dtype=jnp.int32)[None], (b, k.shape[1])
            )
        out = attention_any(
            q, k, v, q_positions=q_positions, k_positions=k_positions, causal=causal
        )
        return jnp.einsum("bshk,hkd->bsd", out, lp["wo"]) + lp["bo"], (k, v)

    # ------------------------------------------------------------------ #
    def encode(self, params: Params, frames: jax.Array, remat: bool = False) -> jax.Array:
        """frames: (B, S_enc, D) stub embeddings → encoder states."""
        cfg = self.cfg
        b, s, d = frames.shape
        pos = sinusoidal_positions(s, d).astype(self.dtype)
        h = frames.astype(self.dtype) + pos[None]

        def body(h, lp):
            x = apply_norm(h, lp["norm_attn"], "layernorm", cfg.norm_eps)
            out, _ = self._mha(x, x, lp["attn"], causal=False)
            h = h + out
            x = apply_norm(h, lp["norm_mlp"], "layernorm", cfg.norm_eps)
            h = h + mlp_apply(x, lp["mlp"], "gelu")
            return h, None

        if remat:
            # without this, backward stores every chunked-attention residual
            # of every encoder layer — hundreds of GB at 4k×256
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        h, _ = jax.lax.scan(body, h, params["encoder"])
        return apply_norm(h, params["norm_enc_final"], "layernorm", cfg.norm_eps)

    def _decoder_full(self, params, tokens, enc_states, remat: bool):
        cfg = self.cfg
        b, s = tokens.shape
        d = cfg.d_model
        h = embed_tokens(tokens, params["embed"]).astype(self.dtype)
        h = h + sinusoidal_positions(s, d).astype(self.dtype)[None]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        def body(h, lp):
            x = apply_norm(h, lp["norm_self"], "layernorm", cfg.norm_eps)
            out, _ = self._mha(
                x, x, lp["self_attn"], causal=True,
                q_positions=positions, k_positions=positions,
            )
            h = h + out
            x = apply_norm(h, lp["norm_cross"], "layernorm", cfg.norm_eps)
            out, _ = self._mha(x, enc_states, lp["cross_attn"], causal=False)
            h = h + out
            x = apply_norm(h, lp["norm_mlp"], "layernorm", cfg.norm_eps)
            h = h + mlp_apply(x, lp["mlp"], "gelu")
            return h, None

        if remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        h, _ = jax.lax.scan(body, h, params["decoder"])
        return apply_norm(h, params["norm_dec_final"], "layernorm", cfg.norm_eps)

    # ------------------------------------------------------------------ #
    def forward(self, params, batch_or_tokens, patch_embeds=None, remat: bool = True):
        """Training forward. Accepts {'frames','tokens'} dict or tokens with
        ``patch_embeds`` doubling as frames (uniform smoke-test interface)."""
        if isinstance(batch_or_tokens, dict):
            frames = batch_or_tokens["frames"]
            tokens = batch_or_tokens["tokens"]
        else:
            tokens = batch_or_tokens
            frames = patch_embeds
        enc = self.encode(params, frames, remat=remat)
        h = self._decoder_full(params, tokens, enc, remat)
        logits = unembed(h, params["embed"])
        return logits.astype(jnp.float32), jnp.zeros((), jnp.float32)

    def loss(self, params, batch, remat: bool = True):
        logits, _ = self.forward(params, batch, remat=remat)
        return cross_entropy_loss(logits, batch["labels"], batch.get("mask"))

    # ------------------------------------------------------------------ #
    # Serving                                                             #
    # ------------------------------------------------------------------ #
    def cache_shape(self, batch: int, max_len: int, enc_len: int = 1500):
        cfg = self.cfg
        self_c = full_cache_shape(cfg.n_layers, batch, max_len, cfg.n_kv_heads, self.hd, self.dtype)
        f = jax.ShapeDtypeStruct
        return {
            "self": self_c,
            "cross_k": f((cfg.n_layers, batch, enc_len, cfg.n_kv_heads, self.hd), self.dtype),
            "cross_v": f((cfg.n_layers, batch, enc_len, cfg.n_kv_heads, self.hd), self.dtype),
        }

    def cache_init(self, batch: int, max_len: int, enc_len: int = 1500):
        cfg = self.cfg
        return {
            "self": full_cache_init(cfg.n_layers, batch, max_len, cfg.n_kv_heads, self.hd, self.dtype),
            "cross_k": jnp.zeros((cfg.n_layers, batch, enc_len, cfg.n_kv_heads, self.hd), self.dtype),
            "cross_v": jnp.zeros((cfg.n_layers, batch, enc_len, cfg.n_kv_heads, self.hd), self.dtype),
        }

    def prefill(self, params, tokens, cache, patch_embeds=None):
        """Encoder pass + decoder prompt prefill. ``patch_embeds`` carries the
        stub frame embeddings (B, S_enc, D)."""
        cfg = self.cfg
        frames = patch_embeds
        if frames is None:
            raise ValueError("whisper prefill needs frame embeddings")
        enc = self.encode(params, frames)
        b, s = tokens.shape
        d = cfg.d_model
        h = embed_tokens(tokens, params["embed"]).astype(self.dtype)
        h = h + sinusoidal_positions(s, d).astype(self.dtype)[None]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        def body(h, xs):
            lp, kc, vc = xs
            x = apply_norm(h, lp["norm_self"], "layernorm", cfg.norm_eps)
            out, (k_new, v_new) = self._mha(
                x, x, lp["self_attn"], causal=True,
                q_positions=positions, k_positions=positions,
            )
            h = h + out
            kc, vc = full_cache_write(kc, vc, k_new, v_new, jnp.int32(0))
            x = apply_norm(h, lp["norm_cross"], "layernorm", cfg.norm_eps)
            out, (ck, cv) = self._mha(x, enc, lp["cross_attn"], causal=False)
            h = h + out
            x = apply_norm(h, lp["norm_mlp"], "layernorm", cfg.norm_eps)
            h = h + mlp_apply(x, lp["mlp"], "gelu")
            return h, (kc, vc, ck, cv)

        h, (k_all, v_all, ck_all, cv_all) = jax.lax.scan(
            body, h, (params["decoder"], cache["self"]["k"], cache["self"]["v"])
        )
        h = apply_norm(h, params["norm_dec_final"], "layernorm", cfg.norm_eps)
        logits = unembed(h[:, -1, :], params["embed"]).astype(jnp.float32)
        new_cache = {
            "self": {"k": k_all, "v": v_all,
                     "length": jnp.full((b,), s, jnp.int32)},
            "cross_k": ck_all,
            "cross_v": cv_all,
        }
        return logits, new_cache

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        b = tokens.shape[0]
        lengths = cache["self"]["length"]              # (B,)
        d = cfg.d_model
        h = embed_tokens(tokens[:, None], params["embed"]).astype(self.dtype)
        # sinusoid at each slot's (traced) position, via the closed form
        posf = lengths.astype(jnp.float32)[:, None]    # (B, 1)
        dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
        angle = posf / jnp.power(10000.0, 2 * dim / d)
        pos_emb = jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1).astype(self.dtype)
        h = h + pos_emb[:, None, :]
        positions = lengths[:, None].astype(jnp.int32)
        max_len = cache["self"]["k"].shape[2]
        idx = jnp.arange(max_len, dtype=jnp.int32)
        k_pos_now = jnp.where(idx[None, :] <= lengths[:, None], idx[None, :], -1)

        def body(h, xs):
            lp, kc, vc, ck, cv = xs
            x = apply_norm(h, lp["norm_self"], "layernorm", cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", x, lp["self_attn"]["wq"]) + lp["self_attn"]["bq"]
            k = jnp.einsum("bsd,dhk->bshk", x, lp["self_attn"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", x, lp["self_attn"]["wv"]) + lp["self_attn"]["bv"]
            kc, vc = full_cache_write_token(kc, vc, k, v, lengths)
            out = attention(
                q, kc, vc, q_positions=positions, k_positions=k_pos_now, causal=True
            )
            out = jnp.einsum("bshk,hkd->bsd", out, lp["self_attn"]["wo"]) + lp["self_attn"]["bo"]
            h = h + out
            x = apply_norm(h, lp["norm_cross"], "layernorm", cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", x, lp["cross_attn"]["wq"]) + lp["cross_attn"]["bq"]
            out = attention_cross(q, ck, cv)
            out = jnp.einsum("bshk,hkd->bsd", out, lp["cross_attn"]["wo"]) + lp["cross_attn"]["bo"]
            h = h + out
            x = apply_norm(h, lp["norm_mlp"], "layernorm", cfg.norm_eps)
            h = h + mlp_apply(x, lp["mlp"], "gelu")
            return h, (kc, vc)

        h, (k_all, v_all) = jax.lax.scan(
            body,
            h,
            (
                params["decoder"],
                cache["self"]["k"], cache["self"]["v"],
                cache["cross_k"], cache["cross_v"],
            ),
        )
        h = apply_norm(h, params["norm_dec_final"], "layernorm", cfg.norm_eps)
        logits = unembed(h[:, 0, :], params["embed"]).astype(jnp.float32)
        new_cache = {
            "self": {"k": k_all, "v": v_all, "length": lengths + 1},
            "cross_k": cache["cross_k"],
            "cross_v": cache["cross_v"],
        }
        return logits, new_cache
