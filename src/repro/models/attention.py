"""Reference (pure-jnp) attention with GQA, causal, sliding-window and
cross-attention masks.

This is the path the dry-run lowers (einsum attention partitions cleanly
under GSPMD). The Pallas kernels in ``repro.kernels`` implement the same
contracts for TPU execution and are validated against these functions.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def attention(
    q: jax.Array,                     # (B, Sq, H, D)
    k: jax.Array,                     # (B, Sk, KV, D)
    v: jax.Array,                     # (B, Sk, KV, D)
    *,
    q_positions: jax.Array,           # (B, Sq) int32
    k_positions: jax.Array,           # (B, Sk) int32; -1 = invalid slot
    causal: bool = True,
    window: int = 0,                  # 0 = unbounded
    scale: Optional[float] = None,
) -> jax.Array:
    """Masked multi-head attention with grouped KV heads.

    Masking is position-based so the same code serves packed prefill,
    ring-buffer (sliding-window) decode and full-cache decode:
      * invalid:   k_pos < 0
      * causal:    k_pos > q_pos
      * window:    q_pos - k_pos >= window (when window > 0)
    """
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    if h % kv != 0:
        raise ValueError(f"q heads {h} not divisible by kv heads {kv}")
    g = h // kv
    scale = scale if scale is not None else d ** -0.5

    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, kv, g, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # scores: (B, KV, G, Sq, Sk)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf)

    qpos = q_positions[:, None, None, :, None]          # (B,1,1,Sq,1)
    kpos = k_positions[:, None, None, None, :]          # (B,1,1,1,Sk)
    mask = kpos >= 0
    if causal:
        mask = jnp.logical_and(mask, kpos <= qpos)
    if window > 0:
        mask = jnp.logical_and(mask, qpos - kpos < window)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, vf)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def chunked_attention(
    q: jax.Array,                     # (B, Sq, H, D)
    k: jax.Array,                     # (B, Sk, KV, D)
    v: jax.Array,                     # (B, Sk, KV, D)
    *,
    q_positions: jax.Array,           # (B, Sq) int32
    k_positions: jax.Array,           # (B, Sk) int32; -1 = invalid
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    q_chunk: int = 512,
    k_chunk: int = 1024,
) -> jax.Array:
    """Flash-style attention in pure jnp: nested scans over query/key blocks
    with a running (max, sum, acc) online softmax. Peak memory is
    O(q_chunk · k_chunk) scores instead of O(Sq · Sk) — required for the 32k+
    prefill cells. Semantics identical to ``attention``.

    Note: every (q-block, k-block) pair is computed and masked; causal
    block-skipping needs data-dependent trip counts, which is exactly what
    the Pallas kernel (``repro.kernels.flash_attention``) provides on TPU.
    The ~2× causal FLOP overcount of this reference path is visible in the
    roofline's MODEL_FLOPS/HLO_FLOPS ratio and addressed in §Perf.
    """
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    scale = scale if scale is not None else d ** -0.5
    qc = min(q_chunk, sq)
    kc = min(k_chunk, sk)
    if sq % qc != 0 or sk % kc != 0:
        raise ValueError(f"seq lens ({sq},{sk}) not divisible by chunks ({qc},{kc})")
    nq, nk = sq // qc, sk // kc

    from ..distributed.sharding import constrain_batch_dim

    # keep q/k/v in their native dtype; tiles are cast to f32 inside the
    # block bodies (a full-array f32 copy costs GBs/device at 32k).
    # K/V are constrained to batch-only sharding HERE, outside the scan:
    # a seq- or head-sharded K consumed inside the q-chunk loop makes GSPMD
    # re-all-gather it per chunk (64× per layer at 32k — §Perf H2).
    qf = q.reshape(b, nq, qc, kv, g, d)
    kf = constrain_batch_dim(k, 0).reshape(b, nk, kc, kv, d)
    vf = constrain_batch_dim(v, 0).reshape(b, nk, kc, kv, d)
    qp = q_positions.reshape(b, nq, qc)
    kp = k_positions.reshape(b, nk, kc)

    # scan over q blocks (outer), k blocks (inner); each q block is a
    # rematerialization unit — its k-scan residuals (the exp'd score tiles)
    # are recomputed in its own backward window instead of being stored for
    # every (q, k) block pair at once (the flash-attention backward
    # structure; without this, training at 32k seq stores O(nq·nk) score
    # tiles and blows tens of GB per device).
    def q_body(_, qx):
        q_blk, qpos = qx                       # (B,qc,KV,G,D), (B,qc)

        def k_body(carry, kx):
            acc, m, l = carry
            k_blk, v_blk, kpos = kx            # (B,kc,KV,D), ..., (B,kc)
            s = jnp.einsum(
                "bqkgd,bskd->bkgqs",
                q_blk.astype(jnp.float32) * scale,
                k_blk.astype(jnp.float32),
            )  # (B,KV,G,qc,kc)
            qq = qpos[:, None, None, :, None]
            kk = kpos[:, None, None, None, :]
            mask = kk >= 0
            if causal:
                mask = jnp.logical_and(mask, kk <= qq)
            if window > 0:
                mask = jnp.logical_and(mask, qq - kk < window)
            s = jnp.where(mask, s, NEG_INF)
            m_blk = jnp.max(s, axis=-1)                         # (B,KV,G,qc)
            m_new = jnp.maximum(m, m_blk)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, v_blk.astype(jnp.float32)
            )
            return (acc, m_new, l), None

        init = (
            jnp.zeros((b, kv, g, qc, d), jnp.float32),
            jnp.full((b, kv, g, qc), -jnp.inf, jnp.float32),
            jnp.zeros((b, kv, g, qc), jnp.float32),
        )
        (acc, m, l), _ = jax.lax.scan(
            k_body, init, (jnp.swapaxes(kf, 0, 1), jnp.swapaxes(vf, 0, 1), jnp.swapaxes(kp, 0, 1))
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]            # (B,KV,G,qc,D)
        return None, out

    q_blocks = jnp.swapaxes(qf, 0, 1)                            # (nq,B,qc,KV,G,D)
    q_body_r = jax.checkpoint(
        q_body, policy=jax.checkpoint_policies.nothing_saveable
    )
    _, outs = jax.lax.scan(q_body_r, None, (q_blocks, jnp.swapaxes(qp, 0, 1)))
    # outs: (nq, B, KV, G, qc, D) → (B, Sq, H, D)
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 2, 3, 1, 4, 5)   # (B,KV,G,nq,qc,D)
    out = out.reshape(b, kv, g, sq, d).transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)
    return out.astype(q.dtype)


def banded_attention(
    q: jax.Array,                     # (B, Sq, H, D)
    k: jax.Array,                     # (B, Sk, KV, D)
    v: jax.Array,
    *,
    q_positions: jax.Array,
    k_positions: jax.Array,
    window: int,
    causal: bool = True,
    scale: Optional[float] = None,
    q_chunk: int = 512,
) -> jax.Array:
    """Sliding-window attention with a *static* key band per query block.

    For window w and query chunk qc, query block i only needs keys in
    [(i+1)·qc − (w+qc), (i+1)·qc) — a fixed-width band sliced with
    ``dynamic_slice`` (start is traced, width static). FLOPs are
    O(Sq · (w + qc)) instead of the O(Sq · Sk) a masked full computation
    would burn — this is the TPU-friendly SWA prefill structure.
    """
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    scale = scale if scale is not None else d ** -0.5
    qc = min(q_chunk, sq)
    if sq % qc != 0:
        raise ValueError(f"sq={sq} not divisible by q_chunk={qc}")
    nq = sq // qc
    band = window + qc
    if band >= sk:
        return chunked_attention(
            q, k, v, q_positions=q_positions, k_positions=k_positions,
            causal=causal, window=window, scale=scale, q_chunk=q_chunk,
        )

    # NOTE: unlike chunked_attention, K/V are NOT gathered here — the band
    # dynamic_slice pulls only O(w + qc) keys per q block, so leaving K/V
    # seq-sharded moves ~band/S of the bytes per chunk (measured 1.6× better
    # than a hoisted full gather for mixtral prefill_32k; §Perf H2).
    qf = q.reshape(b, nq, qc, kv, g, d)
    kf = k
    vf = v

    def q_body(_, qx):
        q_blk, qpos, i = qx               # (B,qc,KV,G,D), (B,qc), scalar
        start = jnp.clip((i + 1) * qc - band, 0, sk - band)
        k_band = jax.lax.dynamic_slice(kf, (0, start, 0, 0), (b, band, kv, d))
        v_band = jax.lax.dynamic_slice(vf, (0, start, 0, 0), (b, band, kv, d))
        kp_band = jax.lax.dynamic_slice(k_positions, (0, start), (b, band))
        s = jnp.einsum(
            "bqkgd,bskd->bkgqs",
            q_blk.astype(jnp.float32) * scale,
            k_band.astype(jnp.float32),
        )
        qq = qpos[:, None, None, :, None]
        kk = kp_band[:, None, None, None, :]
        mask = kk >= 0
        if causal:
            mask = jnp.logical_and(mask, kk <= qq)
        mask = jnp.logical_and(mask, qq - kk < window)
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bkgqd", p, v_band.astype(jnp.float32))
        return None, out

    q_body_r = jax.checkpoint(
        q_body, policy=jax.checkpoint_policies.nothing_saveable
    )
    _, outs = jax.lax.scan(
        q_body_r,
        None,
        (
            jnp.swapaxes(qf, 0, 1),
            jnp.swapaxes(q_positions.reshape(b, nq, qc), 0, 1),
            jnp.arange(nq),
        ),
    )
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 2, 3, 1, 4, 5)
    out = out.reshape(b, kv, g, sq, d).transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)
    return out.astype(q.dtype)


def attention_any(
    q, k, v, *, q_positions, k_positions, causal=True, window=0,
    scale=None, dense_max_seq: int = 2048, q_chunk: int = 512,
) -> jax.Array:
    """Dispatch: dense for short K; banded for long sliding-window; chunked
    (flash-style) otherwise."""
    sk = k.shape[1]
    if sk <= dense_max_seq:
        return attention(
            q, k, v, q_positions=q_positions, k_positions=k_positions,
            causal=causal, window=window, scale=scale,
        )
    if window > 0 and window + q_chunk < sk:
        return banded_attention(
            q, k, v, q_positions=q_positions, k_positions=k_positions,
            window=window, causal=causal, scale=scale, q_chunk=q_chunk,
        )
    return chunked_attention(
        q, k, v, q_positions=q_positions, k_positions=k_positions,
        causal=causal, window=window, scale=scale, q_chunk=q_chunk,
    )


def attention_paged(
    q: jax.Array,                     # (B, Sq, H, D)
    k_pages: jax.Array,               # (KV, P, bs, D) — one layer's page pool
    v_pages: jax.Array,
    block_tables: jax.Array,          # (B, MB) int32; -1 = unallocated
    *,
    q_positions: jax.Array,           # (B, Sq) int32
    valid_lengths: jax.Array,         # (B,) int32 — valid tokens per slot,
                                      # counted *after* this step's KV writes
    causal: bool = True,
    scale: Optional[float] = None,
) -> jax.Array:
    """Attention against a paged KV cache (reference path).

    Gathers each slot's pages into its logical (MB·bs) sequence and masks
    unallocated/past-length positions via the shared position-based scheme —
    the same contract ``kernels.paged_decode_attention`` implements with
    block-table-indirected DMA on TPU. Serves both chunked prefill (Sq =
    chunk) and decode (Sq = 1) behind the paged cache-layout flag."""
    from .cache import paged_gather_kv, paged_key_positions

    k_ctx, v_ctx = paged_gather_kv(k_pages, v_pages, block_tables)
    k_positions = paged_key_positions(
        block_tables, valid_lengths, k_pages.shape[2]
    )
    return attention(
        q, k_ctx, v_ctx,
        q_positions=q_positions,
        k_positions=k_positions,
        causal=causal,
        scale=scale,
    )


def attention_cross(
    q: jax.Array,                     # (B, Sq, H, D)
    k: jax.Array,                     # (B, Sk, KV, D)
    v: jax.Array,                     # (B, Sk, KV, D)
    k_valid: Optional[jax.Array] = None,   # (B, Sk) bool
    scale: Optional[float] = None,
) -> jax.Array:
    """Bidirectional / cross attention (whisper encoder & cross blocks)."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    scale = scale if scale is not None else d ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, kv, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    if k_valid is not None:
        scores = jnp.where(k_valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)
