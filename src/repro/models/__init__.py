from .layers import ParamDef, init_params, abstract_params, logical_specs
from .registry import get_model, MODEL_FAMILIES
