"""Decoder-only transformer LM — the workhorse for 7 of the 10 assigned
architectures (dense, MoE, VLM-backbone variants).

Design notes:
  * Layers are scan-stacked: one set of block weights with a leading
    "layers" dim, iterated with ``jax.lax.scan``. This keeps the lowered HLO
    O(1) in depth — essential for compiling 512-device dry-runs of 56-layer
    models on one CPU core, and it is how production JAX LMs ship anyway.
  * Blocks are optionally rematerialized (``jax.checkpoint``) for training.
  * Attention is the pure-jnp reference (``models.attention``); the Pallas
    kernels implement the same contract for TPU execution.
  * Serving splits into ``prefill`` (writes the KV cache, returns last-token
    logits) and ``decode_step`` (one token per active slot). Sliding-window
    configs use a ring cache of size W instead of the full context.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distributed.sharding import constrain_kv_for_cache, constrain_residual
from .attention import attention, attention_any, attention_paged
from .cache import (
    full_cache_init,
    full_cache_shape,
    full_cache_write,
    full_cache_write_token,
    paged_cache_init,
    paged_cache_shape,
    paged_cache_write,
    paged_cache_write_token,
    ring_cache_init,
    ring_cache_shape,
    ring_cache_write_prefill,
    ring_cache_write_token,
    ring_positions_prefill,
    ring_positions_write_token,
)
from .layers import (
    ParamDef,
    apply_m_rope,
    apply_norm,
    apply_rope,
    cross_entropy_loss,
    embed_defs,
    embed_tokens,
    mlp_apply,
    mlp_defs,
    moe_aux_weight,
    norm_defs,
    rms_norm,
    unembed,
)
from .moe import moe_apply, moe_defs

Params = Dict[str, Any]


class TransformerLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.hd = cfg.resolved_head_dim
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    # ------------------------------------------------------------------ #
    # Parameters                                                          #
    # ------------------------------------------------------------------ #
    def param_defs(self) -> Params:
        cfg, hd, dt = self.cfg, self.hd, self.dtype
        L = cfg.n_layers
        block: Params = {
            "norm_attn": norm_defs(cfg.d_model, cfg.norm_kind, dt, layers=L),
            "norm_mlp": norm_defs(cfg.d_model, cfg.norm_kind, dt, layers=L),
            "wq": ParamDef((L, cfg.d_model, cfg.n_heads, hd), ("layers", "embed", "heads", "head_dim"), dt),
            "wk": ParamDef((L, cfg.d_model, cfg.n_kv_heads, hd), ("layers", "embed", "kv_heads", "head_dim"), dt),
            "wv": ParamDef((L, cfg.d_model, cfg.n_kv_heads, hd), ("layers", "embed", "kv_heads", "head_dim"), dt),
            "wo": ParamDef((L, cfg.n_heads, hd, cfg.d_model), ("layers", "heads", "head_dim", "embed"), dt),
        }
        if cfg.use_bias:
            block["bq"] = ParamDef((L, cfg.n_heads, hd), ("layers", "heads", "head_dim"), dt, "zeros")
            block["bk"] = ParamDef((L, cfg.n_kv_heads, hd), ("layers", "kv_heads", "head_dim"), dt, "zeros")
            block["bv"] = ParamDef((L, cfg.n_kv_heads, hd), ("layers", "kv_heads", "head_dim"), dt, "zeros")
            block["bo"] = ParamDef((L, cfg.d_model), ("layers", "embed"), dt, "zeros")
        if cfg.qk_norm:
            block["q_norm"] = ParamDef((L, hd), ("layers", "head_dim"), dt, "ones")
            block["k_norm"] = ParamDef((L, hd), ("layers", "head_dim"), dt, "ones")
        if cfg.is_moe:
            block["moe"] = moe_defs(L, cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.mlp_kind, dt)
        else:
            block["mlp"] = mlp_defs(cfg.d_model, cfg.d_ff, cfg.mlp_kind, dt, layers=L, use_bias=cfg.use_bias)
        return {
            "embed": embed_defs(cfg.vocab_size, cfg.d_model, dt, tie=cfg.tie_embeddings),
            "blocks": block,
            "norm_final": norm_defs(cfg.d_model, cfg.norm_kind, dt),
        }

    # ------------------------------------------------------------------ #
    # One transformer block (full-sequence form)                          #
    # ------------------------------------------------------------------ #
    def _block_full(
        self,
        h: jax.Array,                     # (B, S, D)
        lp: Params,                       # one layer's params (scan slice)
        positions: jax.Array,             # (B, S) or (B, S, 3)
        k_positions: jax.Array,           # (B, S)
    ) -> Tuple[jax.Array, jax.Array, Tuple[jax.Array, jax.Array]]:
        cfg = self.cfg
        x = apply_norm(h, lp["norm_attn"], cfg.norm_kind, cfg.norm_eps)
        q, k, v = self._qkv_block(x, lp)
        if cfg.m_rope:
            q = apply_m_rope(q, positions, cfg.m_rope_sections, cfg.rope_theta)
            k = apply_m_rope(k, positions, cfg.m_rope_sections, cfg.rope_theta)
            qpos = positions[..., 0]
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            qpos = positions
        attn_out = attention_any(
            q, k, v,
            q_positions=qpos,
            k_positions=k_positions,
            causal=True,
            window=cfg.sliding_window,
        )
        attn_out = jnp.einsum("bshk,hkd->bsd", attn_out, lp["wo"])
        if cfg.use_bias:
            attn_out = attn_out + lp["bo"]
        h = h + attn_out

        x = apply_norm(h, lp["norm_mlp"], cfg.norm_kind, cfg.norm_eps)
        if cfg.is_moe:
            mlp_out, aux = moe_apply(
                x, lp["moe"],
                n_experts=cfg.n_experts,
                top_k=cfg.experts_per_token,
                mlp_kind=cfg.mlp_kind,
                capacity_factor=cfg.moe_capacity_factor,
                group_size=cfg.moe_group_size,
            )
        else:
            mlp_out, aux = mlp_apply(x, lp["mlp"], cfg.mlp_kind), jnp.zeros((), jnp.float32)
        h = h + mlp_out
        return h, aux, (k, v)

    # ------------------------------------------------------------------ #
    # Training / full forward                                             #
    # ------------------------------------------------------------------ #
    def forward(
        self,
        params: Params,
        tokens: jax.Array,                 # (B, S) int32
        patch_embeds: Optional[jax.Array] = None,  # (B, P, D) VLM stub input
        remat: bool = True,
    ) -> Tuple[jax.Array, jax.Array]:
        """Full causal forward → (logits (B,S,V) f32, moe aux loss)."""
        cfg = self.cfg
        b, s = tokens.shape
        h = embed_tokens(tokens, params["embed"]).astype(self.dtype)
        if patch_embeds is not None and cfg.num_patch_tokens > 0:
            p = patch_embeds.shape[1]
            pad = jnp.zeros((b, s - p, cfg.d_model), patch_embeds.dtype)
            merged = jnp.concatenate([patch_embeds, pad], axis=1).astype(self.dtype)
            is_patch = (jnp.arange(s) < p)[None, :, None]
            h = jnp.where(is_patch, merged, h)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        if cfg.m_rope:
            positions = jnp.broadcast_to(positions[..., None], (b, s, 3))
        k_positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        def body(carry, lp):
            h, aux = carry
            h, aux_l, _ = self._block_full(h, lp, positions, k_positions)
            h = constrain_residual(h)
            return (h, aux + aux_l), None

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable
            )
        (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), params["blocks"])
        h = apply_norm(h, params["norm_final"], cfg.norm_kind, cfg.norm_eps)
        logits = unembed(h, params["embed"])
        return logits.astype(jnp.float32), aux

    def loss(
        self,
        params: Params,
        batch: Dict[str, jax.Array],
        remat: bool = True,
    ) -> jax.Array:
        logits, aux = self.forward(
            params, batch["tokens"], batch.get("patch_embeds"), remat=remat
        )
        return cross_entropy_loss(logits, batch["labels"], batch.get("mask")) + (
            moe_aux_weight(self.cfg) * aux
        )

    # ------------------------------------------------------------------ #
    # Serving: cache declaration                                          #
    # ------------------------------------------------------------------ #
    def cache_shape(self, batch: int, max_len: int):
        cfg = self.cfg
        if cfg.sliding_window > 0:
            w = min(cfg.sliding_window, max_len)
            return ring_cache_shape(cfg.n_layers, batch, w, cfg.n_kv_heads, self.hd, self.dtype)
        return full_cache_shape(cfg.n_layers, batch, max_len, cfg.n_kv_heads, self.hd, self.dtype)

    def cache_init(self, batch: int, max_len: int):
        cfg = self.cfg
        if cfg.sliding_window > 0:
            w = min(cfg.sliding_window, max_len)
            return ring_cache_init(cfg.n_layers, batch, w, cfg.n_kv_heads, self.hd, self.dtype)
        return full_cache_init(cfg.n_layers, batch, max_len, cfg.n_kv_heads, self.hd, self.dtype)

    # ------------------------------------------------------------------ #
    # Serving: paged cache (block-table layout; see models.cache)         #
    # ------------------------------------------------------------------ #
    def _check_paged_supported(self) -> None:
        if self.cfg.sliding_window > 0:
            raise NotImplementedError(
                "paged KV cache does not support sliding-window configs "
                "(the ring cache already bounds their KV memory at W)"
            )

    def paged_cache_shape(
        self, num_pages: int, page_size: int, n_slots: int,
        max_pages_per_slot: int,
    ):
        self._check_paged_supported()
        return paged_cache_shape(
            self.cfg.n_layers, num_pages, page_size, self.cfg.n_kv_heads,
            self.hd, n_slots, max_pages_per_slot, self.dtype,
        )

    def paged_cache_init(
        self, num_pages: int, page_size: int, n_slots: int,
        max_pages_per_slot: int,
    ):
        self._check_paged_supported()
        return paged_cache_init(
            self.cfg.n_layers, num_pages, page_size, self.cfg.n_kv_heads,
            self.hd, n_slots, max_pages_per_slot, self.dtype,
        )

    # ------------------------------------------------------------------ #
    # Serving: prefill                                                    #
    # ------------------------------------------------------------------ #
    def prefill(
        self,
        params: Params,
        tokens: jax.Array,                 # (B, S) int32, right-padded
        cache: Dict[str, jax.Array],
        patch_embeds: Optional[jax.Array] = None,
        lengths: Optional[jax.Array] = None,   # (B,) true prompt lengths
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Process the (right-padded) prompts, fill the cache, and return the
        logits at each prompt's last real token. ``lengths`` defaults to the
        full padded width (uniform prefill — the dry-run cells)."""
        cfg = self.cfg
        b, s = tokens.shape
        h = embed_tokens(tokens, params["embed"]).astype(self.dtype)
        if patch_embeds is not None and cfg.num_patch_tokens > 0:
            p = patch_embeds.shape[1]
            pad = jnp.zeros((b, s - p, cfg.d_model), patch_embeds.dtype)
            merged = jnp.concatenate([patch_embeds, pad], axis=1).astype(self.dtype)
            h = jnp.where((jnp.arange(s) < p)[None, :, None], merged, h)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        if cfg.m_rope:
            pos_in = jnp.broadcast_to(positions[..., None], (b, s, 3))
        else:
            pos_in = positions

        ring = cfg.sliding_window > 0
        ring_pos_map = None
        if ring:
            w = cache["k"].shape[2]
            ring_pos_map = ring_positions_prefill(
                b, w, s if lengths is None else lengths.astype(jnp.int32)
            )

        def body(carry, xs):
            h = carry
            lp, kc, vc = xs
            h, _, (k_new, v_new) = self._block_full(h, lp, pos_in, positions)
            h = constrain_residual(h)
            if not ring:
                # full-cache writes must match the cache's CP (seq-sharded)
                # layout; ring caches use a gather-write where the constraint
                # back-fires (measured +60% collectives for mixtral prefill)
                k_new = constrain_kv_for_cache(k_new, cfg.n_kv_heads)
                v_new = constrain_kv_for_cache(v_new, cfg.n_kv_heads)
            if ring:
                kc, vc = ring_cache_write_prefill(kc, vc, k_new, v_new, ring_pos_map)
            else:
                kc, vc = full_cache_write(kc, vc, k_new, v_new, jnp.int32(0))
            return h, (kc, vc)

        h, (k_all, v_all) = jax.lax.scan(
            body, h, (params["blocks"], cache["k"], cache["v"])
        )
        h = apply_norm(h, params["norm_final"], cfg.norm_kind, cfg.norm_eps)
        if lengths is None:
            h_last = h[:, -1, :]
            len_vec = jnp.full((b,), s, jnp.int32)
        else:
            len_vec = lengths.astype(jnp.int32)
            h_last = h[jnp.arange(b), jnp.maximum(len_vec - 1, 0), :]
        logits = unembed(h_last, params["embed"]).astype(jnp.float32)
        new_cache = dict(cache)
        new_cache["k"], new_cache["v"] = k_all, v_all
        new_cache["length"] = len_vec
        if ring:
            new_cache["pos"] = ring_pos_map
        return logits, new_cache

    # ------------------------------------------------------------------ #
    # Serving: chunked prefill into a paged cache                         #
    # ------------------------------------------------------------------ #
    def prefill_chunk(
        self,
        params: Params,
        tokens: jax.Array,                 # (B, C) int32 — one chunk per row
        cache: Dict[str, jax.Array],       # paged cache (the whole pool)
        slot_ids: jax.Array,               # (B,) int32; >= n_slots → pad row
        starts: jax.Array,                 # (B,) int32 — chunk offset in prompt
        chunk_lens: jax.Array,             # (B,) int32 — real tokens (≤ C)
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Process one prompt chunk per row, writing K/V straight into the
        rows' paged blocks (no throwaway cache, no padded full-row scatter).
        Queries attend to everything the slot has accumulated — earlier
        chunks live in the same pages. Returns the logits at each row's last
        real chunk token (only meaningful for a prompt's final chunk) and the
        updated pool."""
        cfg = self.cfg
        self._check_paged_supported()
        b, c = tokens.shape
        n_slots = cache["block_tables"].shape[0]
        h = embed_tokens(tokens, params["embed"]).astype(self.dtype)
        positions = starts[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
        pos_in = (
            jnp.broadcast_to(positions[..., None], (b, c, 3))
            if cfg.m_rope else positions
        )
        tables = cache["block_tables"][jnp.clip(slot_ids, 0, n_slots - 1)]
        new_lens = starts + chunk_lens

        def body(h, xs):
            lp, kc, vc = xs
            x = apply_norm(h, lp["norm_attn"], cfg.norm_kind, cfg.norm_eps)
            q, k, v = self._qkv_block(x, lp)
            if cfg.m_rope:
                q = apply_m_rope(q, pos_in, cfg.m_rope_sections, cfg.rope_theta)
                k = apply_m_rope(k, pos_in, cfg.m_rope_sections, cfg.rope_theta)
            else:
                q = apply_rope(q, positions, cfg.rope_theta)
                k = apply_rope(k, positions, cfg.rope_theta)
            kc, vc = paged_cache_write(kc, vc, k, v, tables, starts, chunk_lens)
            attn_out = attention_paged(
                q, kc, vc, tables,
                q_positions=positions,
                valid_lengths=new_lens,
                causal=True,
            )
            attn_out = jnp.einsum("bshk,hkd->bsd", attn_out, lp["wo"])
            if cfg.use_bias:
                attn_out = attn_out + lp["bo"]
            h = self._mlp_block(h + attn_out, lp)
            return h, (kc, vc)

        h, (k_all, v_all) = jax.lax.scan(
            body, h, (params["blocks"], cache["k"], cache["v"])
        )
        h = apply_norm(h, params["norm_final"], cfg.norm_kind, cfg.norm_eps)
        h_last = h[jnp.arange(b), jnp.maximum(chunk_lens - 1, 0)]
        logits = unembed(h_last, params["embed"]).astype(jnp.float32)
        new_cache = dict(cache)
        new_cache["k"], new_cache["v"] = k_all, v_all
        new_cache["length"] = cache["length"].at[slot_ids].set(
            new_lens, mode="drop"
        )
        return logits, new_cache

    # ------------------------------------------------------------------ #
    # Serving: unified mixed prefill+decode dispatch (paged layout)       #
    # ------------------------------------------------------------------ #
    def _qkv_block(self, x, lp):
        """Shared q/k/v projection + qk-norm for the serving bodies."""
        cfg = self.cfg
        q = jnp.einsum("bsd,dhk->bshk", x, lp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", x, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, lp["wv"])
        if cfg.use_bias:
            q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
        if cfg.qk_norm:
            q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
            k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
        return q, k, v

    def _mlp_block(self, h, lp):
        cfg = self.cfg
        x = apply_norm(h, lp["norm_mlp"], cfg.norm_kind, cfg.norm_eps)
        if cfg.is_moe:
            mlp_out, _ = moe_apply(
                x, lp["moe"],
                n_experts=cfg.n_experts,
                top_k=cfg.experts_per_token,
                mlp_kind=cfg.mlp_kind,
                capacity_factor=cfg.moe_capacity_factor,
                group_size=cfg.moe_group_size,
            )
        else:
            mlp_out = mlp_apply(x, lp["mlp"], cfg.mlp_kind)
        return h + mlp_out

    def mixed_step(
        self,
        params: Params,
        dec_tokens: jax.Array,             # (J,) int32 — pending token/slot
        cache: Dict[str, jax.Array],       # paged cache (the whole pool)
        chunk_tokens: jax.Array,           # (R, C) int32 — one chunk per row
        chunk_slots: jax.Array,            # (R,) int32; >= n_slots → pad row
        chunk_starts: jax.Array,           # (R,) int32 — offset in prompt
        chunk_lens: jax.Array,             # (R,) int32 — real tokens (≤ C)
        *,
        sampler,                           # serving.sampler.Sampler object
        dec_active: jax.Array,             # (J,) bool — slots decoding now
        rids: jax.Array,                   # (J+R,) int32 — request ids
        token_idx: jax.Array,              # (J+R,) int32 — sampled token index
        sample_rows: jax.Array,            # (J+R,) bool — rows that sample
        base_key: Optional[jax.Array] = None,  # typed PRNG key (stochastic)
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Process one *mixed* batch — a decode round over all J slots plus
        R ragged prefill-chunk rows — in ONE device dispatch over the paged
        KV pool.

        The two sub-batches keep their native shapes and run through a
        single layer scan: the chunk rows use exactly ``prefill_chunk``'s
        row-form page writes and chunk attention, the decode lanes exactly
        the paged ``decode_step`` math with ``dec_active`` masking. A mid-
        prefill slot is never bound, so the sub-batches touch disjoint
        slots and the mixed round is mathematically the sequential
        chunk-round-then-decode-round computation fused into one dispatch
        — prefill stops preempting decode because there is no separate
        prefill stage left to preempt it with.

        Sampling happens on device for every row flagged in ``sample_rows``
        (decode lanes first, then chunk rows: a prompt's final chunk emits
        its first output token in the same call), with per-row keys folded
        from ``(base_key, rid, token_idx)`` so streams stay a pure function
        of (seed, rid, token index) regardless of batch composition.
        Returns ``(sampled (J+R,) int32 with -1 on non-sampling rows,
        cache)``.
        """
        cfg = self.cfg
        self._check_paged_supported()
        j = dec_tokens.shape[0]
        r, c = chunk_tokens.shape
        n_slots = cache["block_tables"].shape[0]
        lengths = cache["length"]
        grow = dec_active.astype(jnp.int32)

        # decode-lane geometry (paged decode_step)
        dec_pos = lengths[:, None].astype(jnp.int32)            # (J, 1)
        dec_pos_in = (
            jnp.broadcast_to(dec_pos[..., None], (j, 1, 3))
            if cfg.m_rope else dec_pos
        )
        dec_tables = cache["block_tables"]

        # chunk-row geometry (prefill_chunk)
        ch_pos = chunk_starts[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
        ch_pos_in = (
            jnp.broadcast_to(ch_pos[..., None], (r, c, 3))
            if cfg.m_rope else ch_pos
        )
        ch_tables = cache["block_tables"][jnp.clip(chunk_slots, 0, n_slots - 1)]
        ch_new_lens = chunk_starts + chunk_lens

        def body(carry, xs):
            h_d, h_c = carry
            lp, kc, vc = xs
            # projections for both sub-batches
            x_d = apply_norm(h_d, lp["norm_attn"], cfg.norm_kind, cfg.norm_eps)
            q_d, k_d, v_d = self._qkv_block(x_d, lp)
            x_c = apply_norm(h_c, lp["norm_attn"], cfg.norm_kind, cfg.norm_eps)
            q_c, k_c, v_c = self._qkv_block(x_c, lp)
            if cfg.m_rope:
                q_d = apply_m_rope(q_d, dec_pos_in, cfg.m_rope_sections, cfg.rope_theta)
                k_d = apply_m_rope(k_d, dec_pos_in, cfg.m_rope_sections, cfg.rope_theta)
                q_c = apply_m_rope(q_c, ch_pos_in, cfg.m_rope_sections, cfg.rope_theta)
                k_c = apply_m_rope(k_c, ch_pos_in, cfg.m_rope_sections, cfg.rope_theta)
            else:
                q_d = apply_rope(q_d, dec_pos, cfg.rope_theta)
                k_d = apply_rope(k_d, dec_pos, cfg.rope_theta)
                q_c = apply_rope(q_c, ch_pos, cfg.rope_theta)
                k_c = apply_rope(k_c, ch_pos, cfg.rope_theta)
            # all page writes land before either attention reads — the
            # sub-batches own disjoint slots, so write order is irrelevant
            kc, vc = paged_cache_write(
                kc, vc, k_c, v_c, ch_tables, chunk_starts, chunk_lens
            )
            kc, vc = paged_cache_write_token(
                kc, vc, k_d, v_d, dec_tables, lengths, dec_active
            )
            attn_c = attention_paged(
                q_c, kc, vc, ch_tables,
                q_positions=ch_pos, valid_lengths=ch_new_lens, causal=True,
            )
            attn_d = attention_paged(
                q_d, kc, vc, dec_tables,
                q_positions=dec_pos, valid_lengths=lengths + grow, causal=True,
            )
            attn_d = jnp.einsum("bshk,hkd->bsd", attn_d, lp["wo"])
            attn_c = jnp.einsum("bshk,hkd->bsd", attn_c, lp["wo"])
            if cfg.use_bias:
                attn_d = attn_d + lp["bo"]
                attn_c = attn_c + lp["bo"]
            h_d = self._mlp_block(h_d + attn_d, lp)
            h_c = self._mlp_block(h_c + attn_c, lp)
            return (h_d, h_c), (kc, vc)

        h_d = embed_tokens(dec_tokens[:, None], params["embed"]).astype(self.dtype)
        h_c = embed_tokens(chunk_tokens, params["embed"]).astype(self.dtype)
        (h_d, h_c), (k_all, v_all) = jax.lax.scan(
            body, (h_d, h_c), (params["blocks"], cache["k"], cache["v"])
        )
        h_d = apply_norm(h_d, params["norm_final"], cfg.norm_kind, cfg.norm_eps)
        h_c = apply_norm(h_c, params["norm_final"], cfg.norm_kind, cfg.norm_eps)
        h_last = jnp.concatenate(
            [h_d[:, 0], h_c[jnp.arange(r), jnp.maximum(chunk_lens - 1, 0)]]
        )
        logits = unembed(h_last, params["embed"]).astype(jnp.float32)
        if base_key is None:
            nxt = sampler(logits)
        else:
            from ..serving.sampler import fold_row_keys

            keys = fold_row_keys(base_key, rids, token_idx)
            nxt = sampler(logits, keys)
        sampled = jnp.where(sample_rows, nxt, -1)
        new_cache = dict(cache)
        new_cache["k"], new_cache["v"] = k_all, v_all
        # decode lanes grow their slot by one; chunk rows set start+len
        # (disjoint slots; pad rows scatter out of range and drop)
        new_cache["length"] = (lengths + grow).at[chunk_slots].set(
            ch_new_lens, mode="drop"
        )
        return sampled, new_cache

    # ------------------------------------------------------------------ #
    # Serving: one decode step                                            #
    # ------------------------------------------------------------------ #
    def decode_step(
        self,
        params: Params,
        tokens: jax.Array,                 # (B,) int32 — last sampled token
        cache: Dict[str, jax.Array],
        active: Optional[jax.Array] = None,   # (B,) bool
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Append one token per slot; returns (logits (B,V) f32, cache).

        The cache layout is detected from the pytree: a ``block_tables`` key
        selects the paged path. ``active`` masks which slots may write and
        advance — mandatory for paged caches (an idle slot's stale block
        table could alias pages now owned by another slot) and load-bearing
        inside the fused multi-step loop, where a slot that hit its stop
        condition mid-horizon must become a no-op (no KV write, no length
        growth) instead of forcing the whole batch to exit. ``active=None``
        keeps the legacy all-slots-advance dense behavior."""
        if "block_tables" in cache:
            return self._decode_step_paged(params, tokens, cache, active)
        cfg = self.cfg
        b = tokens.shape[0]
        lengths = cache["length"]                     # (B,) per-slot lengths
        grow = (
            jnp.ones((b,), jnp.int32) if active is None
            else active.astype(jnp.int32)
        )
        h = embed_tokens(tokens[:, None], params["embed"]).astype(self.dtype)  # (B,1,D)
        positions = lengths[:, None].astype(jnp.int32)            # (B, 1)
        if cfg.m_rope:
            pos_in = jnp.broadcast_to(positions[..., None], (b, 1, 3))
        else:
            pos_in = positions

        ring = cfg.sliding_window > 0
        # Post-write key positions (same for every layer): each active slot's
        # new token sits at its own ``lengths[b]``; masked slots gain nothing.
        if ring:
            k_pos_now = ring_positions_write_token(cache["pos"], lengths, active)
        else:
            max_len = cache["k"].shape[2]
            idx = jnp.arange(max_len, dtype=jnp.int32)
            k_pos_now = jnp.where(
                idx[None, :] < (lengths + grow)[:, None], idx[None, :], -1
            )

        def body(h, xs):
            lp, kc, vc = xs
            x = apply_norm(h, lp["norm_attn"], cfg.norm_kind, cfg.norm_eps)
            q, k, v = self._qkv_block(x, lp)
            if cfg.m_rope:
                q = apply_m_rope(q, pos_in, cfg.m_rope_sections, cfg.rope_theta)
                k = apply_m_rope(k, pos_in, cfg.m_rope_sections, cfg.rope_theta)
            else:
                q = apply_rope(q, positions, cfg.rope_theta)
                k = apply_rope(k, positions, cfg.rope_theta)
            if ring:
                kc, vc = ring_cache_write_token(kc, vc, k, v, lengths, active)
            else:
                kc, vc = full_cache_write_token(kc, vc, k, v, lengths, active)
            attn_out = attention(
                q, kc, vc,
                q_positions=positions,
                k_positions=k_pos_now,
                causal=True,
                window=cfg.sliding_window,
            )
            attn_out = jnp.einsum("bshk,hkd->bsd", attn_out, lp["wo"])
            if cfg.use_bias:
                attn_out = attn_out + lp["bo"]
            h = self._mlp_block(h + attn_out, lp)
            return h, (kc, vc)

        h, (k_all, v_all) = jax.lax.scan(
            body, h, (params["blocks"], cache["k"], cache["v"])
        )
        h = apply_norm(h, params["norm_final"], cfg.norm_kind, cfg.norm_eps)
        logits = unembed(h[:, 0, :], params["embed"]).astype(jnp.float32)
        new_cache = dict(cache)
        new_cache["k"], new_cache["v"] = k_all, v_all
        new_cache["length"] = lengths + grow
        if ring:
            new_cache["pos"] = k_pos_now
        return logits, new_cache

    def _decode_step_paged(
        self,
        params: Params,
        tokens: jax.Array,                 # (B,) int32
        cache: Dict[str, jax.Array],       # paged cache; B = n_slots
        active: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        b = tokens.shape[0]
        tables = cache["block_tables"]
        lengths = cache["length"]
        if active is None:
            active = jnp.ones((b,), jnp.bool_)
        grow = active.astype(jnp.int32)
        positions = lengths[:, None].astype(jnp.int32)
        if cfg.m_rope:
            pos_in = jnp.broadcast_to(positions[..., None], (b, 1, 3))
        else:
            pos_in = positions

        def body(h, xs):
            lp, kc, vc = xs
            x = apply_norm(h, lp["norm_attn"], cfg.norm_kind, cfg.norm_eps)
            q, k, v = self._qkv_block(x, lp)
            if cfg.m_rope:
                q = apply_m_rope(q, pos_in, cfg.m_rope_sections, cfg.rope_theta)
                k = apply_m_rope(k, pos_in, cfg.m_rope_sections, cfg.rope_theta)
            else:
                q = apply_rope(q, positions, cfg.rope_theta)
                k = apply_rope(k, positions, cfg.rope_theta)
            kc, vc = paged_cache_write_token(kc, vc, k, v, tables, lengths, active)
            # post-write valid counts: active slots gained one token at
            # position ``lengths``; inactive slots' outputs are ignored
            attn_out = attention_paged(
                q, kc, vc, tables,
                q_positions=positions,
                valid_lengths=lengths + grow,
                causal=True,
            )
            attn_out = jnp.einsum("bshk,hkd->bsd", attn_out, lp["wo"])
            if cfg.use_bias:
                attn_out = attn_out + lp["bo"]
            h = self._mlp_block(h + attn_out, lp)
            return h, (kc, vc)

        h = embed_tokens(tokens[:, None], params["embed"]).astype(self.dtype)
        h, (k_all, v_all) = jax.lax.scan(
            body, h, (params["blocks"], cache["k"], cache["v"])
        )
        h = apply_norm(h, params["norm_final"], cfg.norm_kind, cfg.norm_eps)
        logits = unembed(h[:, 0, :], params["embed"]).astype(jnp.float32)
        new_cache = dict(cache)
        new_cache["k"], new_cache["v"] = k_all, v_all
        new_cache["length"] = lengths + grow
        return logits, new_cache

    # ------------------------------------------------------------------ #
    # Serving: fused multi-step decode                                    #
    # ------------------------------------------------------------------ #
    def decode_steps(
        self,
        params: Params,
        tokens: jax.Array,                 # (B,) int32 — last sampled token
        cache: Dict[str, jax.Array],       # dense or paged layout
        *,
        num_steps: int,                    # static — the fused horizon K
        sampler,                           # serving.sampler.Sampler object
        active: jax.Array,                 # (B,) bool — slots decoding now
        budgets: jax.Array,                # (B,) int32 — max tokens to emit
        rids: jax.Array,                   # (B,) int32 — request ids
        token_idx0: jax.Array,             # (B,) int32 — next token's index
        base_key: Optional[jax.Array] = None,  # typed PRNG key (stochastic)
        eos_id: Optional[int] = None,      # static
    ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, Dict[str, jax.Array]]:
        """Run K decode iterations in ONE device dispatch: attention, KV
        append, and token sampling all stay on device; the host sees nothing
        until the horizon boundary. Works for both cache layouts (dispatch on
        ``block_tables`` happens inside ``decode_step``).

        Each iteration feeds the previous iteration's sampled token back in.
        A slot stops when it has emitted ``budgets[b]`` tokens or samples
        ``eos_id``; from then on it is a no-op (masked KV write, frozen
        length) rather than an early exit, so one finished slot never stalls
        the rest of the batch. Stochastic samplers draw per-row keys folded
        from ``(base_key, rid, token index)`` — a request's stream is
        invariant to the horizon K, the slot it occupies, and its batch
        neighbours, which is what makes fused and per-token decode exactly
        token-identical.

        Returns ``(token_block (K, B) int32 with -1 where a slot emitted
        nothing that iteration, emitted (B,) int32, active_out (B,) bool,
        last_token (B,) int32, cache)``.
        """
        from ..serving.sampler import fold_row_keys

        def body(carry, _):
            cur, act, counts, cache = carry
            logits, cache = self.decode_step(params, cur, cache, active=act)
            if base_key is None:
                nxt = sampler(logits)
            else:
                keys = fold_row_keys(base_key, rids, token_idx0 + counts)
                nxt = sampler(logits, keys)
            nxt = jnp.where(act, nxt, cur)          # frozen slots keep theirs
            counts = counts + act.astype(jnp.int32)
            new_act = act & (counts < budgets)
            if eos_id is not None:
                new_act = new_act & (nxt != eos_id)
            emitted_tok = jnp.where(act, nxt, -1)
            return (nxt, new_act, counts, cache), emitted_tok

        b = tokens.shape[0]
        carry0 = (tokens, active, jnp.zeros((b,), jnp.int32), cache)
        (last_tok, active_out, emitted, cache), token_block = jax.lax.scan(
            body, carry0, None, length=num_steps
        )
        return token_block, emitted, active_out, last_tok, cache
