"""Shared layer primitives for the model zoo.

Parameters are declared as ``ParamDef`` trees (shape + logical axes + init),
so the same declaration serves three consumers:

  * ``init_params``     — materialize real arrays (smoke tests, CPU engine)
  * ``abstract_params`` — ShapeDtypeStructs only (the 512-device dry-run
                          lowers against these; nothing is allocated)
  * ``logical_specs``   — logical-axis tree consumed by
                          ``repro.distributed.sharding`` to build
                          PartitionSpecs for any mesh.

Logical axis vocabulary (mapped to mesh axes by sharding rules):
  "layers"   — scan-stacked layer dim (never sharded)
  "batch"    — data parallel
  "seq"      — sequence (context parallel for long KV)
  "vocab"    — vocabulary rows (TP)
  "embed"    — model width (FSDP axis for 2D weights)
  "heads"    — attention heads (TP)
  "kv_heads" — KV heads
  "head_dim" — per-head width
  "mlp"      — FFN hidden (TP)
  "experts"  — MoE experts (EP)
  "rnn"      — recurrent state width
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Tree = Any


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"       # normal | zeros | ones | embed
    scale: Optional[float] = None

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def _fan_in(shape: Tuple[int, ...]) -> int:
    # For stacked layer weights the leading "layers" dim is not a fan-in.
    return shape[-2] if len(shape) >= 2 else shape[-1]


def init_params(rng: jax.Array, defs: Tree) -> Tree:
    """Materialize a ParamDef tree into real arrays."""
    leaves, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    keys = jax.random.split(rng, len(leaves))
    out = []
    for key, d in zip(keys, leaves):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, d.dtype))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, d.dtype))
        else:
            scale = d.scale
            if scale is None:
                scale = 1.0 if d.init == "embed" else 1.0 / math.sqrt(_fan_in(d.shape))
            out.append(
                (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(d.dtype)
            )
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(defs: Tree) -> Tree:
    """ShapeDtypeStruct tree (no allocation) — the dry-run's param stand-in."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def logical_specs(defs: Tree) -> Tree:
    """Logical-axes tree, same structure as the params."""
    return jax.tree_util.tree_map(
        lambda d: d.axes, defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )


# --------------------------------------------------------------------------- #
# Norms                                                                       #
# --------------------------------------------------------------------------- #
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(
    x: jax.Array, weight: jax.Array, bias: Optional[jax.Array], eps: float = 1e-5
) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    x = x * weight.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dtype)


def apply_norm(x, p: Dict[str, jax.Array], kind: str, eps: float) -> jax.Array:
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"], eps)
    return layer_norm(x, p["scale"], p.get("bias"), eps)


def norm_defs(d_model: int, kind: str, dtype=jnp.bfloat16, layers: Optional[int] = None):
    lead = (layers,) if layers else ()
    lax = ("layers",) if layers else ()
    defs = {"scale": ParamDef(lead + (d_model,), lax + ("embed",), dtype, "ones")}
    if kind == "layernorm":
        defs["bias"] = ParamDef(lead + (d_model,), lax + ("embed",), dtype, "zeros")
    return defs


# --------------------------------------------------------------------------- #
# Rotary position embeddings (RoPE and M-RoPE)                                #
# --------------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies (head_dim/2,), f32."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array,               # (..., seq, heads, head_dim)
    positions: jax.Array,       # (..., seq) int32
    theta: float = 10000.0,
) -> jax.Array:
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                              # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * inv  # (..., seq, hd/2)
    sin = jnp.sin(angles)[..., None, :]                      # (..., seq, 1, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_m_rope(
    x: jax.Array,               # (batch, seq, heads, head_dim)
    positions: jax.Array,       # (batch, seq, 3) int32 — (t, h, w) triples
    sections: Tuple[int, int, int],
    theta: float = 1_000_000.0,
) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): head_dim/2 frequency slots are split into
    (temporal, height, width) sections, each rotated by its own position."""
    hd = x.shape[-1]
    if sum(sections) != hd // 2:
        raise ValueError(f"M-RoPE sections {sections} must sum to head_dim/2={hd // 2}")
    inv = rope_freqs(hd, theta)                               # (hd/2,)
    # Select which of (t, h, w) drives each frequency slot.
    sel = np.concatenate(
        [np.full(s, idx, dtype=np.int32) for idx, s in enumerate(sections)]
    )
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),                        # (b, s, 3)
        jnp.broadcast_to(jnp.asarray(sel), positions.shape[:-1] + (hd // 2,)).astype(jnp.int32) if False else
        jnp.broadcast_to(jnp.asarray(sel)[None, None, :], positions.shape[:2] + (hd // 2,)),
        axis=-1,
    )                                                         # (b, s, hd/2)
    angles = pos * inv                                        # (b, s, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoid table (seq, d_model), f32."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2 * dim / d_model)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# --------------------------------------------------------------------------- #
# MLPs                                                                        #
# --------------------------------------------------------------------------- #
def mlp_defs(
    d_model: int,
    d_ff: int,
    kind: str,
    dtype=jnp.bfloat16,
    layers: Optional[int] = None,
    use_bias: bool = False,
):
    lead = (layers,) if layers else ()
    lax = ("layers",) if layers else ()
    defs: Dict[str, ParamDef] = {}
    if kind == "swiglu":
        defs["w_gate"] = ParamDef(lead + (d_model, d_ff), lax + ("embed", "mlp"), dtype)
        defs["w_up"] = ParamDef(lead + (d_model, d_ff), lax + ("embed", "mlp"), dtype)
        defs["w_down"] = ParamDef(lead + (d_ff, d_model), lax + ("mlp", "embed"), dtype)
    else:  # squared_relu | gelu
        defs["w_up"] = ParamDef(lead + (d_model, d_ff), lax + ("embed", "mlp"), dtype)
        defs["w_down"] = ParamDef(lead + (d_ff, d_model), lax + ("mlp", "embed"), dtype)
        if use_bias:
            defs["b_up"] = ParamDef(lead + (d_ff,), lax + ("mlp",), dtype, "zeros")
            defs["b_down"] = ParamDef(lead + (d_model,), lax + ("embed",), dtype, "zeros")
    return defs


def mlp_apply(x: jax.Array, p: Dict[str, jax.Array], kind: str) -> jax.Array:
    if kind == "swiglu":
        gate = jnp.einsum("...d,df->...f", x, p["w_gate"])
        up = jnp.einsum("...d,df->...f", x, p["w_up"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    elif kind == "squared_relu":
        h = jnp.einsum("...d,df->...f", x, p["w_up"])
        if "b_up" in p:
            h = h + p["b_up"]
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    elif kind == "gelu":
        h = jnp.einsum("...d,df->...f", x, p["w_up"])
        if "b_up" in p:
            h = h + p["b_up"]
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    else:
        raise ValueError(f"unknown mlp kind {kind!r}")
    out = jnp.einsum("...f,fd->...d", h, p["w_down"])
    if "b_down" in p:
        out = out + p["b_down"]
    return out


# --------------------------------------------------------------------------- #
# Embedding / unembedding                                                     #
# --------------------------------------------------------------------------- #
def embed_defs(vocab: int, d_model: int, dtype=jnp.bfloat16, tie: bool = False):
    defs = {
        "embedding": ParamDef((vocab, d_model), ("vocab", "embed"), dtype, "embed", 0.02)
    }
    if not tie:
        defs["unembed"] = ParamDef((d_model, vocab), ("embed", "vocab"), dtype, "embed", 0.02)
    return defs


def embed_tokens(tokens: jax.Array, p: Dict[str, jax.Array]) -> jax.Array:
    """Embedding lookup with an explicit batch-sharding constraint on the
    output: without it GSPMD picks a pathological sharding for the gather
    from the vocab-sharded table and replicates (B, S, D) activations
    ("involuntary full rematerialization"), costing GBs/device at scale."""
    from ..distributed.sharding import constrain_batch_dim  # noqa: PLC0415

    return constrain_batch_dim(jnp.take(p["embedding"], tokens, axis=0), 0)


def unembed(x: jax.Array, p: Dict[str, jax.Array]) -> jax.Array:
    from ..distributed.sharding import constrain_logits  # noqa: PLC0415

    if "unembed" in p:
        return constrain_logits(jnp.einsum("...d,dv->...v", x, p["unembed"]))
    return constrain_logits(jnp.einsum("...d,vd->...v", x, p["embedding"]))


def moe_aux_weight(cfg) -> float:
    """Load-balancing loss weight (standard 0.01 for Switch-style routers)."""
    return 0.01 if getattr(cfg, "n_experts", 0) > 0 else 0.0


def cross_entropy_loss(
    logits: jax.Array,          # (batch, seq, vocab)
    labels: jax.Array,          # (batch, seq) int32
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
