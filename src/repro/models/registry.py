"""Model registry: family → model class."""
from __future__ import annotations

from typing import Any

from ..configs.base import ArchConfig

MODEL_FAMILIES = ("dense", "moe", "vlm", "ssm", "hybrid", "audio")


def get_model(cfg: ArchConfig) -> Any:
    if cfg.family in ("dense", "moe", "vlm"):
        from .transformer import TransformerLM

        return TransformerLM(cfg)
    if cfg.family == "ssm":
        from .xlstm import XLSTM

        return XLSTM(cfg)
    if cfg.family == "hybrid":
        from .recurrentgemma import RecurrentGemma

        return RecurrentGemma(cfg)
    if cfg.family == "audio":
        from .whisper import Whisper

        return Whisper(cfg)
    raise KeyError(f"unknown model family {cfg.family!r}")
