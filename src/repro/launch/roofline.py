"""Roofline analysis from dry-run artifacts (§Roofline in EXPERIMENTS.md).

Reads ``experiments/dryrun/*.json`` (written by ``repro.launch.dryrun``) and
derives the three per-step roofline terms for TPU v5e:

    compute    = HLO_FLOPs_per_chip / 197 TF/s
    memory     = HBM_bytes_per_chip / 819 GB/s
    collective = collective_bytes_per_chip / 50 GB/s

HLO_FLOPs and collective bytes are the trip-count-aware totals from
``hlo_analysis`` (per-device, since the module is the partitioned program).
HBM bytes use a lower-bound traffic model: every while-body iteration must
re-read its live weight shards and stream its major activations — we proxy
this as (argument_bytes + temp_bytes + output_bytes) per step, which is the
buffer-assignment working set. This *underestimates* re-streaming inside
loops, so memory-bound verdicts here are conservative; the dominant-term
analysis in EXPERIMENTS.md discusses this.

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) for training;
2·N[_active]·D for single forward passes (prefill/decode), per chip.
"""
from __future__ import annotations

import argparse
import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

from ..configs import SHAPES_BY_NAME, get_config
from .mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16
from .plan import WHISPER_DECODER_PROMPT

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_per_chip: float
    hlo_flops_per_chip: float
    fit_gb: float
    tag: str = ""

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS — how much lowered compute is useful."""
        if self.hlo_flops_per_chip <= 0:
            return 0.0
        return self.model_flops_per_chip / self.hlo_flops_per_chip

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful FLOPs / (bound time × peak)."""
        if self.bound_s <= 0:
            return 0.0
        return self.model_flops_per_chip / (self.bound_s * PEAK_FLOPS_BF16)


def model_flops_per_chip(arch: str, shape: str, chips: int) -> float:
    """Analytic useful FLOPs per chip for one step of the cell."""
    cfg = get_config(arch)
    cell = SHAPES_BY_NAME[shape]
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        total = 6.0 * n_active * tokens
    elif cell.kind == "prefill":
        if cfg.family == "audio":
            # encoder processes seq_len frames; decoder prompt is small
            tokens = cell.global_batch * (cell.seq_len + WHISPER_DECODER_PROMPT)
            total = 2.0 * n_active * tokens  # enc+dec share the 2·N·D model
        else:
            tokens = cell.global_batch * cell.seq_len
            total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        tokens = cell.global_batch
        total = 2.0 * n_active * tokens
    return total / chips


def load_results(results_dir: Path = RESULTS_DIR) -> List[dict]:
    out = []
    for p in sorted(results_dir.glob("*.json")):
        try:
            out.append(json.loads(p.read_text()))
        except json.JSONDecodeError:
            continue
    return out


def roofline_for(result: dict) -> Optional[Roofline]:
    if result.get("status") != "ok":
        return None
    chips = result["chips"]
    mem = result["memory"]
    # donated outputs alias argument buffers — count them once
    hbm_bytes = (
        mem["argument_bytes"]
        + mem["temp_bytes"]
        + max(0, mem["output_bytes"] - mem["alias_bytes"])
    )
    flops = result["cost"]["flops"]
    coll = result.get("collective_bytes_total", 0.0)
    mf = model_flops_per_chip(result["arch"], result["shape"], chips)
    return Roofline(
        arch=result["arch"],
        shape=result["shape"],
        mesh=result["mesh"],
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=hbm_bytes / HBM_BW,
        collective_s=coll / ICI_BW_PER_LINK,
        model_flops_per_chip=mf,
        hlo_flops_per_chip=flops,
        fit_gb=hbm_bytes / 2**30,
        tag=result.get("tag", ""),
    )


def table(results_dir: Path = RESULTS_DIR, mesh: str = "16x16", tag: str = "") -> str:
    rows = []
    for r in load_results(results_dir):
        if r.get("mesh") != mesh or r.get("tag", "") != tag:
            continue
        rl = roofline_for(r)
        if rl is None:
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r.get('status')} | "
                f"{r.get('reason', r.get('error', ''))[:60]} |"
            )
            continue
        rows.append(
            f"| {rl.arch} | {rl.shape} | {rl.compute_s:.4f} | {rl.memory_s:.4f} | "
            f"{rl.collective_s:.4f} | **{rl.dominant}** | {rl.useful_ratio:.3f} | "
            f"{rl.roofline_fraction * 100:.1f}% | {rl.fit_gb:.1f} |"
        )
    header = (
        "| arch | shape | compute (s) | memory (s) | collective (s) | dominant | "
        "MODEL/HLO | roofline frac | fit GB/chip |\n"
        "|---|---|---|---|---|---|---|---|---|"
    )
    return header + "\n" + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default="")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    if args.json:
        out = []
        for r in load_results():
            rl = roofline_for(r)
            if rl is not None and r.get("mesh") == args.mesh and r.get("tag", "") == args.tag:
                out.append(rl.__dict__ | {
                    "dominant": rl.dominant,
                    "useful_ratio": rl.useful_ratio,
                    "roofline_fraction": rl.roofline_fraction,
                })
        print(json.dumps(out, indent=2))
    else:
        print(table(mesh=args.mesh, tag=args.tag))


if __name__ == "__main__":
    main()
