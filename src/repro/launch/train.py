"""Training launcher.

    python -m repro.launch.train --arch qwen3_8b --smoke --steps 50
    python -m repro.launch.train --arch whisper_small --smoke --steps 100 \\
        --checkpoint-dir /tmp/ckpt           # kill it and rerun → resumes

Full-size configs train via the same path on a real TPU mesh; on this CPU
container use --smoke (reduced same-family config). The multi-device
distribution path is exercised by the dry-run (repro.launch.dryrun).
"""
from __future__ import annotations

import argparse

from ..configs import ARCH_IDS, get_config, get_smoke_config
from ..train.optimizer import AdamWConfig
from ..train.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tc = TrainConfig(
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        microbatches=args.microbatches,
        checkpoint_dir=args.checkpoint_dir,
    )
    out = train(cfg, tc, AdamWConfig(lr=args.lr, warmup_steps=10))
    print(
        f"done: arch={cfg.name} steps={out['steps_run']} "
        f"(resumed at {out['start_step']}) loss {out['first_loss']:.4f} -> "
        f"{out['last_loss']:.4f} in {out['wall_s']:.1f}s"
    )


if __name__ == "__main__":
    main()
