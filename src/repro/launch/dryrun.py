import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This proves the distribution config is coherent without hardware: 512
placeholder CPU devices stand in for 2 pods × 256 chips. For each cell we

    with mesh:
        lowered  = jax.jit(step, in_shardings=...).lower(**input_specs(...))
        compiled = lowered.compile()
        compiled.memory_analysis()   # proves it fits 16 GB/chip
        compiled.cost_analysis()     # FLOPs/bytes for the roofline

and persist everything (plus the HLO collective inventory) to
``experiments/dryrun/<arch>__<cell>__<mesh>.json``, which §Roofline reads.

Usage:
    python -m repro.launch.dryrun --arch qwen3_8b --shape decode_32k
    python -m repro.launch.dryrun --arch qwen3_8b --shape decode_32k --multi-pod
    python -m repro.launch.dryrun --all            # every applicable cell
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, SHAPES_BY_NAME, cell_applicable, get_config
from ..configs.shapes import SHAPES, ShapeCell
from ..distributed.sharding import (
    ShardingConfig,
    build_cache_specs,
    build_param_specs,
    input_specs_for,
)
from ..models.layers import abstract_params, logical_specs
from ..models.registry import get_model
from ..train.optimizer import AdamWConfig, abstract_opt_state
from ..train.train_step import make_train_step
from .mesh import make_production_mesh
from .plan import WHISPER_CROSS_LEN, WHISPER_DECODER_PROMPT, plan_for

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


# --------------------------------------------------------------------------- #
# Collective inventory from the partitioned HLO                               #
# --------------------------------------------------------------------------- #
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_inventory(hlo_text: str) -> dict:
    """Per-kind {count, bytes} from the per-device partitioned HLO. Result
    buffer sizes are used (per-device bytes moved is proportional; the
    roofline divides by per-chip link bandwidth)."""
    inv = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.find(" = ")
        if eq < 0:
            continue
        rhs = s[eq + 3 :]
        for kind in _COLLECTIVES:
            # match the op name right after the result shape, e.g.
            # "bf16[4,128]{1,0} all-gather(..." — avoids matching metadata
            m = re.match(r"^((?:\([^)]*\))|(?:[\w\[\],{}]+))\s+" + kind + r"(-start|-done)?\(", rhs)
            if m:
                if m.group(2) == "-done":
                    break  # bytes counted at -start
                inv[kind]["count"] += 1
                inv[kind]["bytes"] += _shape_bytes(m.group(1))
                break
    inv["total_bytes"] = sum(v["bytes"] for k, v in inv.items() if isinstance(v, dict))
    return inv


# --------------------------------------------------------------------------- #
# Cell construction                                                           #
# --------------------------------------------------------------------------- #
def build_cell(arch: str, cell: ShapeCell, mesh, plan: dict):
    """Returns (fn, args_abstract, in_shardings, out_shardings, donate).

    Explicit out_shardings matter: donated caches only alias when the output
    sharding matches the input's (GSPMD-propagated output shardings usually
    don't, which silently doubles the cache footprint)."""
    cfg = get_config(arch)
    model = get_model(cfg)
    scfg: ShardingConfig = plan["sharding"]
    defs = model.param_defs()
    aparams = abstract_params(defs)
    laxes = logical_specs(defs)
    pspecs = build_param_specs(aparams, laxes, mesh, scfg)
    b, s = cell.global_batch, cell.seq_len

    def logits_spec(batch_dim_size):
        spec = jax.sharding.PartitionSpec(
            scfg.dp_axes if batch_dim_size % _mesh_prod(mesh, scfg.dp_axes) == 0 else None,
            scfg.tp_axis if cfg.vocab_size % _mesh_prod(mesh, (scfg.tp_axis,)) == 0 else None,
        )
        return jax.sharding.NamedSharding(mesh, spec)

    if cell.kind == "train":
        opt_abs = abstract_opt_state(aparams)
        opt_specs = {
            "m": build_param_specs(opt_abs["m"], laxes, mesh, scfg),
            "v": build_param_specs(opt_abs["v"], laxes, mesh, scfg),
            "count": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        }
        in_specs, in_shards = input_specs_for(cfg, cell, mesh, scfg)
        step = make_train_step(
            model, AdamWConfig(), microbatches=plan["microbatches"], remat=plan["remat"]
        )
        args = (aparams, opt_abs, in_specs)
        shards = (pspecs, opt_specs, in_shards)
        rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        metrics_out = {"grad_norm": rep, "lr": rep, "loss": rep}
        return step, args, shards, (pspecs, opt_specs, metrics_out), (0, 1)

    if cell.kind == "prefill":
        if cfg.family == "audio":
            dec_len = WHISPER_DECODER_PROMPT
            cache_abs = model.cache_shape(b, dec_len, enc_len=s)
            tokens = jax.ShapeDtypeStruct((b, dec_len), jnp.int32)
            frames = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
            cache_specs = build_cache_specs(cache_abs, mesh, scfg, cfg.n_kv_heads)
            _, in_shards = input_specs_for(cfg, cell, mesh, scfg)
            fn = lambda params, tokens, cache, frames: model.prefill(
                params, tokens, cache, patch_embeds=frames
            )
            args = (aparams, tokens, cache_abs, frames)
            tok_spec = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(scfg.dp_axes)
            )
            shards = (pspecs, tok_spec, cache_specs, in_shards["frames"])
            return fn, args, shards, (logits_spec(b), cache_specs), (2,)
        in_specs, in_shards = input_specs_for(cfg, cell, mesh, scfg)
        cache_abs = model.cache_shape(b, s)
        cache_specs = build_cache_specs(cache_abs, mesh, scfg, cfg.n_kv_heads)
        if cfg.family == "vlm":
            fn = lambda params, tokens, cache, patch_embeds: model.prefill(
                params, tokens, cache, patch_embeds=patch_embeds
            )
            args = (aparams, in_specs["tokens"], cache_abs, in_specs["patch_embeds"])
            shards = (pspecs, in_shards["tokens"], cache_specs, in_shards["patch_embeds"])
            return fn, args, shards, (logits_spec(b), cache_specs), (2,)
        fn = lambda params, tokens, cache: model.prefill(params, tokens, cache)
        args = (aparams, in_specs["tokens"], cache_abs)
        shards = (pspecs, in_shards["tokens"], cache_specs)
        return fn, args, shards, (logits_spec(b), cache_specs), (2,)

    if cell.kind == "decode":
        in_specs, in_shards = input_specs_for(cfg, cell, mesh, scfg)
        if cfg.family == "audio":
            cache_abs = model.cache_shape(b, s, enc_len=WHISPER_CROSS_LEN)
        else:
            cache_abs = model.cache_shape(b, s)
        cache_specs = build_cache_specs(cache_abs, mesh, scfg, cfg.n_kv_heads)
        fn = lambda params, tokens, cache: model.decode_step(params, tokens, cache)
        args = (aparams, in_specs["tokens"], cache_abs)
        shards = (pspecs, in_shards["tokens"], cache_specs)
        return fn, args, shards, (logits_spec(b), cache_specs), (2,)

    raise ValueError(cell.kind)


def _mesh_prod(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = 1
    for a in axes:
        out *= sizes.get(a, 1)
    return out


def input_specs(arch: str, shape: str, multi_pod: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of a cell (public
    helper per the brief)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = SHAPES_BY_NAME[shape]
    plan = plan_for(arch, shape, multi_pod)
    _, args, _, _, _ = build_cell(arch, cell, mesh, plan)
    return args


# --------------------------------------------------------------------------- #
def run_cell(arch: str, shape: str, multi_pod: bool, overrides=None,
             save: bool = True, tag: str = "") -> dict:
    cell = SHAPES_BY_NAME[shape]
    cfg = get_config(arch)
    ok, why = cell_applicable(cfg, cell)
    if not ok:
        return {"arch": arch, "shape": shape, "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    plan = plan_for(arch, shape, multi_pod, overrides)
    t0 = time.time()
    fn, args, shards, out_shards, donate = build_cell(arch, cell, mesh, plan)
    result = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(n_chips),
        "microbatches": plan["microbatches"],
        "tag": tag,
    }
    try:
        with mesh:
            jitted = jax.jit(
                fn, in_shardings=shards, out_shardings=out_shards,
                donate_argnums=donate,
            )
            lowered = jitted.lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        from .hlo_analysis import analyze_hlo

        totals = analyze_hlo(hlo)
        result.update(
            {
                "status": "ok",
                "lower_s": round(t_lower - t0, 2),
                "compile_s": round(t_compile - t_lower, 2),
                "memory": {
                    "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                    "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                    "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                    "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
                    "generated_code_bytes": int(
                        getattr(mem, "generated_code_size_in_bytes", 0)
                    ),
                },
                # raw XLA numbers (loop bodies counted ONCE — kept for
                # reference only; see hlo_analysis for the real accounting)
                "cost_analysis_raw": {
                    "flops": float(cost.get("flops", -1)) if cost else -1,
                    "bytes_accessed": float(cost.get("bytes accessed", -1)) if cost else -1,
                },
                # trip-count-aware per-device totals
                "cost": {
                    "flops": totals.flops,
                    "transcendentals": totals.transcendentals,
                },
                "collectives": totals.as_dict()["collectives"],
                "collective_bytes_total": totals.total_collective_bytes,
            }
        )
    except Exception as e:  # noqa: BLE001 — a failed cell is a recorded bug
        result.update(
            {
                "status": "failed",
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
            }
        )
    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        out = RESULTS_DIR / f"{arch}__{shape}__{result['mesh']}{suffix}.json"
        out.write_text(json.dumps(result, indent=2))
        result["saved_to"] = str(out)
    return result


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, help="architecture id")
    ap.add_argument("--shape", choices=[s.name for s in SHAPES], help="shape cell")
    ap.add_argument("--multi-pod", action="store_true", help="2x16x16 mesh")
    ap.add_argument("--all", action="store_true", help="run every applicable cell")
    ap.add_argument("--tag", default="", help="variant tag for perf experiments")
    ap.add_argument("--no-fsdp", action="store_true", help="replicate weights over dp")
    args = ap.parse_args()

    overrides = None
    if args.no_fsdp:
        from ..distributed.sharding import ShardingConfig

        overrides = {
            "sharding": ShardingConfig(
                dp_axes=("pod", "data") if args.multi_pod else ("data",),
                fsdp_weights=False,
            )
        }

    if args.all:
        failures = 0
        for arch in ARCH_IDS:
            for cell in SHAPES:
                r = run_cell(arch, cell.name, args.multi_pod, overrides, tag=args.tag)
                status = r["status"]
                extra = r.get("reason", r.get("error", ""))
                print(f"{arch:20s} {cell.name:12s} {status:8s} {extra}", flush=True)
                failures += status == "failed"
        return 1 if failures else 0

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    r = run_cell(args.arch, args.shape, args.multi_pod, overrides, tag=args.tag)
    print(json.dumps({k: v for k, v in r.items() if k != "traceback"}, indent=2))
    if r["status"] == "failed":
        print(r.get("traceback", ""), file=sys.stderr)
    return 1 if r["status"] == "failed" else 0


if __name__ == "__main__":
    sys.exit(main())
