"""Serving launcher — the paper's system, end to end on a real model.

    python -m repro.launch.serve --arch qwen3_8b --policy hybrid \\
        --requests 32 --slots 8

Runs the continuous-batching engine (CPU smoke config here; same code path
on a TPU mesh) under a scheduling configuration and prints the utilization /
throughput / Gantt comparison the paper's Figs. 6–9 make.
"""
from __future__ import annotations

import argparse

import jax

from ..configs import ARCH_IDS, get_smoke_config
from ..core import (
    CostModel,
    GlobalQueueScheduler,
    LagrangianPolicy,
    PrefillFirstPolicy,
    SortingPreemptiveScheduler,
    StaticBacklogScheduler,
    build_clients,
    solve_offline,
)
from ..core.gantt import ascii_gantt
from ..data import WorkloadSpec, gsm8k_like_workload
from ..models.layers import init_params
from ..models.registry import get_model
from ..serving.engine import Engine, EngineConfig


def build_scheduling(mode, reqs, n_slots, cm):
    if mode == "baseline":
        return build_clients(n_slots, reqs, None), GlobalQueueScheduler(reqs), PrefillFirstPolicy()
    if mode == "offline":
        asn = solve_offline(reqs, n_slots, cm).assignment
        clients = build_clients(n_slots, reqs, asn)
        return clients, StaticBacklogScheduler(clients), PrefillFirstPolicy()
    if mode == "online":
        clients = build_clients(
            n_slots, reqs, [[r.rid for r in reqs[j::n_slots]] for j in range(n_slots)]
        )
        return clients, SortingPreemptiveScheduler(clients), LagrangianPolicy()
    if mode == "hybrid":
        asn = solve_offline(reqs, n_slots, cm).assignment
        clients = build_clients(n_slots, reqs, asn)
        return clients, SortingPreemptiveScheduler(clients), LagrangianPolicy()
    raise ValueError(mode)


ENGINE_ARCHS = [a for a in ARCH_IDS if a != "whisper_small"]
# whisper is enc-dec: its prefill consumes frame embeddings the demo engine
# does not synthesize; all decoder-only/recurrent families serve fine.


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ENGINE_ARCHS, default="qwen3_8b")
    ap.add_argument("--policy", choices=["baseline", "offline", "online", "hybrid"],
                    default="hybrid")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--gantt", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = get_model(cfg)
    params = init_params(jax.random.key(0), model.param_defs())
    spec = WorkloadSpec(
        n_requests=args.requests, input_mean=20, input_std=6,
        output_mean=24, output_std=10, output_max=48, input_max=30,
    )
    reqs = gsm8k_like_workload(spec, seed=args.seed, known_lengths=True)
    cm = CostModel(level_caps=(32, 64, 128, 256))
    clients, sched, pol = build_scheduling(args.policy, reqs, args.slots, cm)
    eng = Engine(
        model, params,
        EngineConfig(n_slots=args.slots, max_len=128, prefill_seq_buckets=(32,)),
    )
    eng.profiler.cost_model = cm
    trace = eng.serve(reqs, clients, sched, pol, policy_name=args.policy)
    s = trace.summary()
    print(
        f"policy={args.policy} util={s['utilization'] * 100:.1f}% "
        f"makespan={s['makespan_s']:.2f}s speed={s['generation_speed_tok_s']:.0f} tok/s "
        f"bins={s['num_bins']} decisions p50={s['mean_decision_ms']:.3f}ms"
    )
    if args.gantt:
        print(ascii_gantt(trace, width=90, max_clients=args.slots))


if __name__ == "__main__":
    main()
