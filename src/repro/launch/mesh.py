"""Production mesh builders.

Functions (not module-level constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
device initialization.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests and elastic re-mesh use this)."""
    return jax.make_mesh(shape, axes)


def dp_axes_for(mesh) -> Tuple[str, ...]:
    """The data-parallel axes of a production mesh (pod folds into DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# TPU v5e hardware constants (per chip) — roofline denominators.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW_PER_LINK = 50e9            # B/s per link
