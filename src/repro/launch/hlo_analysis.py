"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body* once —
useless for scan-over-layers / grad-accumulation programs where >99% of the
work sits inside loops. This module parses the partitioned HLO text,
recovers each loop's trip count from its condition computation
(``compare(counter, constant), direction=LT``), and accumulates

  * FLOPs        — dots (2·M·N·K), elementwise arithmetic, reduces
  * collective bytes — per kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), result-buffer bytes
  * HBM traffic proxy — bytes written by dots/parameters is NOT recoverable
    from text alone; we take cost_analysis()'s per-call bytes for the body
    and scale by trip counts the same way.

multiplied through arbitrarily nested while/fusion/call computations.
Numbers are per-device (the module is the SPMD-partitioned per-chip
program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_ELEMENTWISE_1FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "negate",
    "abs", "and", "or", "xor", "not", "compare", "select", "clamp", "sign",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
}
_TRANSCENDENTAL = {
    "exponential", "exponential-minus-one", "log", "log-plus-one", "rsqrt",
    "sqrt", "cbrt", "tanh", "sine", "cosine", "tan", "atan2", "power",
    "logistic", "erf",
}


@dataclass
class OpInfo:
    name: str
    opcode: str
    shape_str: str
    line: str


@dataclass
class Computation:
    name: str
    ops: Dict[str, OpInfo] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)
    root: Optional[str] = None


@dataclass
class CostTotals:
    flops: float = 0.0
    transcendentals: float = 0.0
    collective_bytes: Dict[str, float] = field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS}
    )
    collective_counts: Dict[str, float] = field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS}
    )

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "transcendentals": self.transcendentals,
            "collectives": {
                k: {"bytes": self.collective_bytes[k], "count": self.collective_counts[k]}
                for k in COLLECTIVE_KINDS
            },
            "total_collective_bytes": self.total_collective_bytes,
        }


_SHAPE_TOKEN = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# "  %name = <shape-or-tuple> opcode(...), attrs" — opcode is [\w-]+
_OP_LINE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s+([\w\-]+)\("
)
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    """(element count, bytes) over every array in a (possibly tuple) shape."""
    elems = 0
    total = 0
    for m in _SHAPE_TOKEN.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        header = _COMP_HEADER.match(line)
        if header and ("->" in line):
            cur = Computation(name=header.group(1))
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        is_root, name, shape_str, opcode = m.group(1), m.group(2), m.group(3), m.group(4)
        op = OpInfo(name=name, opcode=opcode, shape_str=shape_str, line=line)
        cur.ops[name] = op
        cur.order.append(name)
        if is_root:
            cur.root = name
    return comps, entry


def _operand_names(line: str, opcode: str) -> List[str]:
    """Operand ids inside the top-level parens of ``opcode(...)``."""
    idx = line.find(opcode + "(")
    if idx < 0:
        return []
    start = idx + len(opcode) + 1
    depth = 1
    out = []
    token = []
    i = start
    while i < len(line) and depth > 0:
        c = line[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                break
        elif c == "," and depth == 1:
            out.append("".join(token).strip())
            token = []
            i += 1
            continue
        token.append(c)
        i += 1
    if token:
        out.append("".join(token).strip())
    names = []
    for t in out:
        t = t.strip()
        if t.startswith("%"):
            t = t[1:]
        # strip embedded shapes like "bf16[2,3]{1,0} %foo"
        parts = t.split()
        cand = parts[-1] if parts else t
        if cand.startswith("%"):
            cand = cand[1:]
        names.append(cand)
    return names


_ATTR_CALLS = re.compile(r"(?:to_apply|body|condition|branch_computations|called_computations|calls)=\{?%?([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONSTANT_VAL = re.compile(r"constant\((-?\d+)\)")


def _dot_flops(comp: Computation, op: OpInfo) -> float:
    elems, _ = _shape_elems_bytes(op.shape_str)
    m = _CONTRACT.search(op.line)
    contract = 1
    if m:
        dims = [int(d) for d in m.group(1).split(",") if d]
        operands = _operand_names(op.line, "dot")
        if operands:
            lhs = comp.ops.get(operands[0])
            if lhs is not None:
                sm = _SHAPE_TOKEN.search(lhs.shape_str)
                if sm:
                    lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
                    for d in dims:
                        if d < len(lhs_dims):
                            contract *= lhs_dims[d]
    return 2.0 * elems * contract


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> float:
    """Trip count of a scan-style loop condition (counter < constant).

    The compare is often wrapped in a fusion with the bound passed as an
    operand, so we take the largest integer constant defined in the
    condition computation — for jax.lax.scan-generated loops that is always
    the trip bound (other constants are 0/±1 counter steps)."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1.0
    best = 1.0
    for op_name in cond.order:
        op = cond.ops[op_name]
        if op.opcode == "constant":
            m = _CONSTANT_VAL.search(op.line)
            if m:
                best = max(best, float(m.group(1)))
    return best


def _analyze_comp(
    comps: Dict[str, Computation],
    name: str,
    totals: CostTotals,
    mult: float,
    visited_stack: Tuple[str, ...] = (),
) -> None:
    comp = comps.get(name)
    if comp is None or name in visited_stack:
        return
    stack = visited_stack + (name,)
    for op_name in comp.order:
        op = comp.ops[op_name]
        oc = op.opcode
        if oc == "while":
            m = re.search(r"condition=%?([\w.\-]+)", op.line)
            b = re.search(r"body=%?([\w.\-]+)", op.line)
            trips = _trip_count(comps, m.group(1)) if m else 1.0
            if b:
                _analyze_comp(comps, b.group(1), totals, mult * trips, stack)
            if m:
                _analyze_comp(comps, m.group(1), totals, mult * trips, stack)
            continue
        if oc in ("fusion", "call", "custom-call", "conditional", "async-start",
                  "map", "reduce", "reduce-window", "sort", "scatter", "select-and-scatter"):
            for cm in _ATTR_CALLS.finditer(op.line):
                _analyze_comp(comps, cm.group(1), totals, mult, stack)
        if oc == "dot":
            totals.flops += mult * _dot_flops(comp, op)
        elif oc == "convolution":
            # rough: 2 * out_elems * (in_channels * window) — rare in our zoo
            elems, _ = _shape_elems_bytes(op.shape_str)
            totals.flops += mult * 2.0 * elems
        elif oc in _ELEMENTWISE_1FLOP:
            elems, _ = _shape_elems_bytes(op.shape_str)
            totals.flops += mult * elems
        elif oc in _TRANSCENDENTAL:
            elems, _ = _shape_elems_bytes(op.shape_str)
            totals.flops += mult * elems
            totals.transcendentals += mult * elems
        elif oc == "reduce":
            operands = _operand_names(op.line, "reduce")
            if operands:
                src = comp.ops.get(operands[0])
                if src is not None:
                    elems, _ = _shape_elems_bytes(src.shape_str)
                    totals.flops += mult * elems
        else:
            base = oc.replace("-start", "")
            if base in COLLECTIVE_KINDS and not oc.endswith("-done"):
                _, nbytes = _shape_elems_bytes(op.shape_str)
                totals.collective_bytes[base] += mult * nbytes
                totals.collective_counts[base] += mult


def analyze_hlo(text: str) -> CostTotals:
    comps, entry = parse_hlo(text)
    totals = CostTotals()
    if entry is None:
        # fall back: analyze every computation once (over-count risk)
        for name in comps:
            _analyze_comp(comps, name, totals, 1.0)
        return totals
    _analyze_comp(comps, entry, totals, 1.0)
    return totals
