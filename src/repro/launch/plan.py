"""Per-(arch × cell) runtime knobs for the dry-run and launchers.

Microbatch counts keep per-device activation peaks inside the 16 GB v5e
budget at train_4k (global batch 256); serve cells run unbatched. These are
the §Perf baseline settings — hillclimbs override via ``overrides``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from ..distributed.sharding import ShardingConfig

# (arch, cell) -> microbatches for training. Sized so per-device
# (argument + temp) stays under the 16 GB v5e HBM (validated by the dry-run;
# the sequence-parallel hillclimb in §Perf reduces these).
MICROBATCHES: Dict[Tuple[str, str], int] = {
    ("mixtral_8x22b", "train_4k"): 32,  # 14.8 GB/chip with bf16 grad accum
    ("olmoe_1b_7b", "train_4k"): 16,
    ("qwen3_8b", "train_4k"): 16,
    ("starcoder2_7b", "train_4k"): 16,
    ("granite_3_8b", "train_4k"): 16,
    ("nemotron_4_15b", "train_4k"): 16,
    ("qwen2_vl_7b", "train_4k"): 16,
    ("xlstm_350m", "train_4k"): 8,
    ("recurrentgemma_9b", "train_4k"): 16,
    ("whisper_small", "train_4k"): 4,
}

# Whisper serve-cell geometry (see DESIGN.md §Arch-applicability):
# prefill_32k = 32k encoder frames + 448-token decoder prompt;
# decode_32k  = one decoder token against a 32k self-KV + 1500 cross-KV.
WHISPER_DECODER_PROMPT = 448
WHISPER_CROSS_LEN = 1536


# Serve-time weights stay FSDP-sharded only where TP-only weights exceed the
# 16 GB/chip budget (mixtral: 282 GB bf16 / 16 TP = 17.6 GB). Everyone else
# replicates weights across DP at serve time — the per-step weight
# all-gathers vanish (§Perf H1: 30× less decode collective traffic).
FSDP_AT_SERVE = {"mixtral_8x22b"}
# xlstm's 0.2B params never warrant FSDP; per-time-step weight gathers under
# the recurrent scan cost ~2.5× the total collective bytes otherwise.
NEVER_FSDP = {"xlstm_350m"}


def plan_for(arch: str, cell_name: str, multi_pod: bool = False,
             overrides: Optional[dict] = None) -> dict:
    is_serve = cell_name in ("prefill_32k", "decode_32k", "long_500k")
    fsdp = True
    if arch in NEVER_FSDP:
        fsdp = False
    elif is_serve and arch not in FSDP_AT_SERVE:
        fsdp = False
    mb = MICROBATCHES.get((arch, cell_name), 1)
    if multi_pod and mb > 1:
        # 2 pods double the DP width to 32: per-microbatch batch must stay
        # divisible by it (256/mb % 32 == 0 → mb ≤ 8), or the batch dim
        # degrades to partial sharding and activations blow up ~2-4×.
        mb = min(mb, 8)
    plan = {
        "microbatches": mb,
        "remat": True,
        "sharding": ShardingConfig(
            dp_axes=("pod", "data") if multi_pod else ("data",),
            fsdp_weights=fsdp,
        ),
    }
    if overrides:
        plan.update(overrides)
    return plan
