"""Policy lab — compare iteration policies beyond the paper's (§VI future
directions): utilization-weighted amortization and the dynamic-batch tail
rule, across several workload shapes.

    PYTHONPATH=src python examples/policy_lab.py
"""
from repro.core import (
    PAPER_COST_MODEL,
    DynamicBatchPolicy,
    LagrangianPolicy,
    PrefillFirstPolicy,
    UtilizationWeightedPolicy,
    simulate,
)
from repro.data import (
    PAPER_PREDICTOR_NOISE_STD,
    PAPER_WORKLOAD_SPEC,
    WorkloadSpec,
    gsm8k_like_workload,
)
import dataclasses

WORKLOADS = {
    "paper(gsm8k)": PAPER_WORKLOAD_SPEC,
    "short-outputs": dataclasses.replace(
        PAPER_WORKLOAD_SPEC, output_mean=80.0, output_std=40.0, output_mu0=80.0,
        output_sigma0=40.0,
    ),
    "long-prompts": dataclasses.replace(
        PAPER_WORKLOAD_SPEC, input_mean=400.0, input_std=120.0,
    ),
}

from repro.core import AmortizedPolicy, BalancedLagrangianPolicy

POLICIES = {
    "prefill_first": PrefillFirstPolicy,
    "lagrangian(paper)": LagrangianPolicy,
    "balanced(ours)": BalancedLagrangianPolicy,
    "amortized(ours)": AmortizedPolicy,
    "util_weighted": UtilizationWeightedPolicy,
    "dynamic_batch": DynamicBatchPolicy,
}


def main():
    for wname, spec in WORKLOADS.items():
        print(f"\n=== workload: {wname} ===")
        reqs = gsm8k_like_workload(
            spec, seed=0, estimate_noise_std=PAPER_PREDICTOR_NOISE_STD
        )
        for pname, pcls in POLICIES.items():
            tr = simulate(
                reqs, 200, PAPER_COST_MODEL, mode="hybrid", iteration_policy=pcls()
            )
            print(
                f"  {pname:18s} util={tr.utilization * 100:6.2f}%  "
                f"total={tr.makespan:7.2f}s  bins={tr.num_bins:4d}"
            )


if __name__ == "__main__":
    main()
