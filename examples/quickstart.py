"""Quickstart — the paper's scheduler in 40 lines.

Simulates the paper's GSM8K × LLaMA-65B experiment (Table III settings) in
all four configurations and prints the utilization / total-time comparison
(Figs. 6–9), plus the theoretical lower bound (Eq. 32) and an ASCII Gantt.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import PAPER_COST_MODEL, simulate, theoretical_lower_bound
from repro.core.gantt import ascii_gantt
from repro.data import PAPER_PREDICTOR_NOISE_STD, gsm8k_like_workload


def main():
    requests = gsm8k_like_workload(
        seed=0, estimate_noise_std=PAPER_PREDICTOR_NOISE_STD
    )
    print(f"{len(requests)} requests, 200 clients (paper Table III)\n")

    lb = theoretical_lower_bound(requests, 200, PAPER_COST_MODEL)
    print(
        f"theoretical lower bound (Eq. 32): {lb.total:.2f}s "
        f"(prefill* {lb.t_prefill_star:.2f} + decode* {lb.t_decode_star:.2f}; "
        f"paper: 180 = 13 + 167)\n"
    )

    paper = {
        "baseline": "80.2% / 201.00s",
        "offline": "85.5% / 197.08s",
        "online": "86.19% / 193.33s",
        "hybrid": "89.06% / 190.58s",
    }
    last = None
    for mode in ("baseline", "offline", "online", "hybrid"):
        tr = simulate(requests, 200, PAPER_COST_MODEL, mode=mode)
        print(
            f"{mode:9s} util={tr.utilization * 100:6.2f}%  "
            f"total={tr.makespan:7.2f}s  "
            f"speed={tr.generation_speed:7.1f} tok/s   (paper: {paper[mode]})"
        )
        last = tr
    print("\nGantt of the hybrid run (paper Fig. 9):")
    print(ascii_gantt(last, width=100, max_clients=20))


if __name__ == "__main__":
    main()
