"""Offline scheduling for an RLHF-style batch job (the paper's §IV-B use
case): all prompts known upfront, decode lengths well-estimated → the
Minimizing-Makespan Bin Packing assignment + the exact-MILP cross-check at
small scale, and the train-loop integration (sampled completions feeding a
training step with checkpointing).

    PYTHONPATH=src python examples/offline_rlhf.py
"""
import tempfile

import jax

from repro.configs import get_smoke_config
from repro.core import (
    PAPER_COST_MODEL,
    milp_assign,
    simulate,
    solve_offline,
    theoretical_lower_bound,
)
from repro.data import gsm8k_like_workload
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import TrainConfig, train

import numpy as np


def main():
    # --- 1. plan the sampling batch ------------------------------------ #
    reqs = gsm8k_like_workload(seed=11, known_lengths=True)
    res = solve_offline(reqs, 200, PAPER_COST_MODEL)
    lb = theoretical_lower_bound(reqs, 200, PAPER_COST_MODEL)
    print(
        f"offline assignment: est makespan={res.makespan_est:.2f}s "
        f"(LP bound {res.lp_lower_bound:.2f}s, gap {res.gap * 100:.2f}%, "
        f"{res.solve_seconds * 1e3:.0f} ms with {res.solver})"
    )

    # exact MILP agrees at small scale
    w = np.asarray([r.est_total_tokens for r in reqs[:12]], float)
    exact = milp_assign(w, 3, time_limit_s=20)
    loads = sorted(sum(w[i] for i in c) for c in exact)
    print(f"HiGHS exact check (12×3): balanced loads {loads}")

    # --- 2. simulate the serve under the assignment -------------------- #
    tr = simulate(reqs, 200, PAPER_COST_MODEL, mode="offline", oracle_estimates=True)
    print(
        f"offline-scheduled sampling run: util={tr.utilization * 100:.2f}% "
        f"total={tr.makespan:.2f}s (LB {lb.total:.2f}s)"
    )

    # --- 3. train on the sampled data with checkpoint/restart ---------- #
    cfg = get_smoke_config("qwen3_8b")
    with tempfile.TemporaryDirectory() as d:
        out = train(cfg, TrainConfig(steps=40, batch=4, seq=32,
                                     checkpoint_dir=d, save_every=10, log_every=0),
                    AdamWConfig(lr=5e-3, warmup_steps=5))
        print(
            f"policy-model training: loss {out['first_loss']:.3f} → "
            f"{out['last_loss']:.3f} over {out['steps_run']} steps "
            f"(checkpointed every 10)"
        )


if __name__ == "__main__":
    main()
